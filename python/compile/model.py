"""L2: GPT-2-architecture forward pass with LAMP attention, in JAX.

This is the computation the artifacts are lowered from. It mirrors the
rust native engine (`rust/src/model/`) operation-for-operation:

  * embeddings  wte[token] + wpe[pos]
  * pre-LN blocks: LN -> fused QKV -> LAMP causal attention (L1 kernel,
    PS(mu) KQ accumulation + selective FP32 recomputation) -> proj ->
    residual; LN -> GELU MLP -> residual
  * final LN -> tied unembedding

Runtime scalar inputs (mu, tau, seed, mode) make one lowered artifact per
model config serve every precision/threshold/rule combination:
mode in {0: strict, 1: relaxed, 2: relaxed_ln, 3: random}; the FP32
reference is mu=23, uniform low precision is tau=+inf.

Outputs: (logits [B, S, V], recompute_count, causal_total).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.lamp_attention import lamp_attention_head

LN_EPS = 1e-5
SQRT_2_OVER_PI = np.float32(0.79788456)
GELU_C = np.float32(0.044715)


class Config:
    """Model hyperparameters; mirror of rust ModelConfig (see config.rs)."""

    def __init__(self, name, vocab, seq, layers, heads, d_model, batch):
        self.name = name
        self.vocab = vocab
        self.seq = seq
        self.layers = layers
        self.heads = heads
        self.d_model = d_model
        self.batch = batch

    @property
    def head_dim(self):
        return self.d_model // self.heads

    @property
    def d_ff(self):
        return 4 * self.d_model

    def causal_products(self, s):
        return self.layers * self.heads * s * (s + 1) // 2


CONFIGS = {
    "nano": Config("nano", 128, 32, 2, 2, 32, 2),
    "small": Config("small", 512, 128, 4, 4, 128, 4),
    "xl": Config("xl", 512, 128, 8, 8, 256, 4),
}


def weight_order(cfg: Config) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical flat artifact input order, matching
    rust Weights::artifact_order()."""
    d, dff = cfg.d_model, cfg.d_ff
    order = [("wte", (cfg.vocab, d)), ("wpe", (cfg.seq, d))]
    for l in range(cfg.layers):
        order += [
            (f"h{l}.ln1.g", (d,)),
            (f"h{l}.ln1.b", (d,)),
            (f"h{l}.attn.w_qkv", (d, 3 * d)),
            (f"h{l}.attn.b_qkv", (3 * d,)),
            (f"h{l}.attn.w_proj", (d, d)),
            (f"h{l}.attn.b_proj", (d,)),
            (f"h{l}.ln2.g", (d,)),
            (f"h{l}.ln2.b", (d,)),
            (f"h{l}.mlp.w_fc", (d, dff)),
            (f"h{l}.mlp.b_fc", (dff,)),
            (f"h{l}.mlp.w_out", (dff, d)),
            (f"h{l}.mlp.b_out", (d,)),
        ]
    order += [("lnf.g", (d,)), ("lnf.b", (d,))]
    return order


def unflatten_params(cfg: Config, flat: List[jax.Array]) -> Dict[str, jax.Array]:
    names = [n for n, _ in weight_order(cfg)]
    assert len(flat) == len(names), (len(flat), len(names))
    return dict(zip(names, flat))


def layernorm(x, g, b):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g + b


def gelu(x):
    """GPT-2 tanh-approximated GELU (same constants as the rust engine)."""
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + GELU_C * x * x * x)))


def forward(
    cfg: Config,
    params: Dict[str, jax.Array],
    tokens: jax.Array,  # [B, S] int32
    mu: jax.Array,  # scalar int32
    tau: jax.Array,  # scalar f32
    seed: jax.Array,  # scalar int32
    mode: jax.Array,  # scalar int32 (0..3)
):
    """LAMP forward pass. Returns (logits, recompute_count, causal_total)."""
    b, s = tokens.shape
    hd = cfg.head_dim

    x = params["wte"][tokens] + params["wpe"][:s][None, :, :]
    total_count = jnp.float32(0.0)

    for l in range(cfg.layers):
        p = lambda k: params[f"h{l}.{k}"]  # noqa: E731
        xn = layernorm(x, p("ln1.g"), p("ln1.b"))
        qkv = xn @ p("attn.w_qkv") + p("attn.b_qkv")
        q, k, v = jnp.split(qkv, 3, axis=-1)  # each [B, S, D]

        heads_out = []
        for h in range(cfg.heads):
            sl = slice(h * hd, (h + 1) * hd)
            qh, kh, vh = q[..., sl], k[..., sl], v[..., sl]
            # Per-(layer, head, batch) seeds so the Random rule streams are
            # independent, mirroring the rust per-layer forked RNGs.
            seeds = seed + jnp.arange(b, dtype=jnp.int32) * 7919 + l * 104729 + h * 1299709
            out, cnt = jax.vmap(
                lambda qq, kk, vv, sd: lamp_attention_head(
                    qq, kk, vv, mu, tau, sd, mode, cfg.seq
                )
            )(qh, kh, vh, seeds)
            heads_out.append(out)
            total_count = total_count + jnp.sum(cnt)
        attn = jnp.concatenate(heads_out, axis=-1)
        x = x + attn @ p("attn.w_proj") + p("attn.b_proj")

        xn = layernorm(x, p("ln2.g"), p("ln2.b"))
        hmid = gelu(xn @ p("mlp.w_fc") + p("mlp.b_fc"))
        x = x + hmid @ p("mlp.w_out") + p("mlp.b_out")

    x = layernorm(x, params["lnf.g"], params["lnf.b"])
    logits = x @ params["wte"].T
    causal_total = jnp.float32(b * cfg.causal_products(s))
    return logits, total_count, causal_total


def forward_flat(cfg: Config, tokens, mu, tau, seed, mode, *flat_weights):
    """Entry point lowered by aot.py: weights as positional args in
    `weight_order`, so the rust runtime can feed them as a flat list."""
    params = unflatten_params(cfg, list(flat_weights))
    return forward(cfg, params, tokens, mu, tau, seed, mode)


# ----------------------------------------------------------------------
# Training-path forward (differentiable: plain FP32 attention, no LAMP).
# Used only by train.py at build time.
# ----------------------------------------------------------------------


def forward_train(cfg: Config, params: Dict[str, jax.Array], tokens: jax.Array):
    """Standard FP32 forward (no rounding simulation), for training."""
    b, s = tokens.shape
    hd = cfg.head_dim

    x = params["wte"][tokens] + params["wpe"][:s][None, :, :]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    for l in range(cfg.layers):
        p = lambda k: params[f"h{l}.{k}"]  # noqa: E731
        xn = layernorm(x, p("ln1.g"), p("ln1.b"))
        qkv = xn @ p("attn.w_qkv") + p("attn.b_qkv")
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, cfg.heads, hd).transpose(0, 2, 1, 3)
        scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
        scores = jnp.where(causal[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = (probs @ v).transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        x = x + attn @ p("attn.w_proj") + p("attn.b_proj")
        xn = layernorm(x, p("ln2.g"), p("ln2.b"))
        hmid = gelu(xn @ p("mlp.w_fc") + p("mlp.b_fc"))
        x = x + hmid @ p("mlp.w_out") + p("mlp.b_out")
    x = layernorm(x, params["lnf.g"], params["lnf.b"])
    return x @ params["wte"].T


def loss_fn(cfg: Config, params, tokens):
    """Mean next-token cross-entropy."""
    logits = forward_train(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def init_params(cfg: Config, key) -> Dict[str, jax.Array]:
    """GPT-2-style initialization (N(0, 0.02), residual scaling)."""
    d, dff = cfg.d_model, cfg.d_ff
    resid = 1.0 / np.sqrt(2.0 * cfg.layers)
    params = {}
    key, k1, k2 = jax.random.split(key, 3)
    params["wte"] = 0.02 * jax.random.normal(k1, (cfg.vocab, d), jnp.float32)
    params["wpe"] = 0.01 * jax.random.normal(k2, (cfg.seq, d), jnp.float32)
    for l in range(cfg.layers):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        params[f"h{l}.ln1.g"] = jnp.ones(d, jnp.float32)
        params[f"h{l}.ln1.b"] = jnp.zeros(d, jnp.float32)
        params[f"h{l}.attn.w_qkv"] = 0.02 * jax.random.normal(k1, (d, 3 * d), jnp.float32)
        params[f"h{l}.attn.b_qkv"] = jnp.zeros(3 * d, jnp.float32)
        params[f"h{l}.attn.w_proj"] = 0.02 * resid * jax.random.normal(k2, (d, d), jnp.float32)
        params[f"h{l}.attn.b_proj"] = jnp.zeros(d, jnp.float32)
        params[f"h{l}.ln2.g"] = jnp.ones(d, jnp.float32)
        params[f"h{l}.ln2.b"] = jnp.zeros(d, jnp.float32)
        params[f"h{l}.mlp.w_fc"] = 0.02 * jax.random.normal(k3, (d, dff), jnp.float32)
        params[f"h{l}.mlp.b_fc"] = jnp.zeros(dff, jnp.float32)
        params[f"h{l}.mlp.w_out"] = 0.02 * resid * jax.random.normal(k4, (dff, d), jnp.float32)
        params[f"h{l}.mlp.b_out"] = jnp.zeros(d, jnp.float32)
    params["lnf.g"] = jnp.ones(d, jnp.float32)
    params["lnf.b"] = jnp.zeros(d, jnp.float32)
    return params
