"""AOT compile path: train the *-sim models, lower the LAMP forward pass to
HLO **text**, and write all artifacts consumed by the rust runtime.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the `xla`
crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts (per model config):
  model_<name>.hlo.txt     full forward; inputs (tokens, mu, tau, seed,
                           mode, *weights); outputs (logits, recompute
                           count, causal total)
  weights_<name>.lamp      trained weights (.lamp container)
  meta_<name>.kv           model hyperparameters
plus standalone L1 kernel artifacts:
  kernel_ps_matmul.hlo.txt
  kernel_lamp_attention.hlo.txt
and train_log_<name>.txt with the loss curve.
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tensorio
from .kernels.lamp_attention import lamp_attention_head
from .kernels.ps_round import ps_matmul
from .model import CONFIGS, Config, forward_flat, weight_order
from .train import params_to_numpy, train


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: Config) -> str:
    """Lower the LAMP forward pass for one config to HLO text."""
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    scal_i = jax.ShapeDtypeStruct((), jnp.int32)
    scal_f = jax.ShapeDtypeStruct((), jnp.float32)
    weight_specs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in weight_order(cfg)
    ]
    fn = functools.partial(forward_flat, cfg)
    lowered = jax.jit(fn).lower(
        tok_spec, scal_i, scal_f, scal_i, scal_i, *weight_specs
    )
    return to_hlo_text(lowered)


def lower_kernels() -> dict:
    """Standalone L1 kernel artifacts for runtime micro-benches/tests."""
    out = {}
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    mu = jax.ShapeDtypeStruct((), jnp.int32)
    out["kernel_ps_matmul"] = to_hlo_text(
        jax.jit(lambda x, y, m: (ps_matmul(x, y, m),)).lower(a, a, mu)
    )
    s, hd = 32, 16
    q = jax.ShapeDtypeStruct((s, hd), jnp.float32)
    scal_f = jax.ShapeDtypeStruct((), jnp.float32)
    out["kernel_lamp_attention"] = to_hlo_text(
        jax.jit(
            lambda qq, kk, vv, m, t, sd, md: lamp_attention_head(
                qq, kk, vv, m, t, sd, md, 1024
            )
        ).lower(q, q, q, mu, scal_f, mu, mu)
    )
    return out


def write_meta(path: str, cfg: Config) -> None:
    with open(path, "w") as f:
        f.write(f"model.name = {cfg.name}\n")
        f.write(f"model.vocab = {cfg.vocab}\n")
        f.write(f"model.seq = {cfg.seq}\n")
        f.write(f"model.layers = {cfg.layers}\n")
        f.write(f"model.heads = {cfg.heads}\n")
        f.write(f"model.d_model = {cfg.d_model}\n")
        f.write(f"model.batch = {cfg.batch}\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="nano,small,xl")
    ap.add_argument("--skip-train", action="store_true", help="random init (tests only)")
    ap.add_argument(
        "--reuse-weights",
        action="store_true",
        help="keep existing weights_<cfg>.lamp (re-lower HLO only)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    t0 = time.time()
    for name in args.configs.split(","):
        cfg = CONFIGS[name]
        wpath = os.path.join(args.out_dir, f"weights_{name}.lamp")
        if args.reuse_weights and os.path.exists(wpath):
            print(f"=== {name}: reusing existing weights ===", flush=True)
        else:
            print(f"=== {name}: train ===", flush=True)
            if args.skip_train:
                from .model import init_params

                params, history = init_params(cfg, jax.random.PRNGKey(0)), [float("nan")]
            else:
                params, history = train(cfg)
            np_params = params_to_numpy(params)
            order = weight_order(cfg)
            tensors = [(n, np_params[n]) for n, _ in order]
            tensorio.write_tensors(wpath, tensors)
            with open(os.path.join(args.out_dir, f"train_log_{name}.txt"), "w") as f:
                for i, l in enumerate(history):
                    f.write(f"{i} {l:.6f}\n")
        write_meta(os.path.join(args.out_dir, f"meta_{name}.kv"), cfg)

        print(f"=== {name}: lower ===", flush=True)
        hlo = lower_model(cfg)
        with open(os.path.join(args.out_dir, f"model_{name}.hlo.txt"), "w") as f:
            f.write(hlo)
        print(f"    {len(hlo)} chars ({time.time() - t0:.1f}s)", flush=True)

    print("=== kernels: lower ===", flush=True)
    for kname, text in lower_kernels().items():
        with open(os.path.join(args.out_dir, f"{kname}.hlo.txt"), "w") as f:
            f.write(text)
    print(f"done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
