"""Writer/reader for the `.lamp` tensor container format.

Mirrors `rust/src/tensorio/mod.rs` byte-for-byte (little-endian). Two
on-disk versions share one layout skeleton:

    magic   : 8 bytes  b"LAMPTNSR"
    version : u32      (1 or 2)
    count   : u32
    repeat count times:
      name_len u32 | name bytes | dtype u32 (0=f32, 1=i32, 2=bf16, 3=ps-f32)
      | mu u32 (dtype 3 only) | ndim u32 | dims ndim*u64
      | payload elem_bytes(dtype)*prod(dims) bytes

* **v1** carries f32/i32 tensors only (4 bytes/element) — the historical
  format, still written whenever no tensor needs more, so f32-only files
  stay byte-identical to the legacy writer's output.
* **v2** adds the mixed-precision weight-storage dtypes consumed by the
  Rust native engine's ``linalg::WeightTensor``: ``bf16`` (2 bytes/element)
  and ``ps-f32`` (f32 payload pre-rounded to mu mantissa bits). Every
  stored value is an exact f32, so decoding is lossless; ``read_tensors``
  returns float32 arrays for both.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Optional, Tuple

import numpy as np

MAGIC = b"LAMPTNSR"
VERSION_V1 = 1
VERSION_V2 = 2

DTYPE_F32 = 0
DTYPE_I32 = 1
DTYPE_BF16 = 2
DTYPE_PS_F32 = 3


def f32_to_bf16(a: np.ndarray) -> np.ndarray:
    """Round a float array to bf16 bit patterns (RNE), as uint16.
    Shape-preserving, including 0-d inputs."""
    x = np.asarray(a, dtype="<f4")
    bits = np.atleast_1d(x).view("<u4")
    nan = np.isnan(np.atleast_1d(x))
    lsb = (bits >> 16) & 1
    out = ((bits + np.uint32(0x7FFF) + lsb) >> 16).astype("<u2")
    # Quiet NaNs explicitly (the rounding add may clear payload bits).
    out[nan] = ((bits[nan] >> 16) | np.uint32(0x0040)).astype("<u2")
    return out.reshape(x.shape)


def bf16_to_f32(b: np.ndarray) -> np.ndarray:
    """Widen bf16 bit patterns (uint16) to the exact float32 they encode."""
    return (np.asarray(b, dtype="<u2").astype("<u4") << 16).view("<f4")


def round_to_mantissa(a: np.ndarray, mu: int) -> np.ndarray:
    """Round float32 values to ``mu`` mantissa bits (RNE) — the numpy twin
    of ``rust/src/softfloat/round.rs::round_to_mantissa``. Shape-preserving,
    including 0-d inputs."""
    if not 1 <= mu <= 23:
        raise ValueError(f"mu {mu} out of 1..=23")
    x = np.asarray(a, dtype="<f4")
    if mu == 23:
        return x.copy()
    shift = 23 - mu
    flat = np.atleast_1d(x)
    bits = flat.view("<u4")
    lsb = (bits >> shift) & 1
    bias = np.uint32((1 << (shift - 1)) - 1) + lsb
    r = ((bits + bias) >> shift) << shift
    out = r.astype("<u4").view("<f4").copy()
    keep = ~np.isfinite(flat)
    out[keep] = flat[keep]
    return out.reshape(x.shape)


def write_tensors(
    path: str,
    tensors: List[Tuple[str, np.ndarray]],
    formats: Optional[Dict[str, str]] = None,
) -> None:
    """Write an ordered list of (name, array) pairs.

    Default mapping: float -> f32, int -> i32 (v1, byte-identical to the
    legacy writer). ``formats`` optionally assigns a storage format per
    tensor name using the shared f32|bf16|ps<mu> vocabulary (``"f32"`` is
    the explicit identity); the file is written as v2 exactly when a
    quantized dtype actually appears. Keys that match no tensor name are
    an error (a typo must not silently skip quantization).
    """
    formats = formats or {}
    names = {name for name, _ in tensors}
    unknown = set(formats) - names
    if unknown:
        raise ValueError(f"formats name(s) matching no tensor: {sorted(unknown)}")
    # Resolve every tensor's payload + dtype first; the container version
    # depends on the *resolved* dtypes (mirrors Rust's required_version),
    # not on whether a formats dict was passed.
    seen = set()
    resolved = []  # (name, payload array, dtype_code, mu)
    for name, arr in tensors:
        if name in seen:
            raise ValueError(f"duplicate tensor name {name!r}")
        seen.add(name)
        a = np.asarray(arr)
        fmt = formats.get(name)
        mu = None
        if fmt is None or fmt == "f32":
            if fmt == "f32" or a.dtype.kind == "f":
                a = a.astype("<f4")
                dtype_code = DTYPE_F32
            elif a.dtype.kind in "iu":
                a = a.astype("<i4")
                dtype_code = DTYPE_I32
            else:
                raise TypeError(f"unsupported dtype {a.dtype} for {name!r}")
        elif fmt == "bf16":
            a = f32_to_bf16(a.astype("<f4"))
            dtype_code = DTYPE_BF16
        elif fmt.startswith("ps") and fmt[2:].isdigit():
            mu = int(fmt[2:])
            a = round_to_mantissa(a.astype("<f4"), mu)
            dtype_code = DTYPE_PS_F32
        else:
            raise ValueError(
                f"unknown storage format {fmt!r} for {name!r} (f32|bf16|ps<mu>)"
            )
        resolved.append((name, a, dtype_code, mu))
    version = (
        VERSION_V2
        if any(code in (DTYPE_BF16, DTYPE_PS_F32) for _, _, code, _ in resolved)
        else VERSION_V1
    )
    out = bytearray()
    out += MAGIC
    out += struct.pack("<II", version, len(resolved))
    for name, a, dtype_code, mu in resolved:
        nb = name.encode("utf-8")
        out += struct.pack("<I", len(nb))
        out += nb
        out += struct.pack("<I", dtype_code)
        if dtype_code == DTYPE_PS_F32:
            out += struct.pack("<I", mu)
        out += struct.pack("<I", a.ndim)
        for d in a.shape:
            out += struct.pack("<Q", d)
        out += a.tobytes(order="C")
    with open(path, "wb") as f:
        f.write(bytes(out))


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    """Read back into a dict (insertion order preserved). Accepts v1 and
    v2; bf16 and ps-f32 payloads are returned as their exact float32
    values (dequantization is lossless)."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != MAGIC:
        raise ValueError("bad magic: not a .lamp file")
    version, count = struct.unpack_from("<II", data, 8)
    if version not in (VERSION_V1, VERSION_V2):
        raise ValueError(f"unsupported version {version}")
    off = 16
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + name_len].decode("utf-8")
        off += name_len
        (dtype_code,) = struct.unpack_from("<I", data, off)
        off += 4
        if dtype_code in (DTYPE_BF16, DTYPE_PS_F32) and version < VERSION_V2:
            raise ValueError(f"dtype code {dtype_code} requires v2, file is v{version}")
        if dtype_code == DTYPE_PS_F32:
            (mu,) = struct.unpack_from("<I", data, off)
            off += 4
            if not 1 <= mu <= 23:
                raise ValueError(f"ps-f32 tensor {name!r}: mu {mu} out of 1..=23")
        (ndim,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        n = int(np.prod(dims)) if ndim else 1
        if dtype_code == DTYPE_F32:
            arr = np.frombuffer(data, dtype="<f4", count=n, offset=off)
            off += 4 * n
        elif dtype_code == DTYPE_I32:
            arr = np.frombuffer(data, dtype="<i4", count=n, offset=off)
            off += 4 * n
        elif dtype_code == DTYPE_BF16:
            raw = np.frombuffer(data, dtype="<u2", count=n, offset=off)
            off += 2 * n
            arr = bf16_to_f32(raw)
        elif dtype_code == DTYPE_PS_F32:
            arr = np.frombuffer(data, dtype="<f4", count=n, offset=off)
            off += 4 * n
        else:
            raise ValueError(f"unknown dtype code {dtype_code}")
        out[name] = arr.reshape(dims).copy()
    return out
