"""Writer/reader for the `.lamp` tensor container format.

Mirrors `rust/src/tensorio/mod.rs` byte-for-byte (little-endian):

    magic   : 8 bytes  b"LAMPTNSR"
    version : u32      (1)
    count   : u32
    repeat count times:
      name_len u32 | name bytes | dtype u32 (0=f32, 1=i32) | ndim u32
      | dims ndim*u64 | payload 4*prod(dims) bytes
"""

from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

MAGIC = b"LAMPTNSR"
VERSION = 1


def write_tensors(path: str, tensors: List[Tuple[str, np.ndarray]]) -> None:
    """Write an ordered list of (name, array) pairs. float -> f32, int -> i32."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<II", VERSION, len(tensors))
    seen = set()
    for name, arr in tensors:
        if name in seen:
            raise ValueError(f"duplicate tensor name {name!r}")
        seen.add(name)
        a = np.asarray(arr)
        if a.dtype.kind == "f":
            a = a.astype("<f4")
            dtype_code = 0
        elif a.dtype.kind in "iu":
            a = a.astype("<i4")
            dtype_code = 1
        else:
            raise TypeError(f"unsupported dtype {a.dtype} for {name!r}")
        nb = name.encode("utf-8")
        out += struct.pack("<I", len(nb))
        out += nb
        out += struct.pack("<II", dtype_code, a.ndim)
        for d in a.shape:
            out += struct.pack("<Q", d)
        out += a.tobytes(order="C")
    with open(path, "wb") as f:
        f.write(bytes(out))


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    """Read back into a dict (order preserved in py3.7+ dicts)."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != MAGIC:
        raise ValueError("bad magic: not a .lamp file")
    version, count = struct.unpack_from("<II", data, 8)
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    off = 16
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (name_len,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + name_len].decode("utf-8")
        off += name_len
        dtype_code, ndim = struct.unpack_from("<II", data, off)
        off += 8
        dims = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        n = int(np.prod(dims)) if ndim else 1
        dt = "<f4" if dtype_code == 0 else "<i4"
        arr = np.frombuffer(data, dtype=dt, count=n, offset=off).reshape(dims)
        off += 4 * n
        out[name] = arr.copy()
    return out
