"""Build-time training of the *-sim models (DESIGN.md §Substitutions).

Pretrained GPT-2 weights are unavailable offline, so each registry config
is trained for a few hundred AdamW steps on the mixed synthetic corpus.
This is enough for the models to develop concentrated attention and a
realistic KQ-logit spread — the numerical regime LAMP targets — while
keeping `make artifacts` fast on CPU.

Run via aot.py; standalone: python -m compile.train --config small
"""

from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .model import CONFIGS, Config, init_params, loss_fn

TRAIN_STEPS = {"nano": 200, "small": 300, "xl": 300}
TRAIN_BATCH = {"nano": 16, "small": 8, "xl": 8}
LR = 3e-3
WD = 0.01


def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr=LR, b1=0.9, b2=0.99, eps=1e-8, wd=WD):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)

    def upd(p, m, v):
        step = lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + eps)
        return p - step - lr * wd * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def train(cfg: Config, steps: int | None = None, seed: int = 0, log_every: int = 50):
    """Train one config; returns (params, loss_history)."""
    steps = steps if steps is not None else TRAIN_STEPS[cfg.name]
    batch = TRAIN_BATCH[cfg.name]
    params = init_params(cfg, jax.random.PRNGKey(seed))
    state = adamw_init(params)

    @jax.jit
    def step_fn(params, state, tokens):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
        params, state = adamw_update(params, grads, state)
        return params, state, loss

    history = []
    t0 = time.time()
    for step in range(steps):
        tokens = jnp.asarray(
            data_mod.mixed_training_batch(cfg.vocab, batch, cfg.seq, step)
        )
        params, state, loss = step_fn(params, state, tokens)
        history.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"[train/{cfg.name}] step {step:4d}  loss {float(loss):.4f}  "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, history


def params_to_numpy(params: Dict[str, jax.Array]) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in params.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="nano", choices=list(CONFIGS))
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    _, hist = train(cfg, steps=args.steps)
    print(f"final loss: {hist[-1]:.4f} (start {hist[0]:.4f})")


if __name__ == "__main__":
    main()
