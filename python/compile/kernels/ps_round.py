"""L1 Pallas kernel: PS(mu) round-to-nearest-even and PS-accumulated matmul.

The PS(mu) format (paper §4.1) is FP32 rounded to mu mantissa bits, RNE.
The bit-twiddling below matches `rust/src/softfloat/round.rs` bit-for-bit
and takes mu as a *runtime* scalar so one lowered artifact serves every
precision.

Pallas kernels are lowered with interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO ops
(see /opt/xla-example/README.md). On a real TPU the same kernel structure
maps to VPU integer ops fused into the MXU accumulation loop — see
DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def ps_round(x: jax.Array, mu: jax.Array) -> jax.Array:
    """Round f32 values to `mu` mantissa bits, RNE (ties to even).

    * mu == 23 is the identity; non-finite values pass through.
    * Matches rust round_to_mantissa: integer add of (half-ulp - 1 + lsb)
      then truncate; mantissa overflow carries into the exponent (correct
      RNE), overflow past the max exponent yields inf.
    """
    mu = jnp.asarray(mu, jnp.int32)
    x = jnp.asarray(x, jnp.float32)
    u = lax.bitcast_convert_type(x, jnp.uint32)
    shift = (23 - mu).astype(jnp.uint32)
    sh = jnp.maximum(shift, jnp.uint32(1))  # avoid UB shifts when mu == 23
    lsb = (u >> sh) & jnp.uint32(1)
    bias = lsb + ((jnp.uint32(1) << (sh - jnp.uint32(1))) - jnp.uint32(1))
    r = ((u + bias) >> sh) << sh
    out = lax.bitcast_convert_type(r, jnp.float32)
    out = jnp.where(shift == 0, x, out)
    return jnp.where(jnp.isfinite(x), out, x)


def ps_matmul_ref_accum(a: jax.Array, b: jax.Array, mu: jax.Array) -> jax.Array:
    """C = A @ B with per-step PS(mu) rounding: c <- round(c + a_k * b_k).

    Sequential over the contraction axis, matching the rust engine's
    accumulation order bit-for-bit. a: [m, k], b: [k, n].
    """
    m, kdim = a.shape
    _, n = b.shape

    def step(i, c):
        col = lax.dynamic_slice_in_dim(a, i, 1, axis=1)  # [m, 1]
        row = lax.dynamic_slice_in_dim(b, i, 1, axis=0)  # [1, n]
        return ps_round(c + col * row, mu)

    return lax.fori_loop(0, kdim, step, jnp.zeros((m, n), jnp.float32))


def _ps_matmul_kernel(mu_ref, a_ref, b_ref, o_ref):
    """Pallas kernel body: one (m, n) tile accumulated over k with rounding."""
    a = a_ref[...]
    b = b_ref[...]
    mu = mu_ref[0]
    o_ref[...] = ps_matmul_ref_accum(a, b, mu)


def ps_matmul(a: jax.Array, b: jax.Array, mu: jax.Array) -> jax.Array:
    """Pallas-wrapped PS(mu) matmul (interpret mode; single tile).

    Tiles are deliberately whole-array here: at the model sizes used in
    this reproduction one (S, S) score tile fits VMEM comfortably
    (see DESIGN.md §Hardware-Adaptation for the blocked variant analysis).
    """
    m, _ = a.shape
    _, n = b.shape
    mu_arr = jnp.asarray(mu, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _ps_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(mu_arr, a, b)
