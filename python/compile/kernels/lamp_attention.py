"""L1 Pallas kernel: LAMP causal attention for one (batch, head) block.

Implements the paper's §4.2 pipeline per attention head:

  1. KQ scores accumulated in PS(mu) with per-step rounding (§4.1),
     scaled by 1/sqrt(d_h) in FP32;
  2. LAMP selection on each causal row — strict (eq. 8), relaxed (eq. 9),
     relaxed length-normalized (App. C.5) or random (App. C.4), chosen by
     a runtime `mode` scalar;
  3. FP32 recomputation of the flagged inner products;
  4. FP32 softmax + value aggregation.

Outputs the attention result and the number of recomputed products.

TPU mapping (DESIGN.md §Hardware-Adaptation): selection is an elementwise
VPU predicate over the score tile; recomputation is a masked MXU matmul of
the whole tile (recompute-tile-then-select), the systolic-array-friendly
replacement for scattered per-element dots.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .ps_round import ps_round

# Selection mode codes (keep in sync with rust/src/coordinator/policy.rs).
MODE_STRICT = 0
MODE_RELAXED = 1
MODE_RELAXED_LN = 2
MODE_RANDOM = 3

# Python float (not a jnp constant: pallas kernels may not capture traced
# constants from module scope).
_NEG = -1e30


def _hash_u32(x: jax.Array) -> jax.Array:
    """splitmix32-style integer hash (uint32 -> uint32)."""
    x = jnp.asarray(x, jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def _strict_mask(y, causal, tau):
    """Strict rule (eq. 8): 2 z (1 - z) |y| > tau (row softmax over the
    causal prefix)."""
    ym = jnp.where(causal, y, _NEG)
    m = jnp.max(ym, axis=1, keepdims=True)
    e = jnp.where(causal, jnp.exp(ym - m), 0.0)
    z = e / jnp.sum(e, axis=1, keepdims=True)
    sens = 2.0 * z * (1.0 - z) * jnp.abs(y)
    return jnp.logical_and(sens > tau, causal)


def _relaxed_w(y, causal):
    """|y| e^{y - rowmax} over causal entries (eq. 9 sensitivities)."""
    ym = jnp.where(causal, y, _NEG)
    m = jnp.max(ym, axis=1, keepdims=True)
    return jnp.where(causal, jnp.abs(y) * jnp.exp(ym - m), 0.0)


def lamp_select(
    y: jax.Array,
    causal: jax.Array,
    tau: jax.Array,
    mode: jax.Array,
    seed: jax.Array,
    ref_len: int,
) -> jax.Array:
    """Selection mask [S, S] for scaled causal scores `y`.

    Rows are softmax rows (query positions); only causal entries (j <= i)
    are candidates. Dispatched with `lax.switch` so only the requested
    rule's mask is computed at run time — the random baseline's O(S³)
    rank computation would otherwise dominate every forward pass
    (EXPERIMENTS.md §Perf L2).
    """
    s = y.shape[0]

    def strict_branch(_):
        return _strict_mask(y, causal, tau)

    def relaxed_branch(_):
        w = _relaxed_w(y, causal)
        wmax = jnp.max(w, axis=1, keepdims=True)
        return jnp.logical_and(w > tau * wmax, causal)

    def relaxed_ln_branch(_):
        # Length-normalized relaxed (App. C.5): tau * sqrt(ref_len / n_i),
        # saturated at 1 (relative thresholds live in [0, 1)).
        w = _relaxed_w(y, causal)
        wmax = jnp.max(w, axis=1, keepdims=True)
        row_len = jnp.arange(1, s + 1, dtype=jnp.float32).reshape(s, 1)
        tau_ln = jnp.minimum(tau * jnp.sqrt(ref_len / row_len), 1.0)
        return jnp.logical_and(w > tau_ln * wmax, causal)

    def random_branch(_):
        # Random baseline (App. C.4): per-row count from the strict rule,
        # uniformly random causal positions. Rank u-values per row; select
        # the `count` smallest.
        count = jnp.sum(_strict_mask(y, causal, tau), axis=1, keepdims=True)
        idx = jnp.arange(s, dtype=jnp.uint32)
        flat = idx[:, None] * jnp.uint32(s) + idx[None, :]
        u = _hash_u32(flat + jnp.asarray(seed, jnp.uint32) * jnp.uint32(0x9E3779B9))
        u = jnp.where(causal, u, jnp.uint32(0xFFFFFFFF))
        # rank[i, j] = #{k : u[i, k] < u[i, j]} (hash collisions are
        # ~impossible at these sizes).
        rank = jnp.sum((u[:, None, :] < u[:, :, None]).astype(jnp.int32), axis=2)
        return jnp.logical_and(rank < count, causal)

    mode = jnp.clip(jnp.asarray(mode, jnp.int32), 0, 3)
    return lax.switch(
        mode,
        [strict_branch, relaxed_branch, relaxed_ln_branch, random_branch],
        operand=None,
    )


def _lamp_attention_kernel(ref_len: int, scalars_ref, q_ref, k_ref, v_ref, o_ref, cnt_ref):
    """Kernel body for one (batch*head) block.

    scalars = [mu (bitcast i32), tau, seed (bitcast i32)] packed as f32[3]
    to keep a single scalar operand; bit-exact unpack via bitcast.
    """
    q = q_ref[...]  # [S, hd]
    k = k_ref[...]
    v = v_ref[...]
    mu = lax.bitcast_convert_type(scalars_ref[0], jnp.int32)
    tau = scalars_ref[1]
    seed = lax.bitcast_convert_type(scalars_ref[2], jnp.int32)

    s, hd = q.shape
    scale = jnp.float32(1.0) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))

    # Step 1: PS(mu) sequential accumulation of raw KQ products.
    def step(d, c):
        qd = lax.dynamic_slice_in_dim(q, d, 1, axis=1)  # [S, 1]
        kd = lax.dynamic_slice_in_dim(k, d, 1, axis=1)  # [S, 1]
        return ps_round(c + qd * kd.T, mu)

    raw = lax.fori_loop(0, hd, step, jnp.zeros((s, s), jnp.float32))
    y = raw * scale

    # Steps 2-3: selection + FP32 recomputation of flagged products.
    sel = lamp_select(y, causal, tau, _mode_of(scalars_ref), seed, ref_len)
    exact = (q @ k.T) * scale
    y = jnp.where(sel, exact, y)

    # Step 4: FP32 softmax + value aggregation.
    ym = jnp.where(causal, y, _NEG)
    m = jnp.max(ym, axis=1, keepdims=True)
    e = jnp.where(causal, jnp.exp(ym - m), 0.0)
    probs = e / jnp.sum(e, axis=1, keepdims=True)
    o_ref[...] = probs @ v
    cnt_ref[...] = jnp.sum(sel).astype(jnp.float32).reshape(1)


def _mode_of(scalars_ref):
    return lax.bitcast_convert_type(scalars_ref[3], jnp.int32)


def lamp_attention_head(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mu: jax.Array,
    tau: jax.Array,
    seed: jax.Array,
    mode: jax.Array,
    ref_len: int,
) -> tuple[jax.Array, jax.Array]:
    """LAMP causal attention for a single head.

    q, k, v: [S, hd] FP32. Returns (out [S, hd], recompute_count scalar).
    """
    s, hd = q.shape
    scalars = jnp.stack(
        [
            lax.bitcast_convert_type(jnp.asarray(mu, jnp.int32), jnp.float32),
            jnp.asarray(tau, jnp.float32),
            lax.bitcast_convert_type(jnp.asarray(seed, jnp.int32), jnp.float32),
            lax.bitcast_convert_type(jnp.asarray(mode, jnp.int32), jnp.float32),
        ]
    )
    import functools

    out, cnt = pl.pallas_call(
        functools.partial(_lamp_attention_kernel, ref_len),
        out_shape=(
            jax.ShapeDtypeStruct((s, hd), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(scalars, q, k, v)
    return out, cnt[0]
