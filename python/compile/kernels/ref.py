"""Pure-jnp/numpy correctness oracles for the L1 kernels.

These implement the same mathematics with no Pallas and no bit tricks
(numpy float64 / explicit Python rounding where needed), and are the
ground truth for `python/tests/`.
"""

from __future__ import annotations

import numpy as np


def ps_round_ref(x: np.ndarray, mu: int) -> np.ndarray:
    """Reference PS(mu) RNE rounding via integer arithmetic on the bits.

    Independent implementation (numpy uint64 arithmetic, explicit tie
    handling) used to validate the bit-twiddling kernel.
    """
    assert 1 <= mu <= 23
    x = np.asarray(x, np.float32)
    if mu == 23:
        return x.copy()
    u = x.view(np.uint32).astype(np.uint64)
    shift = np.uint64(23 - mu)
    one = np.uint64(1)
    kept = u >> shift
    frac = u & ((one << shift) - one)
    half = one << (shift - one)
    round_up = (frac > half) | ((frac == half) & ((kept & one) == one))
    r = (kept + round_up.astype(np.uint64)) << shift
    out = (r & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.float32)
    finite = np.isfinite(x)
    return np.where(finite, out, x)


def fma_f32(a, b, c):
    """Emulated single-rounding f32 FMA: a*b is exact in f64 (48-bit
    product of 24-bit mantissas), the add rounds once in f64, then the cast
    rounds to f32. Agrees with hardware f32 FMA except for astronomically
    rare double-rounding cases (~2^-29 per op). This is the canonical
    accumulation step -- XLA CPU contracts `c + a*b` to an FMA, and the
    rust engine uses `f32::mul_add`."""
    return (
        np.asarray(c, np.float64) + np.asarray(a, np.float64) * np.asarray(b, np.float64)
    ).astype(np.float32)


def ps_matmul_ref(a: np.ndarray, b: np.ndarray, mu: int) -> np.ndarray:
    """C = A @ B with per-step PS(mu) rounding of FMA accumulation,
    sequential over k."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    m, kdim = a.shape
    _, n = b.shape
    c = np.zeros((m, n), np.float32)
    for i in range(kdim):
        c = ps_round_ref(fma_f32(a[:, i : i + 1], b[i : i + 1, :], c), mu)
    return c


def softmax_ref(y: np.ndarray, axis: int = -1) -> np.ndarray:
    m = np.max(y, axis=axis, keepdims=True)
    e = np.exp(y - m)
    return e / np.sum(e, axis=axis, keepdims=True)


def select_strict_ref(y_row: np.ndarray, tau: float) -> np.ndarray:
    """Strict LAMP rule (eq. 8) on one causal row of scaled scores."""
    z = softmax_ref(y_row.astype(np.float64))
    sens = 2.0 * z * (1.0 - z) * np.abs(y_row.astype(np.float64))
    return sens > tau


def select_relaxed_ref(y_row: np.ndarray, tau: float) -> np.ndarray:
    """Relaxed relative-threshold rule (eq. 9) on one causal row."""
    y = y_row.astype(np.float64)
    m = np.max(y)
    w = np.abs(y) * np.exp(y - m)
    return w > tau * np.max(w)


def lamp_attention_ref(
    q: np.ndarray,
    k: np.ndarray,
    v: np.ndarray,
    mu: int,
    tau: float,
    mode: str = "strict",
    ref_len: int = 1024,
):
    """Reference LAMP causal attention for one head (row-by-row, float64
    softmax). Returns (out [S, hd], recompute_count)."""
    s, hd = q.shape
    scale = np.float32(1.0) / np.float32(np.sqrt(np.float32(hd)))
    out = np.zeros((s, hd), np.float32)
    count = 0
    for i in range(s):
        row = np.zeros(i + 1, np.float32)
        for j in range(i + 1):
            c = np.float32(0.0)
            for d in range(hd):
                c = np.float32(ps_round_ref(fma_f32(q[i, d], k[j, d], c), mu))
            row[j] = c * scale
        if np.isfinite(tau):
            if mode == "strict":
                sel = select_strict_ref(row, tau)
            elif mode == "relaxed":
                sel = select_relaxed_ref(row, tau)
            elif mode == "relaxed_ln":
                t = min(tau * np.sqrt(ref_len / (i + 1.0)), 1.0)
                sel = select_relaxed_ref(row, t)
            else:
                raise ValueError(mode)
            for j in np.nonzero(sel)[0]:
                row[j] = np.float32(np.dot(q[i].astype(np.float32), k[j].astype(np.float32))) * scale
                count += 1
        p = softmax_ref(row.astype(np.float64))
        out[i] = (p[:, None] * v[: i + 1].astype(np.float64)).sum(axis=0).astype(np.float32)
    return out, count
