"""Build-time synthetic training corpora.

Python port of `rust/src/data/corpus.rs` — same domain parameterization
(Zipf unigram + seeded Markov bigram + motif repetition) so the models are
trained on the same *structure* the rust harness evaluates on. The streams
use independent seeds (train vs eval splits); only the Markov *table* seed
is shared (7, the project-wide convention).
"""

from __future__ import annotations

import numpy as np

DOMAIN_PARAMS = {
    # (zipf_s, markov_lambda, repeat_prob, motif_len)
    "web": (1.05, 0.55, 0.02, 4),
    "code": (1.35, 0.70, 0.20, 6),
    "arxiv": (0.95, 0.60, 0.05, 8),
    "math": (1.25, 0.65, 0.10, 3),
    "wiki": (1.00, 0.55, 0.03, 4),
}

DOMAIN_IDS = {"web": 0, "code": 1, "arxiv": 2, "math": 3, "wiki": 4}
TABLE_SEED = 7
BRANCH = 4


class SyntheticCorpus:
    """Deterministic synthetic token stream for one domain."""

    def __init__(self, domain: str, vocab: int, table_seed: int, stream_seed: int):
        assert vocab >= 8
        s, lam, rep, motif = DOMAIN_PARAMS[domain]
        self.vocab = vocab
        self.lam = lam
        self.rep = rep
        self.motif = motif
        # Zipf CDF.
        w = 1.0 / np.arange(1, vocab + 1, dtype=np.float64) ** s
        self.cdf = np.cumsum(w / w.sum())
        # Markov successor table seeded per (table_seed, domain).
        trng = np.random.default_rng(table_seed ^ (DOMAIN_IDS[domain] * 0x9E3779B9))
        self.successors = np.stack(
            [self._zipf_sample_rng(trng, BRANCH) for _ in range(vocab)]
        )
        self.rng = np.random.default_rng(stream_seed)
        self.history: list[int] = []

    def _zipf_sample_rng(self, rng, n):
        u = rng.random(n)
        return np.searchsorted(self.cdf, u).clip(0, self.vocab - 1)

    def _zipf_sample(self):
        return int(np.searchsorted(self.cdf, self.rng.random()).clip(0, self.vocab - 1))

    def next_token(self) -> int:
        h = self.history
        if len(h) > 2 * self.motif and self.rng.random() < self.rep:
            start = len(h) - self.motif
            tok = h[start + len(h) % self.motif]
            h.append(tok)
            return tok
        if h and self.rng.random() < self.lam:
            succ = self.successors[h[-1]]
            idx = 0
            while idx + 1 < len(succ) and self.rng.random() < 0.4:
                idx += 1
            tok = int(succ[idx])
        else:
            tok = self._zipf_sample()
        h.append(tok)
        if len(h) > 64:
            del h[:32]
        return tok

    def sequence(self, n: int) -> np.ndarray:
        return np.array([self.next_token() for _ in range(n)], np.int32)

    def batch(self, count: int, n: int) -> np.ndarray:
        return np.stack([self.sequence(n) for _ in range(count)])


def mixed_training_batch(vocab: int, count: int, seq: int, step: int) -> np.ndarray:
    """Round-robin over domains so every evaluation domain is
    in-distribution for the trained models."""
    domains = list(DOMAIN_PARAMS)
    out = []
    for i in range(count):
        d = domains[(step * count + i) % len(domains)]
        c = SyntheticCorpus(d, vocab, TABLE_SEED, stream_seed=1_000_003 * step + 17 * i + 1)
        out.append(c.sequence(seq))
    return np.stack(out)
