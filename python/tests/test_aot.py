"""AOT lowering smoke tests: HLO text is produced and loadable structure
is present. (The full rust-side load/execute parity is covered by the
cargo integration tests against real artifacts.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_kernels, lower_model, to_hlo_text
from compile.model import CONFIGS


@pytest.mark.slow
def test_lower_nano_model():
    hlo = lower_model(CONFIGS["nano"])
    assert "HloModule" in hlo
    # tokens + 4 scalars + 28 weights = 33 parameters
    assert hlo.count("parameter(") >= 33
    assert len(hlo) > 10_000


def test_lower_kernels_smoke():
    out = lower_kernels()
    assert "HloModule" in out["kernel_ps_matmul"]
    assert "HloModule" in out["kernel_lamp_attention"]


def test_to_hlo_text_simple_fn():
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(spec, spec)
    hlo = to_hlo_text(lowered)
    assert "HloModule" in hlo
    assert "dot(" in hlo or "dot." in hlo


def test_hlo_ids_fit_32bit():
    """The whole reason we ship text: ensure our text path exists and the
    module parses from text (smoke-level: no 'id=' overflow markers)."""
    hlo = lower_kernels()["kernel_ps_matmul"]
    # HLO text has no explicit ids; presence of ROOT and ENTRY suffices.
    assert "ENTRY" in hlo and "ROOT" in hlo
