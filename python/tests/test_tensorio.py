"""tensorio container format round-trips (python side; the rust side pins
the same bytes in rust/src/tensorio/)."""

import numpy as np
import pytest

from compile import tensorio


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.lamp")
    w = np.arange(6, dtype=np.float32).reshape(2, 3)
    toks = np.array([1, 2, 3], np.int32)
    tensorio.write_tensors(path, [("w", w), ("toks", toks)])
    back = tensorio.read_tensors(path)
    assert list(back) == ["w", "toks"]
    np.testing.assert_array_equal(back["w"], w)
    np.testing.assert_array_equal(back["toks"], toks)
    assert back["w"].dtype == np.float32
    assert back["toks"].dtype == np.int32


def test_header_bytes(tmp_path):
    path = str(tmp_path / "t.lamp")
    tensorio.write_tensors(path, [("x", np.zeros(1, np.float32))])
    data = open(path, "rb").read()
    assert data[:8] == b"LAMPTNSR"
    assert int.from_bytes(data[8:12], "little") == 1  # version
    assert int.from_bytes(data[12:16], "little") == 1  # count


def test_duplicate_names_rejected(tmp_path):
    path = str(tmp_path / "t.lamp")
    with pytest.raises(ValueError):
        tensorio.write_tensors(
            path, [("x", np.zeros(1, np.float32)), ("x", np.ones(1, np.float32))]
        )


def test_bad_magic_rejected(tmp_path):
    path = str(tmp_path / "bad.lamp")
    open(path, "wb").write(b"NOTLAMP!" + b"\x00" * 16)
    with pytest.raises(ValueError):
        tensorio.read_tensors(path)


def test_float64_downcast(tmp_path):
    path = str(tmp_path / "t.lamp")
    w = np.array([1.5, 2.5], np.float64)
    tensorio.write_tensors(path, [("w", w)])
    back = tensorio.read_tensors(path)
    assert back["w"].dtype == np.float32
    np.testing.assert_array_equal(back["w"], w.astype(np.float32))


def test_v2_bf16_and_ps_roundtrip(tmp_path):
    path = str(tmp_path / "t.lamp")
    rng = np.random.default_rng(7)
    w = rng.normal(size=(3, 4)).astype(np.float32)
    tensorio.write_tensors(
        path,
        [("wb", w), ("wp", w), ("bias", w[0])],
        formats={"wb": "bf16", "wp": "ps6"},
    )
    data = open(path, "rb").read()
    assert int.from_bytes(data[8:12], "little") == 2  # v2 once quantized
    back = tensorio.read_tensors(path)
    # bf16: exact dequant of the RNE-narrowed values.
    np.testing.assert_array_equal(
        back["wb"], tensorio.bf16_to_f32(tensorio.f32_to_bf16(w)).reshape(3, 4)
    )
    # ps: payload is mu-rounded, dequant is the identity.
    np.testing.assert_array_equal(back["wp"], tensorio.round_to_mantissa(w, 6))
    np.testing.assert_array_equal(back["bias"], w[0])
    # Quantization is idempotent (the dequant-is-exact contract).
    np.testing.assert_array_equal(
        tensorio.round_to_mantissa(back["wp"], 6), back["wp"]
    )
    np.testing.assert_array_equal(
        tensorio.bf16_to_f32(tensorio.f32_to_bf16(back["wb"])).reshape(3, 4),
        back["wb"],
    )


def test_f32_only_files_stay_v1(tmp_path):
    # Files with no quantized tensor keep the legacy version so old
    # readers still load them.
    path = str(tmp_path / "t.lamp")
    tensorio.write_tensors(path, [("x", np.zeros(4, np.float32))])
    data = open(path, "rb").read()
    assert int.from_bytes(data[8:12], "little") == 1


def test_unknown_format_rejected(tmp_path):
    path = str(tmp_path / "t.lamp")
    with pytest.raises(ValueError):
        tensorio.write_tensors(
            path, [("x", np.zeros(1, np.float32))], formats={"x": "fp8"}
        )
    with pytest.raises(ValueError):
        tensorio.write_tensors(
            path, [("x", np.zeros(1, np.float32))], formats={"x": "ps24"}
        )
