"""L2 model shape/behaviour tests (nano config; fast)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    forward,
    forward_flat,
    forward_train,
    init_params,
    loss_fn,
    unflatten_params,
    weight_order,
)

CFG = CONFIGS["nano"]


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(1)
    return jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq)), jnp.int32)


def run_fwd(params, tokens, mu, tau, seed=0, mode=0):
    return forward(CFG, params, tokens, mu, jnp.float32(tau), seed, mode)


def test_shapes_and_counts(params, tokens):
    logits, cnt, total = run_fwd(params, tokens, 4, 0.1)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert float(total) == CFG.batch * CFG.causal_products(CFG.seq)
    assert float(cnt) >= 0


def test_reference_mu23_recomputes_nothing(params, tokens):
    _, cnt, _ = run_fwd(params, tokens, 23, 0.0001)
    # mu=23 scores are exact; strict sensitivities can still exceed tiny tau,
    # so use tau=inf for the reference definition instead:
    _, cnt_inf, _ = run_fwd(params, tokens, 23, np.inf)
    assert float(cnt_inf) == 0.0


def test_low_precision_perturbs_lamp_recovers(params, tokens):
    ref, _, _ = run_fwd(params, tokens, 23, np.inf)
    uni, cnt_u, _ = run_fwd(params, tokens, 2, np.inf)
    lamp, cnt_l, _ = run_fwd(params, tokens, 2, 0.001)
    e_uni = float(jnp.abs(uni - ref).max())
    e_lamp = float(jnp.abs(lamp - ref).max())
    assert float(cnt_u) == 0
    assert float(cnt_l) > 0
    assert e_uni > 0
    assert e_lamp < e_uni


def test_forward_flat_matches_dict(params, tokens):
    flat = [params[n] for n, _ in weight_order(CFG)]
    a = forward_flat(CFG, tokens, 4, jnp.float32(0.05), 0, 0, *flat)
    b = run_fwd(params, tokens, 4, 0.05)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert float(a[1]) == float(b[1])


def test_unflatten_roundtrip(params):
    flat = [params[n] for n, _ in weight_order(CFG)]
    d = unflatten_params(CFG, flat)
    assert set(d) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(d[k]), np.asarray(params[k]))


def test_weight_order_shapes(params):
    for name, shape in weight_order(CFG):
        assert params[name].shape == shape, name


def test_train_forward_close_to_lamp_reference(params, tokens):
    """The training forward (plain FP32 attention) must agree with the LAMP
    forward at mu=23/tau=inf up to reduction-order noise."""
    ref, _, _ = run_fwd(params, tokens, 23, np.inf)
    tr = forward_train(CFG, params, tokens)
    np.testing.assert_allclose(np.asarray(tr), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_loss_decreases_one_step(params, tokens):
    """One SGD step on the training loss must reduce it (sanity of grads)."""
    loss0, grads = jax.value_and_grad(lambda p: loss_fn(CFG, p, tokens))(params)
    stepped = jax.tree_util.tree_map(lambda p, g: p - 0.5 * g, params, grads)
    loss1 = loss_fn(CFG, stepped, tokens)
    assert float(loss1) < float(loss0)


def test_random_mode_same_count_different_logits(params, tokens):
    l1, c1, _ = run_fwd(params, tokens, 3, 0.01, seed=1, mode=3)
    l2, c2, _ = run_fwd(params, tokens, 3, 0.01, seed=2, mode=3)
    ls, cs, _ = run_fwd(params, tokens, 3, 0.01, seed=1, mode=0)
    # Counts match strict's budget on the first selection pass.
    assert float(c1) == float(c2) == float(cs)
    if float(c1) > 0:
        assert not np.array_equal(np.asarray(l1), np.asarray(l2))
