"""L1 LAMP attention kernel vs the row-by-row numpy oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.lamp_attention import (
    MODE_RANDOM,
    MODE_RELAXED,
    MODE_RELAXED_LN,
    MODE_STRICT,
    lamp_attention_head,
)
from compile.kernels.ref import lamp_attention_ref


def qkv(s, hd, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (
        (scale * rng.standard_normal((s, hd))).astype(np.float32),
        (scale * rng.standard_normal((s, hd))).astype(np.float32),
        rng.standard_normal((s, hd)).astype(np.float32),
    )


@pytest.mark.parametrize(
    "mu,tau,mode_i,mode_s",
    [
        (4, np.inf, MODE_STRICT, "strict"),
        (23, np.inf, MODE_STRICT, "strict"),
        (4, 0.05, MODE_STRICT, "strict"),
        (2, 0.2, MODE_STRICT, "strict"),
        (3, 0.1, MODE_RELAXED, "relaxed"),
        (5, 0.3, MODE_RELAXED_LN, "relaxed_ln"),
    ],
)
def test_kernel_matches_reference(mu, tau, mode_i, mode_s):
    q, k, v = qkv(10, 8, 42)
    out, cnt = lamp_attention_head(q, k, v, mu, np.float32(tau), 0, mode_i, 1024)
    want, want_cnt = lamp_attention_ref(q, k, v, mu, tau, mode_s, 1024)
    np.testing.assert_allclose(np.asarray(out), want, rtol=3e-5, atol=3e-6)
    assert int(cnt) == want_cnt


def test_mu23_uniform_equals_exact_attention():
    q, k, v = qkv(12, 4, 7)
    out, cnt = lamp_attention_head(q, k, v, 23, np.float32(np.inf), 0, MODE_STRICT, 1024)
    want, _ = lamp_attention_ref(q, k, v, 23, np.inf, "strict", 1024)
    np.testing.assert_allclose(np.asarray(out), want, rtol=3e-5, atol=3e-6)
    assert int(cnt) == 0


def test_random_mode_count_matches_strict():
    q, k, v = qkv(16, 8, 3, scale=2.0)
    _, cnt_s = lamp_attention_head(q, k, v, 4, np.float32(0.05), 0, MODE_STRICT, 1024)
    _, cnt_r = lamp_attention_head(q, k, v, 4, np.float32(0.05), 9, MODE_RANDOM, 1024)
    assert int(cnt_s) == int(cnt_r)
    assert int(cnt_s) > 0


def test_random_mode_seed_changes_selection_not_count():
    q, k, v = qkv(16, 8, 5, scale=2.0)
    out1, c1 = lamp_attention_head(q, k, v, 3, np.float32(0.05), 1, MODE_RANDOM, 1024)
    out2, c2 = lamp_attention_head(q, k, v, 3, np.float32(0.05), 2, MODE_RANDOM, 1024)
    assert int(c1) == int(c2)
    assert not np.allclose(np.asarray(out1), np.asarray(out2))


def test_lamp_reduces_error_vs_uniform():
    q, k, v = qkv(20, 8, 11, scale=2.0)
    exact, _ = lamp_attention_ref(q, k, v, 23, np.inf, "strict", 1024)
    uni, _ = lamp_attention_head(q, k, v, 2, np.float32(np.inf), 0, MODE_STRICT, 1024)
    lamp, cnt = lamp_attention_head(q, k, v, 2, np.float32(0.01), 0, MODE_STRICT, 1024)
    e_uni = np.abs(np.asarray(uni) - exact).max()
    e_lamp = np.abs(np.asarray(lamp) - exact).max()
    assert int(cnt) > 0
    assert e_lamp < e_uni


def test_causality_row0():
    # Row 0 attends only to itself: output row 0 == v row 0.
    q, k, v = qkv(6, 4, 13)
    out, _ = lamp_attention_head(q, k, v, 4, np.float32(0.1), 0, MODE_STRICT, 1024)
    np.testing.assert_allclose(np.asarray(out)[0], v[0], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=2, max_value=10),
    st.sampled_from([2, 4, 8]),
    st.integers(min_value=1, max_value=23),
    st.sampled_from([0.02, 0.1, 0.5]),
    st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_strict_parity(s, hd, mu, tau, seed):
    q, k, v = qkv(s, hd, seed)
    out, cnt = lamp_attention_head(q, k, v, mu, np.float32(tau), 0, MODE_STRICT, 1024)
    want, want_cnt = lamp_attention_ref(q, k, v, mu, tau, "strict", 1024)
    np.testing.assert_allclose(np.asarray(out), want, rtol=5e-5, atol=5e-6)
    assert int(cnt) == want_cnt
