"""L1 kernel vs oracle: PS(mu) rounding — the core correctness signal."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ps_round import ps_matmul, ps_round
from compile.kernels.ref import ps_matmul_ref, ps_round_ref

jitted_round = jax.jit(ps_round)


def bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


@pytest.mark.parametrize("mu", [1, 2, 4, 7, 10, 16, 23])
def test_round_matches_reference_random(mu):
    rng = np.random.default_rng(mu)
    x = (rng.standard_normal(4096) * 10.0 ** rng.integers(-3, 4, 4096)).astype(np.float32)
    got = np.asarray(jitted_round(x, mu))
    want = ps_round_ref(x, mu)
    np.testing.assert_array_equal(bits(got), bits(want))


def test_mu23_is_identity():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(512).astype(np.float32)
    np.testing.assert_array_equal(bits(jitted_round(x, 23)), bits(x))


def test_ties_to_even_bf16():
    # 1 + 2^-8 is exactly halfway between BF16 neighbours -> rounds to 1.0.
    x = np.float32(1.0 + 2.0**-8)
    assert float(jitted_round(x, 7)) == 1.0
    # 1 + 3*2^-8 rounds up to even mantissa 1 + 2^-6.
    x = np.float32(1.0 + 3 * 2.0**-8)
    assert float(jitted_round(x, 7)) == 1.0 + 2.0**-6


def test_specials_pass_through():
    x = np.array([np.nan, np.inf, -np.inf, 0.0, -0.0], np.float32)
    got = np.asarray(jitted_round(x, 7))
    assert np.isnan(got[0])
    assert got[1] == np.inf and got[2] == -np.inf
    assert bits(got[3]) == bits(np.float32(0.0))
    assert bits(got[4]) == bits(np.float32(-0.0))


def test_overflow_to_infinity():
    x = np.float32(np.finfo(np.float32).max)
    assert float(jitted_round(x, 4)) == np.inf


@settings(max_examples=300, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=23),
)
def test_round_hypothesis_parity(pattern, mu):
    # Sweep raw bit patterns: covers subnormals, both signs, all binades.
    x = np.uint32(pattern).view(np.float32)
    if not np.isfinite(x):
        return
    got = np.asarray(jitted_round(x, mu))
    want = ps_round_ref(x, mu)
    assert bits(got) == bits(want), (x, mu)


@settings(max_examples=200, deadline=None)
@given(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False, width=32),
    st.integers(min_value=1, max_value=22),
)
def test_round_idempotent_and_bounded(x, mu):
    x = np.float32(x)
    r = float(jitted_round(x, mu))
    assert float(jitted_round(np.float32(r), mu)) == r
    # The relative |δ| <= u bound holds for *normal* inputs only.
    if abs(x) >= 2.0**-126 and np.isfinite(r):
        assert abs(r - x) <= abs(x) * 2.0 ** (-mu - 1) * (1 + 1e-6)


@pytest.mark.parametrize("mu", [1, 4, 7, 23])
@pytest.mark.parametrize("shape", [(3, 5, 4), (8, 8, 8), (1, 1, 1)])
def test_matmul_matches_reference(mu, shape):
    m, k, n = shape
    rng = np.random.default_rng(mu * 100 + m)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(ps_matmul(a, b, mu))
    want = ps_matmul_ref(a, b, mu)
    np.testing.assert_array_equal(bits(got), bits(want))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=23),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, mu, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(ps_matmul(a, b, mu))
    want = ps_matmul_ref(a, b, mu)
    np.testing.assert_array_equal(bits(got), bits(want))
