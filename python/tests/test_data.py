"""Synthetic corpus generator sanity (python compile-path side)."""

import numpy as np

from compile.data import DOMAIN_PARAMS, SyntheticCorpus, mixed_training_batch


def test_tokens_in_vocab():
    for d in DOMAIN_PARAMS:
        c = SyntheticCorpus(d, 64, 7, 1)
        seq = c.sequence(500)
        assert seq.min() >= 0 and seq.max() < 64


def test_deterministic():
    a = SyntheticCorpus("web", 128, 7, 5).sequence(256)
    b = SyntheticCorpus("web", 128, 7, 5).sequence(256)
    np.testing.assert_array_equal(a, b)


def test_streams_differ():
    a = SyntheticCorpus("web", 128, 7, 5).sequence(256)
    b = SyntheticCorpus("web", 128, 7, 6).sequence(256)
    assert not np.array_equal(a, b)


def test_code_more_repetitive():
    def bigram_repeat_rate(d):
        seq = SyntheticCorpus(d, 128, 7, 9).sequence(3000)
        seen, rep = set(), 0
        for a, b in zip(seq, seq[1:]):
            if (a, b) in seen:
                rep += 1
            seen.add((a, b))
        return rep / (len(seq) - 1)

    assert bigram_repeat_rate("code") > bigram_repeat_rate("arxiv")


def test_mixed_batch_shape():
    b = mixed_training_batch(128, 4, 32, step=3)
    assert b.shape == (4, 32)
    assert b.dtype == np.int32
    assert b.max() < 128
