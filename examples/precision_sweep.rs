//! Precision sweep: reproduce the shape of the paper's Figure 2 at small
//! scale in under a minute — KL divergence and recomputation rate as τ
//! tightens, for BF16-width (μ=7) and PS(4) accumulation.
//!
//! ```bash
//! cargo run --release --offline --example precision_sweep
//! ```

use lamp::benchkit::{fnum, Table};
use lamp::coordinator::{PrecisionPolicy, Rule, SitePolicy};
use lamp::data::Domain;
use lamp::experiments::common::{load_weights, EvalOptions, EvalPanel};

fn main() -> lamp::Result<()> {
    let opts = EvalOptions { num_seqs: 4, seq_len: 48, ..Default::default() };
    let weights = load_weights("small", &opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, &opts)?;

    let mut table = Table::new(
        "precision sweep (small model, web panel, strict LAMP)",
        &["mu", "tau", "KL vs FP32", "flip%", "recompute%"],
    );
    for mu in [4u32, 7] {
        let uni = panel.evaluate(&PrecisionPolicy::uniform(mu), 0)?;
        table.row(vec![
            mu.to_string(),
            "inf".into(),
            fnum(uni.kl),
            format!("{:.2}", 100.0 * uni.flip),
            "0".into(),
        ]);
        for tau in [0.5f32, 0.2, 0.1, 0.05, 0.02] {
            let r = panel.evaluate(&PrecisionPolicy::lamp(mu, tau, Rule::Strict), 0)?;
            table.row(vec![
                mu.to_string(),
                tau.to_string(),
                fnum(r.kl),
                format!("{:.2}", 100.0 * r.flip),
                format!("{:.3}", 100.0 * r.rate),
            ]);
        }
    }
    table.print();
    println!("expected shape: KL falls by orders of magnitude as tau tightens,");
    println!("with recomputation rates of only a few percent (paper Fig. 2).");

    // Whole-model plan: the same attention point with the MLP, norm, and
    // sampler sites active (per-site LAMP), vs every site uniform-low.
    let mut whole = Table::new(
        "whole-model plan (mu=4 attention, per-site LAMP elsewhere)",
        &["plan", "KL vs FP32", "flip%", "attn recompute%"],
    );
    let uniform_all = PrecisionPolicy::uniform(4)
        .with_mlp(SitePolicy::uniform(7))
        .with_norm(SitePolicy::uniform(10))
        .with_sampler(SitePolicy::uniform(7));
    let lamp_all = PrecisionPolicy::lamp(4, 0.1, Rule::Strict)
        .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))
        .with_norm(SitePolicy::lamp(10, 1.0, Rule::Strict))
        .with_sampler(SitePolicy::lamp(7, 0.05, Rule::Relaxed));
    for (name, policy) in [("uniform everywhere", uniform_all), ("LAMP everywhere", lamp_all)] {
        let r = panel.evaluate(&policy, 0)?;
        whole.row(vec![
            name.into(),
            fnum(r.kl),
            format!("{:.2}", 100.0 * r.flip),
            format!("{:.3}", 100.0 * r.rate),
        ]);
    }
    whole.print();
    println!("whole-model LAMP repairs every composition site, not just attention.");
    Ok(())
}
