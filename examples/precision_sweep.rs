//! Precision sweep: reproduce the shape of the paper's Figure 2 at small
//! scale in under a minute — KL divergence and recomputation rate as τ
//! tightens, for BF16-width (μ=7) and PS(4) accumulation.
//!
//! ```bash
//! cargo run --release --offline --example precision_sweep
//! ```

use lamp::benchkit::{fnum, Table};
use lamp::coordinator::{PrecisionPolicy, Rule};
use lamp::data::Domain;
use lamp::experiments::common::{load_weights, EvalOptions, EvalPanel};

fn main() -> lamp::Result<()> {
    let opts = EvalOptions { num_seqs: 4, seq_len: 48, ..Default::default() };
    let weights = load_weights("small", &opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, &opts)?;

    let mut table = Table::new(
        "precision sweep (small model, web panel, strict LAMP)",
        &["mu", "tau", "KL vs FP32", "flip%", "recompute%"],
    );
    for mu in [4u32, 7] {
        let uni = panel.evaluate(&PrecisionPolicy::uniform(mu), 0)?;
        table.row(vec![
            mu.to_string(),
            "inf".into(),
            fnum(uni.kl),
            format!("{:.2}", 100.0 * uni.flip),
            "0".into(),
        ]);
        for tau in [0.5f32, 0.2, 0.1, 0.05, 0.02] {
            let r = panel.evaluate(&PrecisionPolicy::lamp(mu, tau, Rule::Strict), 0)?;
            table.row(vec![
                mu.to_string(),
                tau.to_string(),
                fnum(r.kl),
                format!("{:.2}", 100.0 * r.flip),
                format!("{:.3}", 100.0 * r.rate),
            ]);
        }
    }
    table.print();
    println!("expected shape: KL falls by orders of magnitude as tau tightens,");
    println!("with recomputation rates of only a few percent (paper Fig. 2).");
    Ok(())
}
