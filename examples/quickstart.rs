//! Quickstart: load a compiled LAMP artifact, run one mixed-precision
//! forward pass, and inspect what LAMP recomputed.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use lamp::coordinator::{Engine, PjrtEngine, PrecisionPolicy, Rule};
use lamp::data::{Dataset, Domain};
use lamp::runtime::ArtifactStore;

fn main() -> lamp::Result<()> {
    // 1. Open the artifact store produced by `make artifacts`.
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    println!("available models: {:?}", store.available_models());

    // 2. Load the compiled HLO + trained weights for the nano model.
    //    Python is NOT involved here — the artifact is self-contained.
    let engine = PjrtEngine::load(&store, "nano")?;
    let cfg = engine.config().clone();
    println!(
        "loaded {} ({} layers, {} heads, d={}, {} params)",
        cfg.name,
        cfg.layers,
        cfg.heads,
        cfg.d_model,
        cfg.param_count()
    );

    // 3. Generate a small synthetic workload.
    let data = Dataset::generate(Domain::Web, cfg.vocab, cfg.batch, cfg.seq, 7, 1);

    // 4. Run the same batch at three precision points.
    let reference = engine.infer(&data.sequences, &PrecisionPolicy::reference(), 0)?;
    let uniform = engine.infer(&data.sequences, &PrecisionPolicy::uniform(4), 0)?;
    let lamp = engine.infer(
        &data.sequences,
        &PrecisionPolicy::lamp(4, 0.1, Rule::Strict),
        0,
    )?;

    // 5. Compare: LAMP recovers most of the accuracy for ~1 recomputed
    //    product in a hundred.
    let kl = |a: &lamp::linalg::Matrix, b: &lamp::linalg::Matrix| {
        lamp::metrics::mean_kl_from_logits(a, b)
    };
    let kl_uniform: f64 = reference
        .logits
        .iter()
        .zip(&uniform.logits)
        .map(|(r, t)| kl(r, t))
        .sum::<f64>()
        / cfg.batch as f64;
    let kl_lamp: f64 = reference
        .logits
        .iter()
        .zip(&lamp.logits)
        .map(|(r, t)| kl(r, t))
        .sum::<f64>()
        / cfg.batch as f64;

    println!("\nKQ accumulation in PS(4) (4 mantissa bits):");
    println!("  uniform PS(4):      KL vs FP32 = {kl_uniform:.3e}   (0 recomputed)");
    println!(
        "  LAMP strict tau=0.1: KL vs FP32 = {kl_lamp:.3e}   ({} / {} = {:.2}% recomputed)",
        lamp.stats.recomputed,
        lamp.stats.causal_total,
        100.0 * lamp.stats.rate()
    );
    println!(
        "\nLAMP improvement: {:.1}x lower KL divergence",
        kl_uniform / kl_lamp.max(1e-300)
    );
    Ok(())
}
