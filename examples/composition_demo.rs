//! Algorithm 1 on a generic composition: LAMP-evaluate f(g(x)) where
//! g(x) = A·x is accumulated in PS(3) and f is softmax — the paper's §2
//! machinery outside the transformer, including the RMS-norm and
//! activation closed forms of §3.
//!
//! ```bash
//! cargo run --release --offline --example composition_demo
//! ```

use lamp::lamp::activation::{select_activation, Activation};
use lamp::lamp::composition::{lamp_evaluate, Objective};
use lamp::lamp::condition::VectorFn;
use lamp::lamp::rmsnorm::{kappa_c_rmsnorm, select_rmsnorm};
use lamp::lamp::softmax::softmax;
use lamp::linalg::Matrix;
use lamp::softfloat::dot::{dot_f32, dot_ps};
use lamp::util::Rng;

fn main() -> lamp::Result<()> {
    let mut rng = Rng::new(7);
    let (n, k) = (24usize, 96usize);
    let a = Matrix::randn(n, k, 0.5, &mut rng);
    let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();

    // --- §2.3 Algorithm 1: matvec -> softmax composition. ---
    let f = VectorFn::new(|y| softmax(y));
    let a1 = a.clone();
    let a2 = a.clone();
    let result = lamp_evaluate(
        &x,
        move |xv| (0..n).map(|i| dot_ps(a1.row(i), xv, 3)).collect(),
        move |xv, j| dot_f32(a2.row(j), xv),
        &f,
        0.05,
        Objective::NormwiseL1,
    )?;

    let y_exact: Vec<f32> = (0..n).map(|i| dot_f32(a.row(i), &x)).collect();
    let z_exact = softmax(&y_exact);
    let y_low: Vec<f32> = (0..n).map(|i| dot_ps(a.row(i), &x, 3)).collect();
    let z_low = softmax(&y_low);
    let l1 = |p: &[f32], q: &[f32]| -> f64 {
        p.iter().zip(q).map(|(&a, &b)| (a - b).abs() as f64).sum()
    };

    println!("Algorithm 1 on softmax(A.x), A in R^{n}x{k}, PS(3) accumulation:");
    println!("  kappa_1 after selection : {:.4} (tau = 0.05)", result.kappa);
    println!("  recomputed components   : {}/{n}", result.recomputed);
    println!("  L1 error, uniform PS(3) : {:.3e}", l1(&z_low, &z_exact));
    println!("  L1 error, LAMP          : {:.3e}", l1(&result.z, &z_exact));

    // --- §3.2 RMS-norm closed form (Prop 3.1/3.2). ---
    let y: Vec<f32> = (0..32).map(|_| rng.normal_f32() * 2.0).collect();
    let mask = select_rmsnorm(&y, 0.5);
    println!("\nRMS-norm greedy solution (Prop 3.2), tau=0.5:");
    println!(
        "  selected {}/{} components, kappa_c = {:.4}",
        mask.iter().filter(|&&b| b).count(),
        y.len(),
        kappa_c_rmsnorm(&y, &mask)
    );

    // --- §3.1 activation closed form. ---
    let acts: Vec<f32> = (0..16).map(|i| -4.0 + 0.5 * i as f32).collect();
    let sel = select_activation(&acts, Activation::Gelu, 1.5);
    println!("\nGELU componentwise LAMP (tau=1.5) over y in [-4, 3.5]:");
    for (yi, s) in acts.iter().zip(&sel) {
        if *s {
            println!(
                "  y = {yi:+.1} flagged (sensitivity {:.2})",
                Activation::Gelu.sensitivity(*yi)
            );
        }
    }
    println!("(the deep negative GELU tail is relative-error-sensitive — §3.1)");
    Ok(())
}
