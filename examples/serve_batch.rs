//! End-to-end serving driver (the DESIGN.md e2e validation): load the
//! trained small model through PJRT and serve a batched synthetic
//! workload with mixed precision tiers, reporting latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example serve_batch
//! ```
//!
//! Environment: LAMP_SERVE_MODEL (default "small"), LAMP_SERVE_N (default 24).

use lamp::coordinator::{Engine, InferenceRequest, PjrtEngine, PrecisionPolicy, Server};
use lamp::data::{Dataset, Domain};
use lamp::runtime::ArtifactStore;
use std::time::Duration;

fn main() -> lamp::Result<()> {
    let model = std::env::var("LAMP_SERVE_MODEL").unwrap_or_else(|_| "small".into());
    let n: usize = std::env::var("LAMP_SERVE_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);

    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    let engine = PjrtEngine::load(&store, &model)?;
    let cfg = engine.config().clone();
    println!(
        "serving {n} requests on {} via PJRT (batch={}, seq={})",
        cfg.name, cfg.batch, cfg.seq
    );

    // A mixed workload: most requests balanced, some exact, some economy —
    // the precision-policy router keeps incompatible tiers in separate
    // batches automatically.
    let tiers = ["balanced", "balanced", "exact", "economy"];
    let data = Dataset::generate(Domain::Web, cfg.vocab, n, cfg.seq, 7, 11);

    let mut server = Server::new(Box::new(engine), Duration::from_millis(5));
    let mut responses = Vec::new();
    for (i, seq) in data.sequences.into_iter().enumerate() {
        let tier = tiers[i % tiers.len()];
        let policy = PrecisionPolicy::tier(tier)?;
        // Vary request lengths to exercise padding.
        let len = cfg.seq / 2 + (i * 13) % (cfg.seq / 2);
        server.submit(InferenceRequest::new(i as u64, seq[..len].to_vec(), policy))?;
        responses.extend(server.step(false)?);
    }
    responses.extend(server.drain()?);
    assert_eq!(responses.len(), n);

    let stats = server.stats();
    println!("\n== serving summary ==");
    println!("requests          : {}", stats.requests);
    println!(
        "batches           : {} ({} padding rows)",
        stats.batches, stats.padding_rows
    );
    println!("tokens processed  : {}", stats.total_tokens);
    println!(
        "recompute rate    : {:.4}% of causal KQ products",
        100.0 * stats.recomputed as f64 / stats.causal_total.max(1) as f64
    );
    println!("mean latency      : {:.1} ms", 1e3 * stats.latency_mean_s);
    println!("p95 latency       : {:.1} ms", 1e3 * stats.latency_p95_s);
    println!("throughput        : {:.1} tok/s", stats.throughput_tok_s);
    println!("wall time         : {:.2} s", stats.wall_s);

    // Echo a sample prediction to show real logits flow end to end.
    let r = &responses[0];
    let row = r.logits.row(r.logits.rows() - 1);
    let argmax = lamp::metrics::flip::argmax(row);
    println!(
        "\nrequest {} next-token argmax: {argmax} (logit {:.3})",
        r.id, row[argmax]
    );
    Ok(())
}
