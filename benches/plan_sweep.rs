//! Whole-model plan sweep — the PR-3 measurement.
//!
//! Three genuinely distinct decode workloads on the same 4-layer native
//! engine:
//!
//! * **reference** — every composition site at FP32 reference (the plan
//!   short-circuits to the pre-plan fast kernels: this is the refactored
//!   hot path whose tokens/sec is the cross-PR regression signal —
//!   compare against `BENCH_PR1.json`'s decode section);
//! * **attention-only** — the pre-plan serving point (`lamp(4, 0.02)` at
//!   the attention site, every other site reference);
//! * **whole-model** — every composition site active; per-site recompute
//!   rates are asserted non-zero and recorded, plus a τ sweep of the MLP
//!   site showing the rate knob.
//!
//! Results land in `BENCH_PR3.json` (override with `LAMP_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench plan_sweep            # full measurement (S=160)
//! cargo bench --bench plan_sweep -- --smoke # CI scale: S=64, 1 sample
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{Engine, NativeEngine, PrecisionPolicy, Rule, SitePolicy};
use lamp::model::{generate_with_stats, Decode, ModelConfig, Weights};
use lamp::util::Rng;
use std::time::Duration;

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR3.json"))
}

/// Decode `new_tokens` greedily through the shared decode loop and return
/// (tokens, per-site rates).
fn drive(
    engine: &NativeEngine,
    policy: &PrecisionPolicy,
    prompt: &[u32],
    new_tokens: usize,
    seed: u64,
) -> (Vec<u32>, Vec<(String, f64)>) {
    let (tokens, stats) = generate_with_stats(
        engine.weights(),
        prompt,
        new_tokens,
        engine.decode_precision(policy),
        Decode::Greedy,
        seed,
    )
    .expect("generate");
    (tokens, stats.site_rates())
}

fn main() {
    // `--smoke` (CI): shorter context, one timed sample — the plan-activity
    // assertions and the recorded rate metrics still run at full strength.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-plan".into(),
        vocab: 256,
        seq: if smoke { 64 } else { 160 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(31);
    let weights = Weights::random(&cfg, &mut rng).unwrap();
    let engine = NativeEngine::new(weights);
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len() - 1;

    let reference = PrecisionPolicy::reference();
    let attention_only = PrecisionPolicy::lamp(4, 0.02, Rule::Strict);
    let whole = PrecisionPolicy::lamp(4, 0.02, Rule::Strict)
        .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))
        .with_norm(SitePolicy::lamp(10, 1.0, Rule::Strict))
        .with_sampler(SitePolicy::lamp(7, 0.05, Rule::Relaxed));

    // Sanity before timing: the reference plan recomputes nothing anywhere;
    // the whole-model plan is active at every composition site.
    let (_, ref_rates) = drive(&engine, &reference, &prompt, new_tokens, 3);
    assert!(
        ref_rates.iter().all(|(_, r)| *r == 0.0),
        "reference plan must not recompute: {ref_rates:?}"
    );
    let (_, whole_rates) = drive(&engine, &whole, &prompt, new_tokens, 3);
    assert!(
        whole_rates.iter().all(|(_, r)| *r > 0.0),
        "whole-model plan left a site inactive: {whole_rates:?}"
    );

    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 5 },
        max_total: Duration::from_secs(90),
    };
    let mut tok_s = Vec::new();
    for (name, policy) in [
        ("reference plan", &reference),
        ("attention-only plan", &attention_only),
        ("whole-model plan", &whole),
    ] {
        let stats = b.run(&format!("decode {name} (4l, S={})", cfg.seq), || {
            drive(&engine, policy, &prompt, new_tokens, 3)
        });
        println!("{}", stats.summary());
        tok_s.push(new_tokens as f64 / stats.median().as_secs_f64().max(1e-12));
    }
    let (ref_tok_s, attn_tok_s, whole_tok_s) = (tok_s[0], tok_s[1], tok_s[2]);
    println!(
        "decode throughput: reference {ref_tok_s:.1} tok/s, \
         attention-only {attn_tok_s:.1} tok/s, whole-model {whole_tok_s:.1} tok/s"
    );
    println!(
        "(cross-PR regression guard: compare the reference/attention-only \
         numbers against BENCH_PR1.json's decode section — the plan refactor \
         must keep the short-circuited hot path within 10%)"
    );

    let mut obj = JsonObj::new()
        .str("model", &format!("4 layers, 4 heads, d=128, vocab=256, S={}", cfg.seq))
        .str("attention_policy", &attention_only.label())
        .str("whole_policy", &whole.label())
        .int("generated_tokens", new_tokens as u64)
        .int("smoke", smoke as u64)
        .num("reference_tok_s", ref_tok_s)
        .num("attention_only_tok_s", attn_tok_s)
        .num("whole_model_tok_s", whole_tok_s);
    for (site, rate) in &whole_rates {
        obj = obj.num(&format!("whole_rate_{site}"), *rate);
        println!("whole-model recompute rate [{site}]: {:.4}%", 100.0 * rate);
    }
    // MLP-site τ sweep: the site's recompute-rate knob.
    for tau in [1.5f32, 0.8, 0.5, 0.2] {
        let policy = PrecisionPolicy::reference()
            .with_mlp(SitePolicy::lamp(7, tau, Rule::Strict));
        let (_, rates) = drive(&engine, &policy, &prompt, new_tokens, 3);
        let mlp_rate = rates
            .iter()
            .find(|(s, _)| s == "mlp")
            .map(|(_, r)| *r)
            .unwrap_or(0.0);
        obj = obj.num(&format!("mlp_rate_tau_{tau}"), mlp_rate);
        println!("mlp site rate at tau={tau}: {:.4}%", 100.0 * mlp_rate);
    }

    let path = bench_out();
    record_bench_section(&path, "plan_sweep", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());
}
