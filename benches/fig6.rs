//! Regenerates paper Figure 6 — see rust/src/experiments/fig6.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig6");
}
