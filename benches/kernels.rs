//! Micro-benchmarks of the numeric hot paths — the §Perf L1/L2 evidence:
//! PS(μ) rounding, PS-accumulated dots/matmuls vs FP32, the LAMP selection
//! rules, and the PR-8 headline: SIMD vs scalar-replay GFLOP/s on the
//! attention-score dot path (`score_row_ps`), the pinned reference dot
//! chain (`dot_block`), the blocked matmul, and decode tok/s under both
//! dispatch modes. The two modes are asserted bitwise identical before any
//! number is recorded — the speedup is never bought with different math.
//!
//! Results go into `BENCH_PR8.json` (override with `LAMP_BENCH_OUT`) under
//! the `kernels` section. `--smoke` (the CI bench-smoke job) runs one
//! sample on a short decode so the record producer is exercised on every
//! push; smoke numbers are not comparable across runs.
//!
//! ```bash
//! cargo bench --bench kernels            # full measurement
//! cargo bench --bench kernels -- --smoke # CI record-producer check
//! ```

use lamp::benchkit::{record_bench_section, BenchStats, Bencher, JsonObj, Table};
use lamp::lamp::softmax::{select_relaxed, select_strict, SoftmaxRule};
use lamp::linalg::matmul::matmul_bias_fast;
use lamp::linalg::simd::{dot_block, set_simd_enabled, simd_backend};
use lamp::linalg::{matmul_f32, matmul_ps, Matrix};
use lamp::model::{generate, AttentionPrecision, Decode, ModelConfig, Weights};
use lamp::softfloat::dot::{dot_f32, dot_kahan, dot_ps, dot_ps_stochastic, score_row_ps};
use lamp::softfloat::round::round_to_mantissa;
use lamp::util::Rng;
use std::path::PathBuf;
use std::time::Duration;

fn bench_out() -> PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_PR8.json"))
}

fn gflops(flops: f64, stats: &BenchStats) -> f64 {
    flops / stats.median().as_secs_f64().max(1e-12) / 1e9
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 3 },
        sample_iters: if smoke { 1 } else { 15 },
        max_total: Duration::from_secs(60),
    };
    let mut rng = Rng::new(1);
    let mut results = Vec::new();

    // --- L1 analogue: rounding + accumulation primitives. ---
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 100.0).collect();
    results.push(b.run("round_to_mantissa x4096 (mu=7)", || {
        xs.iter().map(|&x| round_to_mantissa(x, 7)).sum::<f32>()
    }));

    let a: Vec<f32> = (0..1024).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..1024).map(|_| rng.normal_f32()).collect();
    let dot_k = a.len();
    results.push(b.run("dot_f32 k=1024 (sequential fma)", || dot_f32(&a, &v)));
    results.push(b.run("dot_ps k=1024 (mu=4)", || dot_ps(&a, &v, 4)));
    results.push(b.run("dot_kahan k=1024", || dot_kahan(&a, &v)));
    let mut srng = Rng::new(2);
    results.push(b.run("dot_ps_stochastic k=1024 (mu=4)", || {
        dot_ps_stochastic(&a, &v, 4, &mut srng)
    }));

    // --- Selection rules over a softmax row. ---
    let row: Vec<f32> = (0..512).map(|_| rng.normal_f32() * 4.0).collect();
    results.push(b.run("select_strict n=512", || select_strict(&row, 0.1)));
    results.push(b.run("select_relaxed n=512", || select_relaxed(&row, 0.1)));

    // ----------------------------------------------------------------------
    // PR-8 headline: SIMD vs scalar-replay on the same pinned chain.
    // Parity is asserted first; only bitwise-identical paths get timed.
    // ----------------------------------------------------------------------
    let simd_available = set_simd_enabled(true);
    println!("simd backend: {} (LAMP_SIMD honored at first use)", simd_backend());

    // Pinned reference dot chain, k=1024.
    let simd_dot = {
        set_simd_enabled(true);
        dot_block(&a, &v)
    };
    let scalar_dot = {
        set_simd_enabled(false);
        dot_block(&a, &v)
    };
    assert_eq!(
        simd_dot.to_bits(),
        scalar_dot.to_bits(),
        "dot_block SIMD diverged from scalar replay"
    );
    let dot_flops = (2 * dot_k) as f64;
    set_simd_enabled(true);
    let dot_simd = b.run("dot_block k=1024 (simd)", || dot_block(&a, &v));
    set_simd_enabled(false);
    let dot_scalar = b.run("dot_block k=1024 (scalar replay)", || dot_block(&a, &v));
    let dot_gflops_simd = gflops(dot_flops, &dot_simd);
    let dot_gflops_scalar = gflops(dot_flops, &dot_scalar);
    results.push(dot_simd);
    results.push(dot_scalar);

    // Attention-score dot path: one full causal row at max length,
    // PS(4) accumulation — the acceptance-criterion kernel.
    let (hd, d, srow) = (32usize, 128usize, 256usize);
    let qh: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
    let keys: Vec<f32> = (0..srow * d).map(|_| rng.normal_f32()).collect();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out_simd = vec![0.0f32; srow];
    let mut out_scalar = vec![0.0f32; srow];
    set_simd_enabled(true);
    score_row_ps(&qh, &keys, d, srow, 4, scale, &mut out_simd);
    set_simd_enabled(false);
    score_row_ps(&qh, &keys, d, srow, 4, scale, &mut out_scalar);
    for (j, (s, r)) in out_simd.iter().zip(&out_scalar).enumerate() {
        assert_eq!(
            s.to_bits(),
            r.to_bits(),
            "score_row_ps SIMD diverged from scalar replay at column {j}"
        );
    }
    let score_flops = (2 * hd * srow) as f64;
    set_simd_enabled(true);
    let mut out = vec![0.0f32; srow];
    let score_simd = b.run("score_row_ps n=256 hd=32 mu=4 (simd)", || {
        score_row_ps(&qh, &keys, d, srow, 4, scale, &mut out);
        out[srow - 1]
    });
    set_simd_enabled(false);
    let score_scalar = b.run("score_row_ps n=256 hd=32 mu=4 (scalar replay)", || {
        score_row_ps(&qh, &keys, d, srow, 4, scale, &mut out);
        out[srow - 1]
    });
    let score_gflops_simd = gflops(score_flops, &score_simd);
    let score_gflops_scalar = gflops(score_flops, &score_scalar);
    let score_speedup = score_gflops_simd / score_gflops_scalar.max(1e-12);
    results.push(score_simd);
    results.push(score_scalar);

    // Blocked matmul (the 4-row register-blocked body), 64x64x64.
    let ma = Matrix::randn(64, 64, 1.0, &mut rng);
    let mb = Matrix::randn(64, 64, 1.0, &mut rng);
    results.push(b.run("matmul_f32 64x64x64 (legacy simple)", || {
        matmul_f32(&ma, &mb).unwrap()
    }));
    results.push(b.run("matmul_ps 64x64x64 (mu=4)", || matmul_ps(&ma, &mb, 4).unwrap()));
    let mm_flops = (2 * 64 * 64 * 64) as f64;
    set_simd_enabled(true);
    let mm_simd_out = matmul_bias_fast(&ma, &mb, &[]).unwrap();
    set_simd_enabled(false);
    let mm_scalar_out = matmul_bias_fast(&ma, &mb, &[]).unwrap();
    assert_eq!(
        mm_simd_out.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        mm_scalar_out.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "matmul_bias_fast SIMD diverged from scalar replay"
    );
    set_simd_enabled(true);
    let mm_simd = b.run("matmul_bias_fast 64x64x64 (simd)", || {
        matmul_bias_fast(&ma, &mb, &[]).unwrap()
    });
    set_simd_enabled(false);
    let mm_scalar = b.run("matmul_bias_fast 64x64x64 (scalar replay)", || {
        matmul_bias_fast(&ma, &mb, &[]).unwrap()
    });
    let mm_gflops_simd = gflops(mm_flops, &mm_simd);
    let mm_gflops_scalar = gflops(mm_flops, &mm_scalar);
    results.push(mm_simd);
    results.push(mm_scalar);

    // --- Decode tok/s (the BENCH_PR1-lineage number), both modes. ---
    let cfg = ModelConfig {
        name: "bench-4l".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 256 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut wrng = Rng::new(17);
    let weights = Weights::random(&cfg, &mut wrng).unwrap();
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len();
    let prec = AttentionPrecision::lamp(4, 0.05, SoftmaxRule::Strict);
    let b_dec = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 5 },
        max_total: Duration::from_secs(120),
    };
    set_simd_enabled(true);
    let (tok_simd, _) = generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap();
    set_simd_enabled(false);
    let (tok_scalar, _) = generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap();
    assert_eq!(tok_simd, tok_scalar, "decode token stream diverged across dispatch modes");
    set_simd_enabled(true);
    let dec_simd = b_dec.run("generate kv-cache 4l (simd)", || {
        generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap()
    });
    set_simd_enabled(false);
    let dec_scalar = b_dec.run("generate kv-cache 4l (scalar replay)", || {
        generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap()
    });
    let tok_s_simd = new_tokens as f64 / dec_simd.median().as_secs_f64().max(1e-12);
    let tok_s_scalar = new_tokens as f64 / dec_scalar.median().as_secs_f64().max(1e-12);
    results.push(dec_simd);
    results.push(dec_scalar);

    // Leave the process in the default mode for anything run after us.
    set_simd_enabled(true);

    let mut t = Table::new("kernel micro-benchmarks", &["benchmark"]);
    for r in &results {
        t.row(vec![r.summary()]);
    }
    t.print();

    println!(
        "dot_block k=1024:      simd {dot_gflops_simd:.3} GFLOP/s, scalar {dot_gflops_scalar:.3} GFLOP/s"
    );
    println!(
        "score_row_ps n=256:    simd {score_gflops_simd:.3} GFLOP/s, scalar {score_gflops_scalar:.3} GFLOP/s ({score_speedup:.2}x)"
    );
    println!(
        "matmul 64x64x64:       simd {mm_gflops_simd:.3} GFLOP/s, scalar {mm_gflops_scalar:.3} GFLOP/s"
    );
    println!(
        "decode bench-4l:       simd {tok_s_simd:.1} tok/s, scalar {tok_s_scalar:.1} tok/s"
    );
    if simd_available && !smoke && score_speedup < 2.0 {
        println!(
            "WARNING: attention-score speedup {score_speedup:.2}x below the 2x acceptance target"
        );
    }

    let path = bench_out();
    record_bench_section(
        &path,
        "kernels",
        &JsonObj::new()
            .str("kernel", "score_row_ps (PS(4), n=256, hd=32)")
            .str("model", "bench-4l (4 layers, 4 heads, d=128, vocab=256)")
            .str("backend", simd_backend())
            .int("score_n", srow as u64)
            .int("score_hd", hd as u64)
            .int("dot_k", dot_k as u64)
            .int("decode_new_tokens", new_tokens as u64)
            .num("attention_gflops_simd", score_gflops_simd)
            .num("attention_gflops_scalar", score_gflops_scalar)
            .num("attention_simd_speedup", score_speedup)
            .num("dot_block_gflops_simd", dot_gflops_simd)
            .num("dot_block_gflops_scalar", dot_gflops_scalar)
            .num("matmul_gflops_simd", mm_gflops_simd)
            .num("matmul_gflops_scalar", mm_gflops_scalar)
            .num("decode_tok_s_simd", tok_s_simd)
            .num("decode_tok_s_scalar", tok_s_scalar)
            .int("smoke", smoke as u64),
    )
    .expect("write bench record");
    println!("recorded kernel GFLOP/s + decode tok/s -> {}", path.display());
}
