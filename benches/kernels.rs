//! Micro-benchmarks of the numeric hot paths — the §Perf L1/L2 evidence:
//! PS(μ) rounding, PS-accumulated dots/matmuls vs FP32, the LAMP selection
//! rules, one native forward pass, and one PJRT artifact execution.
//! Includes the accumulation-mode ablation (RNE vs stochastic vs Kahan).

use lamp::benchkit::{bench_record_path, record_bench_section, Bencher, JsonObj, Table};
use lamp::coordinator::{Engine, NativeEngine, PjrtEngine, PrecisionPolicy, Rule};
use lamp::data::{Dataset, Domain};
use lamp::lamp::softmax::{select_relaxed, select_strict};
use lamp::linalg::{matmul_f32, matmul_ps, Matrix};
use lamp::model::{ModelConfig, Weights};
use lamp::runtime::ArtifactStore;
use lamp::softfloat::dot::{dot_f32, dot_kahan, dot_ps, dot_ps_stochastic, score_row_ps};
use lamp::softfloat::round::round_to_mantissa;
use lamp::util::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(1);
    let mut results = Vec::new();

    // --- L1 analogue: rounding + accumulation primitives. ---
    let xs: Vec<f32> = (0..4096).map(|_| rng.normal_f32() * 100.0).collect();
    results.push(b.run("round_to_mantissa x4096 (mu=7)", || {
        xs.iter().map(|&x| round_to_mantissa(x, 7)).sum::<f32>()
    }));

    let a: Vec<f32> = (0..1024).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..1024).map(|_| rng.normal_f32()).collect();
    results.push(b.run("dot_f32 k=1024", || dot_f32(&a, &v)));
    results.push(b.run("dot_ps k=1024 (mu=4)", || dot_ps(&a, &v, 4)));
    results.push(b.run("dot_kahan k=1024", || dot_kahan(&a, &v)));
    let mut srng = Rng::new(2);
    results.push(b.run("dot_ps_stochastic k=1024 (mu=4)", || {
        dot_ps_stochastic(&a, &v, 4, &mut srng)
    }));

    let ma = Matrix::randn(64, 64, 1.0, &mut rng);
    let mb = Matrix::randn(64, 64, 1.0, &mut rng);
    results.push(b.run("matmul_f32 64x64x64", || matmul_f32(&ma, &mb).unwrap()));
    results.push(b.run("matmul_ps 64x64x64 (mu=4)", || matmul_ps(&ma, &mb, 4).unwrap()));

    // --- Fused attention score row (the causal_attention hot kernel). ---
    let (hd, d, srow) = (32usize, 128usize, 256usize);
    let qh: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
    let keys: Vec<f32> = (0..srow * d).map(|_| rng.normal_f32()).collect();
    let fused = b.run("score_row_ps n=256 hd=32 (mu=4)", || {
        let mut out = vec![0.0f32; srow];
        score_row_ps(&qh, &keys, d, srow, 4, 0.176_776_7, &mut out);
        out
    });
    let score_flops = (2 * hd * srow) as f64;
    let score_gflops = score_flops / fused.median().as_secs_f64().max(1e-12) / 1e9;
    results.push(fused);

    // --- Selection rules over a softmax row. ---
    let row: Vec<f32> = (0..512).map(|_| rng.normal_f32() * 4.0).collect();
    results.push(b.run("select_strict n=512", || select_strict(&row, 0.1)));
    results.push(b.run("select_relaxed n=512", || select_relaxed(&row, 0.1)));

    // --- Whole-model paths. ---
    let cfg = ModelConfig::small();
    let weights = ArtifactStore::open(ArtifactStore::default_dir())
        .and_then(|s| s.weights("small"))
        .unwrap_or_else(|_| Weights::random(&cfg, &mut rng).expect("random weights"));
    let native = NativeEngine::new(weights);
    let data = Dataset::generate(Domain::Web, cfg.vocab, cfg.batch, cfg.seq, 7, 9);
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
    results.push(b.run("native forward small (batch=4, mu=4, lamp)", || {
        native.infer(&data.sequences, &policy, 0).unwrap()
    }));
    results.push(b.run("native forward small (batch=4, fp32 ref)", || {
        native.infer(&data.sequences, &PrecisionPolicy::reference(), 0).unwrap()
    }));

    if let Ok(store) = ArtifactStore::open(ArtifactStore::default_dir()) {
        if store.available_models().contains(&"small".to_string()) {
            let pjrt = PjrtEngine::load(&store, "small").unwrap();
            results.push(b.run("pjrt execute small (batch=4, mu=4, lamp)", || {
                pjrt.infer(&data.sequences, &policy, 0).unwrap()
            }));
        }
    }

    let mut t = Table::new("kernel micro-benchmarks", &["benchmark"]);
    for r in &results {
        t.row(vec![r.summary()]);
    }
    t.print();

    let path = bench_record_path();
    record_bench_section(
        &path,
        "kernels",
        &JsonObj::new()
            .str("kernel", "score_row_ps (PS(4), n=256, hd=32)")
            .num("attention_kernel_gflops", score_gflops),
    )
    .expect("write bench record");
    println!("recorded attention-kernel GFLOP/s -> {}", path.display());
}
