//! Self-speculative decoding benchmark — the PR-9 headline measurement.
//!
//! Drafts k tokens per round under an aggressive (cheap) LAMP plan, then
//! verifies the whole chunk with the exact target plan in one batched
//! forward, comparing end-to-end decode throughput and acceptance length
//! against the non-speculative target-plan baseline across a ladder of
//! draft aggressiveness. The emitted stream is bit-identical to the solo
//! decode by construction (asserted here for every configuration), so the
//! speedup — when the draft is accepted often enough — is free.
//!
//! Results go into `BENCH_PR9.json` (override with `LAMP_BENCH_OUT`) under
//! the `speculative` section.
//!
//! ```bash
//! cargo bench --bench speculative
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj, Table};
use lamp::lamp::softmax::SoftmaxRule;
use lamp::model::{
    generate_with_stats, AttentionPrecision, Decode, ModelConfig, PrecisionPlan, SpecConfig,
    Weights,
};
use lamp::util::Rng;
use std::time::Duration;

fn record_path() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR9.json"))
}

fn main() {
    // `--smoke` (the CI bench-smoke job): one sample on a short context so
    // the producer of BENCH_PR9.json is exercised on every push without
    // burning CI minutes — numbers from a smoke run are not comparable.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-4l".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 256 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(29);
    let weights = Weights::random(&cfg, &mut rng).unwrap();
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 31 + 7) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len();
    let samples = if smoke { 1 } else { 5 };
    let seed = 7u64;

    // The target plan is deliberately repair-heavy (low τ ⇒ many exact
    // FP32 recomputes): that is the regime where drafting under a cheaper
    // plan and verifying in one batched forward pays for itself.
    let target =
        PrecisionPlan::whole_model(AttentionPrecision::lamp(3, 0.02, SoftmaxRule::Strict));
    target.validate().expect("target plan");

    // Draft ladder: coarser μ / looser τ ⇒ cheaper drafting but lower
    // acceptance; k trades round count against wasted draft work.
    let drafts: [(&str, AttentionPrecision, usize); 3] = [
        ("uniform(2) k=4", AttentionPrecision::uniform(2), 4),
        ("uniform(3) k=8", AttentionPrecision::uniform(3), 8),
        (
            "lamp(3,0.5) k=4",
            AttentionPrecision::lamp(3, 0.5, SoftmaxRule::Strict),
            4,
        ),
    ];

    // --- Solo baseline: non-speculative decode under the target plan. ---
    let bencher = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: samples,
        max_total: Duration::from_secs(120),
    };
    let solo_run = bencher.run("solo decode (target plan)", || {
        generate_with_stats(&weights, &prompt, new_tokens, target, Decode::Greedy, seed).unwrap()
    });
    println!("{}", solo_run.summary());
    let solo_tok_s = new_tokens as f64 / solo_run.median().as_secs_f64().max(1e-12);
    let (solo_tokens, _) =
        generate_with_stats(&weights, &prompt, new_tokens, target, Decode::Greedy, seed).unwrap();

    // --- Speculative ladder. ---
    let mut table = Table::new(
        "speculative decode vs solo (target plan)",
        &["draft", "tok/s", "speedup", "accept rate", "tok/round"],
    );
    table.row(vec![
        "(solo)".into(),
        format!("{solo_tok_s:.1}"),
        "1.00x".into(),
        "-".into(),
        "-".into(),
    ]);
    let mut obj = JsonObj::new()
        .str("model", "4 layers, 4 heads, d=128, vocab=256")
        .int("seq", cfg.seq as u64)
        .int("new_tokens", new_tokens as u64)
        .str("target_policy", "lamp(mu=3, tau=0.02, strict)")
        .int("draft_configs", drafts.len() as u64)
        .num("solo_tok_s", solo_tok_s);
    let mut best_speedup = 0.0f64;
    let mut best_label = "";
    for (i, &(label, draft, k)) in drafts.iter().enumerate() {
        let plan = target.with_spec(Some(SpecConfig::whole_model(draft, k)));
        plan.validate().expect("spec plan");
        let run = bencher.run(&format!("speculative decode ({label})"), || {
            generate_with_stats(&weights, &prompt, new_tokens, plan, Decode::Greedy, seed).unwrap()
        });
        println!("{}", run.summary());
        let (tokens, stats) =
            generate_with_stats(&weights, &prompt, new_tokens, plan, Decode::Greedy, seed).unwrap();
        // The bit-exactness contract: speculation is invisible in the output.
        assert_eq!(tokens, solo_tokens, "spec stream diverged from solo ({label})");
        assert!(stats.spec.rounds > 0, "no speculative rounds ran ({label})");
        let tok_s = new_tokens as f64 / run.median().as_secs_f64().max(1e-12);
        let speedup = tok_s / solo_tok_s.max(1e-12);
        let acc = stats.spec.acceptance_rate();
        let per_round = stats.spec.mean_accept_len();
        table.row(vec![
            label.into(),
            format!("{tok_s:.1}"),
            format!("{speedup:.2}x"),
            format!("{:.1}%", acc * 100.0),
            format!("{per_round:.2}"),
        ]);
        obj = obj
            .str(&format!("draft{i}_label"), label)
            .int(&format!("draft{i}_k"), k as u64)
            .num(&format!("draft{i}_tok_s"), tok_s)
            .num(&format!("draft{i}_speedup"), speedup)
            .num(&format!("draft{i}_accept_rate"), acc)
            .num(&format!("draft{i}_tokens_per_round"), per_round);
        if speedup > best_speedup {
            best_speedup = speedup;
            best_label = label;
        }
    }
    println!("{}", table.render());
    println!(
        "best: {best_label} at {best_speedup:.2}x over solo {solo_tok_s:.1} tok/s \
         (target: > 1x for at least one draft config)"
    );

    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    obj = obj
        .num("best_speedup", best_speedup)
        .int("host_cores", cores as u64)
        // Smoke records are single-sample and not comparable; mark them so
        // the cross-PR guards can't mistake them for real.
        .int("smoke", smoke as u64);
    let path = record_path();
    if smoke {
        println!("smoke mode: timings above are single-sample and not comparable");
    }
    record_bench_section(&path, "speculative", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());

    if best_speedup <= 1.0 && !smoke {
        eprintln!(
            "WARNING: no draft configuration beat the solo baseline \
             (best {best_speedup:.2}x) — speculation is not paying for itself"
        );
    }
}
