//! Regenerates paper Figure 4 — see rust/src/experiments/fig4.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig4");
}
