//! Regenerates paper Figure 2 — see rust/src/experiments/fig2.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig2");
}
