//! The obs-plane overhead price — the PR-10 measurement.
//!
//! One scheduler workload driven twice, recorded into `BENCH_PR10.json`
//! (override with `LAMP_BENCH_OUT`):
//!
//! * **obs off** — no caller hub: the scheduler runs on its private
//!   wall-clock hub with no tracer, exactly what `Scheduler::new`
//!   gives every pre-existing caller.
//! * **obs on** — an attached `ObsHub` with a span tracer, plus a
//!   registry snapshot and JSONL trace render after each drive (the
//!   full `--metrics-out`/`--trace-out` export path).
//!
//! The bench asserts the two drives stream bit-identically (the parity
//! suite pins this; the bench re-checks it on the workload it prices)
//! and records the relative wall overhead — the ≤2% hot-path budget of
//! DESIGN.md §Observability. Wall metrics stay out of the committed
//! baseline (runner heterogeneity); the gate pins the workload shape.
//!
//! ```bash
//! cargo bench --bench observability [-- --smoke]
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{
    Engine, GenerateRequest, KvCacheOptions, NativeEngine, PrecisionPolicy, Rule, Scheduler,
    SchedulerOptions,
};
use lamp::linalg::WeightFormat;
use lamp::model::{ModelConfig, Weights};
use lamp::obs::{trace, ObsHub};
use lamp::util::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR10.json"))
}

const TRACE_CAPACITY: usize = 1 << 16;

fn workload(n: usize, cfg: &ModelConfig, max_new: usize) -> Vec<GenerateRequest> {
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
    (0..n as u64)
        .map(|id| {
            let prompt: Vec<u32> = (0..16u32)
                .map(|i| (i * 31 + id as u32 * 13 + 3) % cfg.vocab as u32)
                .collect();
            GenerateRequest::new(id, prompt, max_new, policy).with_seed(id)
        })
        .collect()
}

/// Drain `reqs` through a fresh scheduler; returns the sorted token
/// streams and the drain wall-clock. With `obs: Some(..)`, also renders
/// the registry snapshot and span trace afterwards — export cost is
/// part of what the obs-on column prices.
fn drive(
    engine: &dyn Engine,
    reqs: &[GenerateRequest],
    opts: &SchedulerOptions,
    obs: Option<&Arc<ObsHub>>,
) -> (Vec<Vec<u32>>, f64) {
    let mut run_opts = opts.clone();
    run_opts.obs = obs.map(Arc::clone);
    let mut sched = Scheduler::new(engine, run_opts);
    for r in reqs {
        sched.admit(r.clone());
    }
    let t0 = Instant::now();
    let mut done = sched.run_to_completion().expect("drive");
    if let Some(hub) = obs {
        let _snapshot = hub.registry().snapshot().to_json();
        if let Some(tr) = hub.tracer() {
            let _jsonl = trace::to_jsonl(&tr.events());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(done.len(), reqs.len(), "every request must complete");
    done.sort_by_key(|r| r.id);
    (done.into_iter().map(|r| r.tokens).collect(), wall)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-obs".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 128 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(71);
    let weights = Weights::random(&cfg, &mut rng).unwrap();
    let kv = KvCacheOptions::serving(&cfg, WeightFormat::F32, 4);
    let engine = NativeEngine::new(weights).with_kv_cache(kv).unwrap();
    let n_requests = if smoke { 4 } else { 16 };
    let max_new = if smoke { 12 } else { 32 };
    let reqs = workload(n_requests, &cfg, max_new);
    let opts = SchedulerOptions { max_sessions: 4, prefill_chunk: 8, ..Default::default() };
    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 7 },
        max_total: Duration::from_secs(120),
    };
    let tokens_total = (n_requests * max_new) as f64;

    // --- Obs off: the private-hub default every existing caller gets. ---
    let stats = b.run("serve, obs off", || drive(&engine, &reqs, &opts, None));
    println!("{}", stats.summary());
    let off_wall = stats.median().as_secs_f64().max(1e-12);
    let off_tok_s = tokens_total / off_wall;
    let (off_streams, _) = drive(&engine, &reqs, &opts, None);

    // --- Obs on: attached hub + tracer + post-drive exports. ---
    let hub = Arc::new(ObsHub::new().with_tracer(TRACE_CAPACITY));
    let stats = b.run("serve, obs on (tracer + exports)", || {
        if let Some(tr) = hub.tracer() {
            tr.clear(); // fresh ring per sample; capacity never rolls over
        }
        drive(&engine, &reqs, &opts, Some(&hub))
    });
    println!("{}", stats.summary());
    let on_wall = stats.median().as_secs_f64().max(1e-12);
    let on_tok_s = tokens_total / on_wall;
    let (on_streams, _) = drive(&engine, &reqs, &opts, Some(&hub));
    assert_eq!(off_streams, on_streams, "obs plane changed a token stream");
    let spans = hub.tracer().map_or(0, |t| t.len());
    assert!(spans > 0, "obs-on drive recorded no spans");

    let overhead_pct = 100.0 * (on_wall / off_wall - 1.0);
    println!(
        "obs off {off_tok_s:.1} tok/s | obs on {on_tok_s:.1} tok/s | \
         overhead {overhead_pct:+.2}% ({spans} spans; budget <=2%)"
    );
    if smoke {
        println!("smoke mode: single-sample timings, overhead not comparable");
    }

    let obj = JsonObj::new()
        .str("model", "4 layers, 4 heads, d=128, vocab=256")
        .int("seq", cfg.seq as u64)
        .int("requests", n_requests as u64)
        .int("generated_per_request", max_new as u64)
        .int("trace_capacity", TRACE_CAPACITY as u64)
        .num("obs_off_tok_s", off_tok_s)
        .num("obs_on_tok_s", on_tok_s)
        .num("overhead_pct", overhead_pct)
        .int("spans_recorded", spans as u64)
        // Smoke records are single-sample and not comparable; mark them so
        // downstream comparisons can't mistake them for real numbers.
        .int("smoke", smoke as u64);
    let path = bench_out();
    record_bench_section(&path, "observability", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());
}
