//! Decode-throughput benchmark — the PR-1 headline measurement.
//!
//! Compares autoregressive generation through the KV-cache
//! [`DecodeSession`] path against the seed engine's full-re-forward loop
//! (`generate_reforward`) on a 4-layer model at S=256, and measures the
//! fused attention score kernel's arithmetic throughput. Results are
//! printed and recorded into `BENCH_PR1.json` (override with
//! `LAMP_BENCH_OUT`) under the `decode` and `attention_kernel` sections.
//!
//! Single-thread kernel parity is preserved: both decode paths run the
//! identical sequential per-row kernels — the speedup is purely the
//! O(S²) → O(S) work reduction, not a parallelism artifact.
//!
//! ```bash
//! cargo bench --bench decode
//! ```

use lamp::benchkit::{bench_record_path, record_bench_section, Bencher, JsonObj};
use lamp::model::{generate, generate_reforward, AttentionPrecision, Decode, ModelConfig, Weights};
use lamp::softfloat::dot::{dot_ps, score_row_ps};
use lamp::util::Rng;
use std::time::Duration;

fn main() {
    // `--smoke` (the CI bench-smoke job): one sample on a short context so
    // the producer of BENCH_*.json is exercised on every push without
    // burning CI minutes — numbers from a smoke run are not comparable.
    let smoke = std::env::args().any(|a| a == "--smoke");
    // The ISSUE-1 measurement setting: 4 layers, S=256, single sequence.
    let cfg = ModelConfig {
        name: "bench-4l".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 256 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(17);
    let weights = Weights::random(&cfg, &mut rng).unwrap();
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len();
    let prec = AttentionPrecision::lamp(4, 0.05, lamp::lamp::softmax::SoftmaxRule::Strict);
    let samples = if smoke { 1 } else { 5 };

    // --- KV-cache decode path. ---
    let b_kv = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: samples,
        max_total: Duration::from_secs(60),
    };
    let kv = b_kv.run("generate kv-cache (4l, S=256)", || {
        generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap()
    });
    println!("{}", kv.summary());
    let kv_tok_s = new_tokens as f64 / kv.median().as_secs_f64().max(1e-12);

    // --- Seed baseline: full re-forward per token. ---
    let b_rf = Bencher {
        warmup_iters: 0,
        sample_iters: if smoke { 1 } else { 2 },
        max_total: Duration::from_secs(240),
    };
    let rf = b_rf.run("generate re-forward (4l, S=256)", || {
        generate_reforward(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap()
    });
    println!("{}", rf.summary());
    let rf_tok_s = new_tokens as f64 / rf.median().as_secs_f64().max(1e-12);

    // Sanity: identical token streams (the bit-exactness contract).
    let (kv_tokens, _) =
        generate(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap();
    let (rf_tokens, _) =
        generate_reforward(&weights, &prompt, new_tokens, prec, Decode::Greedy, 3).unwrap();
    assert_eq!(kv_tokens, rf_tokens, "KV decode diverged from re-forward");

    let speedup = kv_tok_s / rf_tok_s.max(1e-12);
    println!("decode throughput: kv-cache {kv_tok_s:.1} tok/s, re-forward {rf_tok_s:.1} tok/s");
    println!("speedup: {speedup:.1}x (target: >= 4x)");

    // --- Attention score kernel GFLOP/s: fused row vs per-dot loop. ---
    let hd = cfg.head_dim();
    let d = cfg.d_model;
    let s = cfg.seq;
    let q: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
    let keys: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
    let scale = 1.0 / (hd as f32).sqrt();
    let flops = (2 * hd * s) as f64; // one full causal row at max length
    let bk = Bencher::default();
    let fused = bk.run(&format!("score_row_ps fused (n={s}, hd=32, mu=4)"), || {
        let mut out = vec![0.0f32; s];
        score_row_ps(&q, &keys, d, s, 4, scale, &mut out);
        out
    });
    println!("{}", fused.summary());
    let per_dot = bk.run(&format!("per-dot dot_ps row (n={s}, hd=32, mu=4)"), || {
        let mut out = vec![0.0f32; s];
        for (j, o) in out.iter_mut().enumerate() {
            *o = dot_ps(&q, &keys[j * d..j * d + hd], 4) * scale;
        }
        out
    });
    println!("{}", per_dot.summary());
    let fused_gflops = flops / fused.median().as_secs_f64().max(1e-12) / 1e9;
    let per_dot_gflops = flops / per_dot.median().as_secs_f64().max(1e-12) / 1e9;
    println!(
        "attention score kernel: fused {fused_gflops:.3} GFLOP/s, per-dot {per_dot_gflops:.3} GFLOP/s"
    );

    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let path = bench_record_path();
    if smoke {
        println!("smoke mode: timings above are single-sample and not comparable");
    }
    record_bench_section(
        &path,
        "decode",
        &JsonObj::new()
            .str("model", "4 layers, 4 heads, d=128, vocab=256")
            .int("seq", s as u64)
            .int("new_tokens", new_tokens as u64)
            .str("policy", "lamp(mu=4, tau=0.05, strict)")
            .num("kv_cache_tok_s", kv_tok_s)
            .num("reforward_tok_s", rf_tok_s)
            .num("speedup", speedup)
            .int("host_cores", cores as u64)
            // Smoke records are single-sample and not comparable; mark
            // them so the cross-PR guards can't mistake them for real.
            .int("smoke", smoke as u64),
    )
    .expect("write bench record");
    record_bench_section(
        &path,
        "attention_kernel",
        &JsonObj::new()
            .str("kernel", &format!("score_row_ps (PS(4) accumulate, n={s}, hd=32)"))
            .num("fused_gflops", fused_gflops)
            .num("per_dot_gflops", per_dot_gflops),
    )
    .expect("write bench record");
    println!("recorded -> {}", path.display());

    if speedup < 4.0 && !smoke {
        eprintln!("WARNING: decode speedup {speedup:.1}x below the 4x acceptance target");
    }
}
