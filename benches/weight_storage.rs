//! Mixed-precision weight storage — the PR-4 measurement.
//!
//! For each storage format (f32 / bf16 / PS(8)) of the same 4-layer
//! native engine:
//!
//! * **resident parameter bytes** (`Weights::resident_param_bytes`) — the
//!   bytes the decode path actually streams per pass; bf16 must land near
//!   the 2× matrix saving (bias/layernorm vectors stay f32);
//! * **decode tokens/sec** through the shared `generate_with_stats` loop
//!   under the reference plan (the fused-dequant hot path) and under the
//!   whole-model LAMP plan (repair kernels reading stored bytes).
//!
//! Results land in `BENCH_PR4.json` (override with `LAMP_BENCH_OUT`).
//! `--smoke` (the CI bench-smoke job) runs one short sample per point so
//! the producer is exercised on every push; smoke numbers are not
//! comparable.
//!
//! ```bash
//! cargo bench --bench weight_storage [-- --smoke]
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{Engine, NativeEngine, PrecisionPolicy, Rule, SitePolicy};
use lamp::linalg::WeightFormat;
use lamp::model::{generate_with_stats, Decode, ModelConfig, Weights};
use lamp::util::Rng;
use std::time::Duration;

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR4.json"))
}

fn drive(engine: &NativeEngine, policy: &PrecisionPolicy, prompt: &[u32], new_tokens: usize) {
    generate_with_stats(
        engine.weights(),
        prompt,
        new_tokens,
        engine.decode_precision(policy),
        Decode::Greedy,
        3,
    )
    .expect("generate");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-wfmt".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 160 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(41);
    let base = Weights::random(&cfg, &mut rng).unwrap();
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len() - 1;

    let reference = PrecisionPolicy::reference();
    let whole = PrecisionPolicy::lamp(4, 0.02, Rule::Strict)
        .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))
        .with_norm(SitePolicy::lamp(10, 1.0, Rule::Strict))
        .with_sampler(SitePolicy::lamp(7, 0.05, Rule::Relaxed));

    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 5 },
        max_total: Duration::from_secs(120),
    };

    let f32_bytes = base.resident_param_bytes();
    let mut obj = JsonObj::new()
        .str("model", "4 layers, 4 heads, d=128, vocab=256")
        .int("seq", cfg.seq as u64)
        .int("generated_tokens", new_tokens as u64)
        .str("whole_policy", &whole.label())
        // Smoke records are single-sample and not comparable; mark them
        // so downstream comparisons can't mistake them for real numbers.
        .int("smoke", smoke as u64);

    let mut ref_tok_s = Vec::new();
    for fmt in [
        WeightFormat::F32,
        WeightFormat::Bf16,
        WeightFormat::PsRounded { mu: 8 },
    ] {
        let engine = NativeEngine::new(base.clone()).with_weight_format(fmt).unwrap();
        let bytes = engine.weights().resident_param_bytes();
        println!(
            "{}: resident parameter bytes {} ({:.2}x vs f32)",
            fmt.label(),
            bytes,
            f32_bytes as f64 / bytes as f64
        );
        let stats = b.run(
            &format!("decode reference plan, {} storage (4l, S={})", fmt.label(), cfg.seq),
            || drive(&engine, &reference, &prompt, new_tokens),
        );
        println!("{}", stats.summary());
        let tok_s = new_tokens as f64 / stats.median().as_secs_f64().max(1e-12);
        ref_tok_s.push(tok_s);
        let wstats = b.run(
            &format!("decode whole-model plan, {} storage (4l, S={})", fmt.label(), cfg.seq),
            || drive(&engine, &whole, &prompt, new_tokens),
        );
        println!("{}", wstats.summary());
        let whole_tok_s = new_tokens as f64 / wstats.median().as_secs_f64().max(1e-12);
        println!(
            "{}: decode reference {tok_s:.1} tok/s, whole-model {whole_tok_s:.1} tok/s",
            fmt.label()
        );
        obj = obj
            .int(&format!("{}_resident_bytes", fmt.label()), bytes as u64)
            .num(&format!("{}_reference_tok_s", fmt.label()), tok_s)
            .num(&format!("{}_whole_model_tok_s", fmt.label()), whole_tok_s);
    }

    // Acceptance signals (informative in smoke mode): bf16 must halve the
    // matrix-resident bytes and keep decode throughput in f32's band.
    let bf16_bytes = base
        .quantize_to(WeightFormat::Bf16)
        .unwrap()
        .resident_param_bytes();
    let byte_ratio = f32_bytes as f64 / bf16_bytes as f64;
    if byte_ratio < 1.8 {
        eprintln!("WARNING: bf16 byte saving {byte_ratio:.2}x below the ~2x target");
    }
    let throughput_ratio = ref_tok_s[1] / ref_tok_s[0].max(1e-12);
    println!(
        "bf16 bytes {:.2}x smaller than f32; bf16/f32 decode throughput ratio {:.2}",
        byte_ratio, throughput_ratio
    );
    if throughput_ratio < 0.9 && !smoke {
        eprintln!(
            "WARNING: bf16 decode throughput {throughput_ratio:.2}x of f32 (target: >= 1.0)"
        );
    }
    obj = obj.num("bf16_byte_ratio", byte_ratio).num(
        "bf16_over_f32_reference_throughput",
        throughput_ratio,
    );

    let path = bench_out();
    record_bench_section(&path, "weight_storage", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());
}
