//! Regenerates paper Figure 7 — see rust/src/experiments/fig7.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig7");
}
