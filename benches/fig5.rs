//! Regenerates paper Figure 5 — see rust/src/experiments/fig5.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig5");
}
