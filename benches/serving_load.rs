//! Serving-under-load benchmark — the PR-2 headline measurement.
//!
//! A Zipf-length generation workload (natural-language request lengths are
//! approximately Zipfian; `data::zipf`) is served two ways on the same
//! 4-layer native engine:
//!
//! * **serial** — each request alone through `NativeEngine::generate`,
//!   one after another (the pre-scheduler serving model: a long generation
//!   monopolizes the engine);
//! * **continuous batching** — all requests through the
//!   `coordinator::scheduler`, sessions stepped in parallel across the
//!   thread pool, requests admitted and retired mid-flight.
//!
//! Both paths produce bit-identical per-request token streams (asserted
//! here; the differential suite is `rust/tests/scheduler_parity.rs`).
//! Results — throughput, TTFT/ITL percentiles, occupancy — are recorded
//! into `BENCH_PR2.json` (override with `LAMP_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench serving_load            # full measurement
//! cargo bench --bench serving_load -- --smoke # CI scale: 8 reqs, 1 sample
//! ```

use lamp::benchkit::{env_usize, record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{
    GenerateRequest, NativeEngine, PrecisionPolicy, Rule, Scheduler, SchedulerOptions,
};
use lamp::data::Zipf;
use lamp::model::{Decode, ModelConfig, Weights};
use lamp::util::{Rng, ThreadPool};
use std::sync::Arc;
use std::time::Duration;

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR2.json"))
}

/// Build the mixed-length Zipf workload: many short requests, a heavy tail
/// of long generations — exactly the traffic shape where one-at-a-time
/// decode starves the short requests.
fn workload(cfg: &ModelConfig, n: usize, seed: u64) -> Vec<GenerateRequest> {
    let zipf = Zipf::new(24, 1.1);
    let mut rng = Rng::new(seed);
    let policies = [
        PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed),
        PrecisionPolicy::lamp(4, 0.05, Rule::Strict),
        PrecisionPolicy::uniform(4),
    ];
    (0..n as u64)
        .map(|id| {
            let prompt_len = 2 + zipf.sample(&mut rng);
            let prompt: Vec<u32> =
                (0..prompt_len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            // Rank 0 (most likely) → short; deep ranks → near-context-length.
            let new_tokens = (4 + zipf.sample(&mut rng) * 4).min(cfg.seq - prompt_len - 1);
            let decode = if id % 3 == 0 {
                Decode::TopK { k: 8, temperature: 1.1 }
            } else {
                Decode::Greedy
            };
            GenerateRequest::new(id, prompt, new_tokens, policies[(id % 3) as usize])
                .with_decode(decode)
                .with_seed(id * 7 + 1)
        })
        .collect()
}

fn main() {
    let cfg = ModelConfig {
        name: "bench-serve".into(),
        vocab: 256,
        seq: 128,
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    // `--smoke` (CI): fewer requests, one timed sample — the parity guard
    // and the recorded configuration metrics still run at full strength.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Rng::new(23);
    let weights = Weights::random(&cfg, &mut rng).unwrap();
    let engine = NativeEngine::new(weights);
    let n_req = env_usize("LAMP_BENCH_REQS", if smoke { 8 } else { 24 });
    let reqs = workload(&cfg, n_req, 99);
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let pool = Arc::new(ThreadPool::with_cpus(usize::MAX));
    let opts = SchedulerOptions {
        max_sessions: (2 * cores).max(4),
        prefill_chunk: 8,
        pool: Some(Arc::clone(&pool)),
        ..Default::default()
    };

    // --- Parity guard: the scheduler must reproduce solo decode exactly. ---
    let solo: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| {
            engine
                .generate(&r.prompt, r.max_new_tokens, &r.policy, r.decode, r.seed)
                .expect("solo generate")
                .0
        })
        .collect();
    let total_generated: usize = reqs
        .iter()
        .zip(&solo)
        .map(|(r, toks)| toks.len() - r.prompt.len())
        .sum();
    {
        let mut sched = Scheduler::new(&engine, opts.clone());
        for r in &reqs {
            sched.admit(r.clone());
        }
        let mut out = sched.run_to_completion().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), reqs.len(), "lost responses");
        for (resp, want) in out.iter().zip(&solo) {
            assert_eq!(&resp.tokens, want, "scheduler diverged from solo decode");
        }
    }

    // --- Serial per-request decode (the baseline serving model). ---
    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 3 },
        max_total: Duration::from_secs(120),
    };
    let serial = b.run(&format!("serial decode ({n_req} reqs, Zipf lengths)"), || {
        for r in &reqs {
            let (tokens, _) = engine
                .generate(&r.prompt, r.max_new_tokens, &r.policy, r.decode, r.seed)
                .expect("solo generate");
            std::hint::black_box(tokens);
        }
    });
    println!("{}", serial.summary());
    let serial_tok_s = total_generated as f64 / serial.median().as_secs_f64().max(1e-12);

    // --- Continuous batching through the scheduler. ---
    let mut last_metrics = None;
    let sched_stats =
        b.run(&format!("continuous batching ({n_req} reqs, Zipf lengths)"), || {
            let mut sched = Scheduler::new(&engine, opts.clone());
            for r in &reqs {
                sched.admit(r.clone());
            }
            let out = sched.run_to_completion().unwrap();
            assert_eq!(out.len(), reqs.len());
            last_metrics = Some(sched.metrics());
        });
    println!("{}", sched_stats.summary());
    let sched_tok_s = total_generated as f64 / sched_stats.median().as_secs_f64().max(1e-12);
    let m = last_metrics.expect("at least one sample ran");

    let speedup = sched_tok_s / serial_tok_s.max(1e-12);
    println!(
        "serving throughput: continuous batching {sched_tok_s:.1} tok/s, \
         serial {serial_tok_s:.1} tok/s — speedup {speedup:.2}x (target: >= 2x)"
    );
    println!(
        "TTFT p50/p95: {:.1}/{:.1} ms — ITL p50/p95: {:.2}/{:.2} ms — occupancy {:.1}",
        1e3 * m.ttft_p50_s,
        1e3 * m.ttft_p95_s,
        1e3 * m.itl_p50_s,
        1e3 * m.itl_p95_s,
        m.mean_active_sessions
    );

    let path = bench_out();
    record_bench_section(
        &path,
        "serving_load",
        &JsonObj::new()
            .str("model", "4 layers, 4 heads, d=128, vocab=256, S=128")
            .str("workload", "Zipf(s=1.1) prompt/generation lengths, 3 policies, mixed sampling")
            .int("requests", n_req as u64)
            .int("generated_tokens", total_generated as u64)
            .num("continuous_tok_s", sched_tok_s)
            .num("serial_tok_s", serial_tok_s)
            .num("speedup", speedup)
            .num("ttft_p50_ms", 1e3 * m.ttft_p50_s)
            .num("ttft_p95_ms", 1e3 * m.ttft_p95_s)
            .num("itl_p50_ms", 1e3 * m.itl_p50_s)
            .num("itl_p95_ms", 1e3 * m.itl_p95_s)
            .num("mean_active_sessions", m.mean_active_sessions)
            .int("max_sessions", opts.max_sessions as u64)
            .int("pool_threads", pool.size() as u64)
            .int("host_cores", cores as u64)
            .int("smoke", smoke as u64),
    )
    .expect("write bench record");
    println!("recorded -> {}", path.display());

    if speedup < 2.0 {
        eprintln!(
            "WARNING: continuous-batching speedup {speedup:.2}x below the 2x acceptance \
             target (pool has {} workers on {cores} cores)",
            pool.size()
        );
    }
}
