//! L3 coordinator benchmarks — the §Perf L3 evidence: batcher admission/
//! cut throughput (pure queueing, no model), and end-to-end serving
//! throughput on the native nano engine at several batch policies.

use lamp::benchkit::{bench_record_path, record_bench_section, Bencher, JsonObj, Table};
use lamp::coordinator::{
    Batcher, InferenceRequest, NativeEngine, PrecisionPolicy, Server,
};
use lamp::data::{Dataset, Domain};
use lamp::model::{ModelConfig, Weights};
use lamp::runtime::ArtifactStore;
use lamp::util::Rng;
use std::time::Duration;

fn main() {
    let b = Bencher::default();
    let mut results = Vec::new();

    // --- Pure queueing: admission + cutting 10k requests, 3 policies. ---
    results.push(b.run("batcher admit+cut 10k reqs / 3 policies", || {
        let mut batcher = Batcher::new(8, Duration::from_secs(3600));
        let policies = [
            PrecisionPolicy::uniform(4),
            PrecisionPolicy::uniform(7),
            PrecisionPolicy::reference(),
        ];
        for i in 0..10_000u64 {
            batcher.push(InferenceRequest::new(
                i,
                vec![1, 2, 3],
                policies[(i % 3) as usize],
            ));
        }
        let mut total = 0;
        while let Some(cut) = batcher.cut(true) {
            total += cut.requests.len();
        }
        assert_eq!(total, 10_000);
    }));

    // --- End-to-end serving on the native nano engine. ---
    let cfg = ModelConfig::nano();
    let mut rng = Rng::new(5);
    let weights = ArtifactStore::open(ArtifactStore::default_dir())
        .and_then(|s| s.weights("nano"))
        .unwrap_or_else(|_| Weights::random(&cfg, &mut rng).expect("random weights"));
    let data = Dataset::generate(Domain::Web, cfg.vocab, 16, cfg.seq, 7, 3);

    for (label, tier) in [("economy", "economy"), ("balanced", "balanced"), ("exact", "exact")] {
        let w = weights.clone();
        let seqs = data.sequences.clone();
        results.push(b.run(&format!("serve 16 reqs nano native [{label}]"), move || {
            let engine = NativeEngine::new(w.clone());
            let mut server = Server::new(Box::new(engine), Duration::from_millis(1));
            let policy = PrecisionPolicy::tier(tier).unwrap();
            let mut served = 0;
            for (i, seq) in seqs.iter().enumerate() {
                server
                    .submit(InferenceRequest::new(i as u64, seq.clone(), policy))
                    .unwrap();
                served += server.step(false).unwrap().len();
            }
            served += server.drain().unwrap().len();
            assert_eq!(served, 16);
        }));
    }

    // --- Serving tokens/sec on the parallel native engine (balanced). ---
    let serve_stats = {
        let engine = NativeEngine::new(weights.clone()).with_threads(0);
        let mut server = Server::new(Box::new(engine), Duration::from_millis(1));
        let policy = PrecisionPolicy::tier("balanced").unwrap();
        for (i, seq) in data.sequences.iter().enumerate() {
            server
                .submit(InferenceRequest::new(i as u64, seq.clone(), policy))
                .unwrap();
            server.step(false).unwrap();
        }
        server.drain().unwrap();
        server.stats()
    };
    println!(
        "serving throughput (nano, balanced, parallel native): {:.1} tok/s",
        serve_stats.throughput_tok_s
    );

    let mut t = Table::new("coordinator benchmarks", &["benchmark"]);
    for r in &results {
        t.row(vec![r.summary()]);
    }
    t.print();

    record_bench_section(
        &bench_record_path(),
        "serving",
        &JsonObj::new()
            .str("engine", "native nano, balanced tier, attention tiled on all CPUs")
            .int("requests", serve_stats.requests as u64)
            .int("tokens", serve_stats.total_tokens as u64)
            .num("tokens_per_sec", serve_stats.throughput_tok_s)
            .num("latency_p95_ms", 1e3 * serve_stats.latency_p95_s),
    )
    .expect("write bench record");
}
