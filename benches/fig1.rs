//! Regenerates paper Figure 1 — see rust/src/experiments/fig1.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig1");
}
