//! Fault-tolerant serving plane — the PR-6 measurement.
//!
//! Three sections, recorded into `BENCH_PR6.json` (override with
//! `LAMP_BENCH_OUT`):
//!
//! * **fault-free baseline** — scheduler throughput and TTFT p95 with no
//!   injector in the path, the zero-overhead reference for the two
//!   faulted sections.
//! * **retry under injected faults** — the same workload behind a
//!   deterministic `FaultInjector` (transient step errors + latency
//!   spikes): throughput, TTFT p95, retries taken, and the overhead
//!   ratio against the baseline. Every stream still completes (the
//!   chaos suite pins bit-exactness; this bench prices it).
//! * **recovery after a pool-exhaustion burst** — a burst of sessions
//!   against a ~1.5-session KV pool: wall-clock to fully drain through
//!   preempt/recompute cycles, plus the preemption count.
//!
//! `--smoke` (the CI bench-smoke job) runs one short sample per point so
//! the producer is exercised on every push; smoke numbers are not
//! comparable.
//!
//! ```bash
//! cargo bench --bench fault_recovery [-- --smoke]
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{
    DecodeMetrics, Engine, FaultInjector, FaultPlan, GenerateRequest, KvCacheOptions,
    NativeEngine, PrecisionPolicy, RetryPolicy, Rule, Scheduler, SchedulerOptions,
};
use lamp::linalg::WeightFormat;
use lamp::model::{ModelConfig, Weights};
use lamp::util::Rng;
use std::time::{Duration, Instant};

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR6.json"))
}

fn workload(n: usize, cfg: &ModelConfig, max_new: usize) -> Vec<GenerateRequest> {
    let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
    (0..n as u64)
        .map(|id| {
            let prompt: Vec<u32> = (0..16u32)
                .map(|i| (i * 37 + id as u32 * 11 + 5) % cfg.vocab as u32)
                .collect();
            GenerateRequest::new(id, prompt, max_new, policy).with_seed(id)
        })
        .collect()
}

/// Drain `reqs` through a fresh scheduler; returns lifetime metrics and
/// the wall-clock seconds the drain took.
fn drive(
    engine: &dyn Engine,
    reqs: &[GenerateRequest],
    opts: &SchedulerOptions,
) -> (DecodeMetrics, f64) {
    let mut sched = Scheduler::new(engine, opts.clone());
    for r in reqs {
        sched.admit(r.clone());
    }
    let t0 = Instant::now();
    let done = sched.run_to_completion().expect("drive");
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(done.len(), reqs.len(), "every request must complete");
    (sched.metrics(), wall)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-faults".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 128 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(61);
    let base = Weights::random(&cfg, &mut rng).unwrap();
    let n_requests = if smoke { 4 } else { 16 };
    let max_new = if smoke { 12 } else { 32 };
    let reqs = workload(n_requests, &cfg, max_new);
    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 5 },
        max_total: Duration::from_secs(120),
    };
    let retry = RetryPolicy {
        max_retries: 16,
        backoff: Duration::from_micros(50),
        jitter: 0.25,
    };
    let opts = SchedulerOptions {
        max_sessions: 4,
        prefill_chunk: 8,
        retry,
        ..Default::default()
    };

    // --- Section 1: fault-free baseline. ---
    let ample = KvCacheOptions::serving(&cfg, WeightFormat::F32, 4);
    let engine = NativeEngine::new(base.clone()).with_kv_cache(ample.clone()).unwrap();
    let stats = b.run("serve, no faults", || drive(&engine, &reqs, &opts));
    println!("{}", stats.summary());
    let (m, _) = drive(&engine, &reqs, &opts);
    let base_wall = stats.median().as_secs_f64().max(1e-12);
    let base_tok_s = m.generated_tokens as f64 / base_wall;
    println!(
        "baseline: {base_tok_s:.1} tok/s, ttft p95 {:.2}ms",
        m.ttft_p95_s * 1e3
    );

    // --- Section 2: the same workload under injected faults. ---
    let plan = FaultPlan::quiet(0xF417)
        .with_step_errors(0.05)
        .with_delay(0.02, Duration::from_micros(200));
    let faulted_engine = NativeEngine::new(base.clone()).with_kv_cache(ample).unwrap();
    let inj = FaultInjector::new(faulted_engine, plan).unwrap();
    let stats = b.run("serve, transient faults + retry", || drive(&inj, &reqs, &opts));
    println!("{}", stats.summary());
    let (fm, _) = drive(&inj, &reqs, &opts);
    let fault_wall = stats.median().as_secs_f64().max(1e-12);
    let fault_tok_s = fm.generated_tokens as f64 / fault_wall;
    let overhead = fault_wall / base_wall;
    println!(
        "faulted: {fault_tok_s:.1} tok/s ({overhead:.2}x baseline wall), \
         ttft p95 {:.2}ms, {} retries, {} faults injected",
        fm.ttft_p95_s * 1e3,
        fm.retries,
        fm.faults_injected
    );

    // --- Section 3: recovery from a pool-exhaustion burst. ---
    // A ~1.5-session pool under a 2x-slot burst: progress happens only
    // through preempt/recompute cycles; the drain wall-clock is the
    // recovery latency.
    let mut tiny = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
    // ~1.5x the positions one burst session needs (prompt + continuation
    // + the final fed token), so any two co-tenants exhaust the pool.
    let per_session = 16 + max_new + 1;
    tiny.capacity_blocks = (per_session * 3 / 2).div_ceil(tiny.block_size);
    tiny.sharing = false;
    let burst_engine = NativeEngine::new(base).with_kv_cache(tiny).unwrap();
    let burst = workload(2 * opts.max_sessions, &cfg, max_new);
    let stats = b.run("serve, pool-exhaustion burst", || {
        drive(&burst_engine, &burst, &opts)
    });
    println!("{}", stats.summary());
    let (bm, _) = drive(&burst_engine, &burst, &opts);
    let burst_wall = stats.median().as_secs_f64().max(1e-12);
    let burst_tok_s = bm.generated_tokens as f64 / burst_wall;
    println!(
        "burst recovery: {burst_wall:.3}s to drain, {burst_tok_s:.1} tok/s, \
         {} preemptions, ttft p95 {:.2}ms",
        bm.preemptions,
        bm.ttft_p95_s * 1e3
    );

    let obj = JsonObj::new()
        .str("model", "4 layers, 4 heads, d=128, vocab=256")
        .int("seq", cfg.seq as u64)
        .int("requests", n_requests as u64)
        .int("generated_per_request", max_new as u64)
        .num("baseline_tok_s", base_tok_s)
        .num("baseline_ttft_p95_s", m.ttft_p95_s)
        .num("faulted_tok_s", fault_tok_s)
        .num("faulted_ttft_p95_s", fm.ttft_p95_s)
        .num("fault_overhead_wall", overhead)
        .int("faulted_retries", fm.retries as u64)
        .int("faults_injected", fm.faults_injected as u64)
        .num("burst_recovery_wall_s", burst_wall)
        .num("burst_tok_s", burst_tok_s)
        .int("burst_preemptions", bm.preemptions as u64)
        // Smoke records are single-sample and not comparable; mark them so
        // downstream comparisons can't mistake them for real numbers.
        .int("smoke", smoke as u64);
    let path = bench_out();
    record_bench_section(&path, "fault_recovery", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());
    if smoke {
        println!("smoke mode: timings above are single-sample and not comparable");
    }
}
