//! Paged mixed-precision KV cache — the PR-5 measurement.
//!
//! Two sections, recorded into `BENCH_PR5.json` (override with
//! `LAMP_BENCH_OUT`):
//!
//! * **max concurrent sessions at fixed KV memory** — the serving-scale
//!   claim: against a byte budget equal to 4 contiguous per-session f32
//!   caches, block-paged pools are filled with full-context sessions
//!   until allocation refuses. f32 paging matches the contiguous count
//!   (same bytes, just blocked); bf16 paging must fit **≥ 2×** the
//!   sessions (the acceptance target); PS(μ) storage is a 4-byte
//!   simulation and fits the f32 count.
//! * **decode tokens/sec per KV format** — the fused dequant-on-read
//!   kernels through the shared decode loop, plus a LAMP-repaired bf16
//!   point (pinned rows add f32 reads), so the paging + quantization
//!   overhead on the hot path is visible next to `BENCH_PR1/PR4`.
//!
//! `--smoke` (the CI bench-smoke job) runs one short sample per point so
//! the producer is exercised on every push; smoke numbers are not
//! comparable.
//!
//! ```bash
//! cargo bench --bench kv_paging [-- --smoke]
//! ```

use lamp::benchkit::{record_bench_section, Bencher, JsonObj};
use lamp::coordinator::{Engine, KvCacheOptions, NativeEngine, PrecisionPolicy};
use lamp::linalg::WeightFormat;
use lamp::model::{Decode, KvBlockPool, ModelConfig, PagedKvCache, Weights};
use lamp::util::Rng;
use std::time::Duration;

fn bench_out() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR5.json"))
}

/// Admit full-context sessions (writing every position across every
/// layer) until the pool refuses an allocation; returns how many fit.
fn max_full_sessions(cfg: &ModelConfig, fmt: WeightFormat, budget_bytes: usize) -> usize {
    let block_size = 16;
    let opts = |capacity_blocks: usize| KvCacheOptions {
        format: fmt,
        repair_tau: f32::INFINITY,
        block_size,
        capacity_blocks,
        sharing: false,
    };
    let probe = KvBlockPool::new(cfg, opts(1)).unwrap();
    let capacity_blocks = (budget_bytes / probe.slab_bytes_per_block()).max(1);
    let pool = KvBlockPool::new(cfg, opts(capacity_blocks)).unwrap();
    let row = vec![0.5f32; cfg.d_model];
    let mut sessions: Vec<PagedKvCache> = Vec::new();
    'outer: while sessions.len() < 256 {
        let mut c = PagedKvCache::new(pool.clone(), sessions.len() as u64 + 1);
        for pos in 0..cfg.seq {
            for l in 0..cfg.layers {
                if c.append_row(l, pos, &row, &row).is_err() {
                    break 'outer;
                }
            }
            c.complete_position(0, pos);
        }
        sessions.push(c);
    }
    sessions.len()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cfg = ModelConfig {
        name: "bench-kv".into(),
        vocab: 256,
        seq: if smoke { 48 } else { 160 },
        layers: 4,
        heads: 4,
        d_model: 128,
        batch: 1,
    };
    cfg.validate().expect("bench config");
    let mut rng = Rng::new(47);
    let base = Weights::random(&cfg, &mut rng).unwrap();
    let prompt: Vec<u32> = (0..16u32).map(|i| (i * 37 + 5) % cfg.vocab as u32).collect();
    let new_tokens = cfg.seq - prompt.len() - 1;

    // --- Section 1: max concurrent sessions at fixed KV memory. ---
    // Budget = 4 contiguous per-session f32 full-context caches.
    let contiguous_bytes = 2 * cfg.layers * cfg.seq * cfg.d_model * 4;
    let budget = 4 * contiguous_bytes;
    let contiguous_sessions = budget / contiguous_bytes;
    let f32_sessions = max_full_sessions(&cfg, WeightFormat::F32, budget);
    let bf16_sessions = max_full_sessions(&cfg, WeightFormat::Bf16, budget);
    let ps8_sessions = max_full_sessions(&cfg, WeightFormat::PsRounded { mu: 8 }, budget);
    let bf16_ratio = bf16_sessions as f64 / contiguous_sessions.max(1) as f64;
    println!(
        "fixed {budget} KV bytes: contiguous f32 {contiguous_sessions} sessions, \
         paged f32 {f32_sessions}, paged bf16 {bf16_sessions}, paged ps8 {ps8_sessions}"
    );
    println!("bf16 paged vs contiguous: {bf16_ratio:.2}x (target: >= 2x)");
    if bf16_ratio < 2.0 {
        eprintln!(
            "WARNING: bf16 paged concurrency {bf16_ratio:.2}x below the 2x acceptance target"
        );
    }

    // --- Section 2: decode tok/s per KV format. ---
    let b = Bencher {
        warmup_iters: if smoke { 0 } else { 1 },
        sample_iters: if smoke { 1 } else { 5 },
        max_total: Duration::from_secs(120),
    };
    let policy = PrecisionPolicy::reference();
    let mut obj = JsonObj::new()
        .str("model", "4 layers, 4 heads, d=128, vocab=256")
        .int("seq", cfg.seq as u64)
        .int("generated_tokens", new_tokens as u64)
        .int("budget_bytes", budget as u64)
        .int("contiguous_sessions", contiguous_sessions as u64)
        .int("f32_paged_sessions", f32_sessions as u64)
        .int("bf16_paged_sessions", bf16_sessions as u64)
        .int("ps8_paged_sessions", ps8_sessions as u64)
        .num("bf16_vs_contiguous_sessions", bf16_ratio)
        // Smoke records are single-sample and not comparable; mark them so
        // downstream comparisons can't mistake them for real numbers.
        .int("smoke", smoke as u64);
    let points: Vec<(String, WeightFormat, f32)> = vec![
        ("f32".to_string(), WeightFormat::F32, f32::INFINITY),
        ("bf16".to_string(), WeightFormat::Bf16, f32::INFINITY),
        ("ps8".to_string(), WeightFormat::PsRounded { mu: 8 }, f32::INFINITY),
        // LAMP-repaired bf16: rows whose realized quantization error
        // exceeds tau stay pinned at exact f32.
        ("bf16_repaired".to_string(), WeightFormat::Bf16, 0.004),
    ];
    for (label, fmt, tau) in points {
        // Sharing off so repeated bench iterations cannot adopt earlier
        // iterations' published blocks and skip the prefill being timed.
        let opts = KvCacheOptions {
            format: fmt,
            repair_tau: tau,
            block_size: 16,
            capacity_blocks: cfg.seq.div_ceil(16) + 1,
            sharing: false,
        };
        let engine = NativeEngine::new(base.clone()).with_kv_cache(opts).unwrap();
        let stats = b.run(
            &format!("decode, {label} KV storage (4l, S={})", cfg.seq),
            || {
                engine
                    .generate(&prompt, new_tokens, &policy, Decode::Greedy, 3)
                    .expect("generate")
            },
        );
        println!("{}", stats.summary());
        let tok_s = new_tokens as f64 / stats.median().as_secs_f64().max(1e-12);
        // Resident bytes + pinned rate of one full session under this
        // configuration (annex included).
        let mut session = engine
            .decode_session(&policy, 3)
            .expect("session");
        session.prefill(&prompt).expect("prefill");
        for t in 0..new_tokens as u32 {
            session.decode_step((t * 13 + 1) % cfg.vocab as u32).expect("step");
        }
        let resident = session.kv().resident_bytes();
        let pinned = session.kv().pinned_rate();
        println!(
            "{label}: {tok_s:.1} tok/s, {resident} resident KV bytes, \
             {:.2}% rows pinned",
            100.0 * pinned
        );
        obj = obj
            .num(&format!("{label}_tok_s"), tok_s)
            .int(&format!("{label}_resident_bytes"), resident as u64)
            .num(&format!("{label}_pinned_rate"), pinned);
    }

    let path = bench_out();
    record_bench_section(&path, "kv_paging", &obj).expect("write bench record");
    println!("recorded -> {}", path.display());
    if smoke {
        println!("smoke mode: timings above are single-sample and not comparable");
    }
}
