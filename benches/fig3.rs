//! Regenerates paper Figure 3 — see rust/src/experiments/fig3.rs for the
//! experiment definition and DESIGN.md for the expected shape.
fn main() {
    lamp::benchkit::run_experiment_bench("fig3");
}
