//! Regenerates paper Table 1 (App. C.5 perplexity comparison) — see
//! rust/src/experiments/table1.rs.
fn main() {
    lamp::benchkit::run_experiment_bench("table1");
}
