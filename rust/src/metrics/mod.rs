//! Accuracy and efficiency metrics of paper §4.2:
//!
//! * [`kl`] — mean Kullback–Leibler divergence between reference and test
//!   output distributions over evaluation panels.
//! * [`flip`] — flip rate: how often the argmax prediction differs.
//! * [`pareto`] — Pareto boundaries (accuracy vs recomputation rate) used
//!   in Figures 3–7.
//! * [`stats`] — aggregation helpers (mean/stderr accumulators).

pub mod flip;
pub mod kl;
pub mod pareto;
pub mod stats;

pub use flip::flip_rate;
pub use kl::{kl_divergence, mean_kl_from_logits};
pub use pareto::{pareto_front, ParetoPoint};
pub use stats::{nearest_rank_index, percentile, Accumulator};
