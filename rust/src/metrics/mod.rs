//! Offline *accuracy and efficiency* metrics of paper §4.2 — computed
//! after the fact over evaluation panels, never on a serving hot path:
//!
//! * [`kl`] — mean Kullback–Leibler divergence between reference and test
//!   output distributions over evaluation panels.
//! * [`flip`] — flip rate: how often the argmax prediction differs.
//! * [`pareto`] — Pareto boundaries (accuracy vs recomputation rate) used
//!   in Figures 3–7.
//! * [`stats`] — aggregation helpers (mean/stderr accumulators, the
//!   nearest-rank [`percentile`] every latency summary in the repo
//!   delegates to).
//!
//! The *runtime* observability plane — counters, gauges, and histograms
//! sampled while the scheduler runs, plus span tracing — is the separate
//! [`crate::obs`] module; it reuses [`stats`]'s percentile definition so
//! `ServerStats`/`DecodeMetrics` latency quantiles and the exposition
//! histograms can never disagree on what "p95" means.

pub mod flip;
pub mod kl;
pub mod pareto;
pub mod stats;

pub use flip::flip_rate;
pub use kl::{kl_divergence, mean_kl_from_logits};
pub use pareto::{pareto_front, ParetoPoint};
pub use stats::{nearest_rank_index, percentile, Accumulator};
