//! Pareto boundaries: accuracy metric vs recomputation rate (Figures 3–7).

/// One sweep point: an (efficiency, accuracy) pair with its provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    /// Recomputation rate in [0, 1] (efficiency axis; lower is cheaper).
    pub rate: f64,
    /// Accuracy metric (KL divergence or flip rate; lower is better).
    pub metric: f64,
    /// The threshold τ that produced this point.
    pub tau: f64,
}

/// Extract the Pareto-optimal front: points not dominated by any other
/// (lower-or-equal rate AND lower-or-equal metric, strictly better in one).
/// Returned sorted by rate ascending.
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut front: Vec<ParetoPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.rate <= p.rate && q.metric < p.metric)
                || (q.rate < p.rate && q.metric <= p.metric)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.rate.partial_cmp(&b.rate).unwrap());
    front.dedup_by(|a, b| a.rate == b.rate && a.metric == b.metric);
    front
}

/// Area-under-the-front summary (lower = uniformly better trade-off),
/// integrated by trapezoid over the shared rate range. Used by tests and
/// the figure benches to compare methods the way the paper's plots do.
pub fn front_area(front: &[ParetoPoint]) -> f64 {
    if front.len() < 2 {
        return front.first().map(|p| p.metric).unwrap_or(0.0);
    }
    let mut area = 0.0;
    for w in front.windows(2) {
        let dr = w[1].rate - w[0].rate;
        area += 0.5 * (w[0].metric + w[1].metric) * dr;
    }
    area / (front.last().unwrap().rate - front[0].rate).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(rate: f64, metric: f64) -> ParetoPoint {
        ParetoPoint { rate, metric, tau: 0.0 }
    }

    #[test]
    fn dominated_points_removed() {
        let pts = vec![p(0.1, 1.0), p(0.2, 0.5), p(0.15, 2.0), p(0.3, 0.4)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 3);
        assert!(front.iter().all(|q| q.metric != 2.0));
    }

    #[test]
    fn front_sorted_by_rate() {
        let pts = vec![p(0.5, 0.1), p(0.1, 1.0), p(0.3, 0.3)];
        let front = pareto_front(&pts);
        for w in front.windows(2) {
            assert!(w[0].rate <= w[1].rate);
        }
    }

    #[test]
    fn all_on_front_when_tradeoff_strict() {
        let pts = vec![p(0.1, 1.0), p(0.2, 0.5), p(0.3, 0.25)];
        assert_eq!(pareto_front(&pts).len(), 3);
    }

    #[test]
    fn area_orders_fronts() {
        // A uniformly lower front has smaller area.
        let hi = pareto_front(&[p(0.1, 1.0), p(0.3, 0.6), p(0.5, 0.4)]);
        let lo = pareto_front(&[p(0.1, 0.5), p(0.3, 0.3), p(0.5, 0.2)]);
        assert!(front_area(&lo) < front_area(&hi));
    }

    #[test]
    fn degenerate_inputs() {
        assert!(pareto_front(&[]).is_empty());
        let single = pareto_front(&[p(0.2, 0.7)]);
        assert_eq!(single.len(), 1);
        assert_eq!(front_area(&single), 0.7);
    }
}
