//! Flip rate (paper §4.2): how often the most probable prediction of the
//! test model differs from the reference model's.

use crate::linalg::Matrix;

/// Index of the max entry (first on ties — deterministic).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Fraction of positions where argmax(reference) != argmax(test).
pub fn flip_rate(reference: &Matrix, test: &Matrix) -> f64 {
    assert_eq!(reference.shape(), test.shape());
    let s = reference.rows();
    if s == 0 {
        return 0.0;
    }
    let flips = (0..s)
        .filter(|&i| argmax(reference.row(i)) != argmax(test.row(i)))
        .count();
    flips as f64 / s as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_no_flips() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 5.0, 2.0, 0.0, -1.0, 3.0]).unwrap();
        assert_eq!(flip_rate(&m, &m), 0.0);
    }

    #[test]
    fn full_flip() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 0.0]).unwrap();
        let b = Matrix::from_vec(1, 2, vec![0.0, 1.0]).unwrap();
        assert_eq!(flip_rate(&a, &b), 1.0);
    }

    #[test]
    fn partial_flip() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.0, 1.0, 0.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(flip_rate(&a, &b), 0.5);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 1.0, 1.0]), 0);
        assert_eq!(argmax(&[0.0, 2.0, 2.0]), 1);
    }
}
