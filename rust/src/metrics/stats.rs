//! Streaming mean/variance accumulator (Welford) for metric aggregation,
//! plus the crate's single percentile implementation.

/// Index of the nearest-rank percentile in a sorted sample of length `n`:
/// rank `⌈q·n⌉` (1-based, clamped to `[1, n]`), returned 0-based.
///
/// This is the one percentile convention in the crate. The previous
/// floor-index convention (`(n as f64 * q) as usize`) silently returned
/// the *maximum* sample for p95 at the bench default of 15–20 samples
/// (e.g. `floor(20 · 0.95) = 19` = the last index); nearest-rank returns
/// the sample below which at least `q` of the data falls.
pub fn nearest_rank_index(n: usize, q: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let q = q.clamp(0.0, 1.0);
    let rank = (q * n as f64).ceil() as usize;
    rank.clamp(1, n) - 1
}

/// Empirical nearest-rank percentile of unsorted samples (0 when empty).
///
/// Shared by `BenchStats::{median,p95}` and the scheduler's TTFT/ITL
/// percentiles — one convention, one implementation.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite samples"));
    v[nearest_rank_index(v.len(), q)]
}

/// Welford accumulator for mean, variance and standard error.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        self.stddev() / (self.n as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 0.95), 5.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&[7.5], 0.95), 7.5);
    }

    #[test]
    fn p95_of_twenty_is_not_the_max() {
        // The regression the consolidation fixes: with the old floor-index
        // convention, p95 over 20 samples indexed floor(19.0) = 19 — the
        // max. Nearest-rank takes rank ⌈19⌉ = 19 → the 19th sample.
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 19.0);
        // 15 samples: rank ⌈14.25⌉ = 15 → the max, legitimately.
        let v: Vec<f64> = (1..=15).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 15.0);
    }

    #[test]
    fn nearest_rank_bounds() {
        assert_eq!(nearest_rank_index(0, 0.5), 0);
        assert_eq!(nearest_rank_index(1, 0.0), 0);
        assert_eq!(nearest_rank_index(1, 1.0), 0);
        assert_eq!(nearest_rank_index(4, 0.5), 1); // rank ⌈2⌉ = 2
        assert_eq!(nearest_rank_index(5, 0.5), 2); // rank ⌈2.5⌉ = 3
        assert_eq!(nearest_rank_index(10, 2.0), 9); // q clamped
        assert_eq!(nearest_rank_index(10, -1.0), 0);
    }

    #[test]
    fn known_values() {
        let mut a = Accumulator::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
        assert_eq!(a.count(), 8);
    }

    #[test]
    fn empty_and_single() {
        let a = Accumulator::new();
        assert!(a.mean().is_nan());
        let mut b = Accumulator::new();
        b.push(3.0);
        assert_eq!(b.mean(), 3.0);
        assert!(b.variance().is_nan());
    }

    #[test]
    fn stderr_shrinks_with_n() {
        let mut small = Accumulator::new();
        let mut large = Accumulator::new();
        let mut rng = crate::util::Rng::new(1);
        for i in 0..10_000 {
            let x = rng.normal();
            if i < 100 {
                small.push(x);
            }
            large.push(x);
        }
        assert!(large.stderr() < small.stderr());
    }
}
