//! Streaming mean/variance accumulator (Welford) for metric aggregation.

/// Welford accumulator for mean, variance and standard error.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        self.stddev() / (self.n as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let mut a = Accumulator::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            a.push(x);
        }
        assert!((a.mean() - 5.0).abs() < 1e-12);
        assert!((a.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(a.min(), 2.0);
        assert_eq!(a.max(), 9.0);
        assert_eq!(a.count(), 8);
    }

    #[test]
    fn empty_and_single() {
        let a = Accumulator::new();
        assert!(a.mean().is_nan());
        let mut b = Accumulator::new();
        b.push(3.0);
        assert_eq!(b.mean(), 3.0);
        assert!(b.variance().is_nan());
    }

    #[test]
    fn stderr_shrinks_with_n() {
        let mut small = Accumulator::new();
        let mut large = Accumulator::new();
        let mut rng = crate::util::Rng::new(1);
        for i in 0..10_000 {
            let x = rng.normal();
            if i < 100 {
                small.push(x);
            }
            large.push(x);
        }
        assert!(large.stderr() < small.stderr());
    }
}
