//! Kullback–Leibler divergence between output distributions (paper §4.2).
//!
//! "We compute the mean KL divergence between the probability distributions
//! output by a reference model and a test model over [...] sequences."

use crate::linalg::Matrix;

/// KL(p ‖ q) for two probability vectors, in nats, computed in f64.
///
/// Zero entries of p contribute 0 by the usual convention; zero entries of
/// q with nonzero p yield +∞ (clamped to a large finite value so means stay
/// usable — with softmax outputs this never triggers).
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 0.0 {
            if qi > 0.0 {
                kl += pi * (pi / qi).ln();
            } else {
                return 1e300;
            }
        }
    }
    kl.max(0.0) // guard tiny negative from rounding
}

/// Softmax (f64) of one logits row.
pub fn softmax_f64(row: &[f32]) -> Vec<f64> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = row.iter().map(|&v| ((v as f64) - m).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Mean per-position KL divergence between reference and test logits
/// ([S, V] each): mean_i KL(softmax(ref_i) ‖ softmax(test_i)).
pub fn mean_kl_from_logits(reference: &Matrix, test: &Matrix) -> f64 {
    assert_eq!(reference.shape(), test.shape());
    let s = reference.rows();
    if s == 0 {
        return 0.0;
    }
    let mut total = 0.0;
    for i in 0..s {
        let p = softmax_f64(reference.row(i));
        let q = softmax_f64(test.row(i));
        total += kl_divergence(&p, &q);
    }
    total / s as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_self_is_zero() {
        let p = vec![0.2, 0.3, 0.5];
        assert!(kl_divergence(&p, &p).abs() < 1e-15);
    }

    #[test]
    fn kl_nonnegative_and_asymmetric() {
        let p = vec![0.9, 0.1];
        let q = vec![0.5, 0.5];
        let a = kl_divergence(&p, &q);
        let b = kl_divergence(&q, &p);
        assert!(a > 0.0 && b > 0.0);
        assert!((a - b).abs() > 1e-3);
    }

    #[test]
    fn kl_known_value() {
        // KL(Bern(0.5) || Bern(0.25)) = 0.5 ln2 + 0.5 ln(2/3)
        let p = vec![0.5, 0.5];
        let q = vec![0.25, 0.75];
        let expect = 0.5 * (2.0f64).ln() + 0.5 * (0.5f64 / 0.75).ln();
        assert!((kl_divergence(&p, &q) - expect).abs() < 1e-12);
    }

    #[test]
    fn zero_q_support_clamped() {
        let p = vec![1.0, 0.0];
        let q = vec![0.0, 1.0];
        assert!(kl_divergence(&p, &q) >= 1e299);
    }

    #[test]
    fn mean_kl_identical_logits_zero() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        assert!(mean_kl_from_logits(&m, &m) < 1e-14);
    }

    #[test]
    fn mean_kl_grows_with_perturbation() {
        use crate::util::Rng;
        let mut rng = Rng::new(1);
        let m = Matrix::randn(8, 16, 1.0, &mut rng);
        let small = m.map(|x| x + 0.01);
        // Constant shifts cancel in softmax: still ~0.
        assert!(mean_kl_from_logits(&m, &small) < 1e-10);
        let mut rng2 = Rng::new(2);
        let bumpy = Matrix::from_vec(
            8,
            16,
            m.data().iter().map(|&x| x + 0.1 * rng2.normal_f32()).collect(),
        )
        .unwrap();
        let big = Matrix::from_vec(
            8,
            16,
            m.data().iter().map(|&x| x + 1.0 * rng2.normal_f32()).collect(),
        )
        .unwrap();
        let kl_small = mean_kl_from_logits(&m, &bumpy);
        let kl_big = mean_kl_from_logits(&m, &big);
        assert!(kl_big > kl_small, "big={kl_big} small={kl_small}");
    }
}
