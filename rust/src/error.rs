//! Crate-wide error type.
//!
//! No external error crates are available offline, so we hand-roll a small
//! enum that covers the failure surface of the library: I/O, artifact
//! parsing, runtime (PJRT) failures, configuration and shape errors.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the LAMP library.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure (file missing, short read, ...).
    Io(std::io::Error),
    /// A `.lamp` tensor file or `.kv` metadata file failed to parse.
    Format(String),
    /// Configuration error: unknown key, invalid value, missing artifact.
    Config(String),
    /// Tensor shape mismatch in linear algebra or model plumbing.
    Shape(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Coordinator-level failure (queue closed, worker died, ...).
    Coordinator(String),
    /// A bounded resource (KV block pool, slot budget) is exhausted —
    /// retryable: the scheduler turns this into preempt-then-recompute
    /// rather than failing the request.
    Resource(String),
    /// A transient failure (injected fault, I/O blip) that is expected to
    /// clear on retry. Retryable in place: the failing step changed no
    /// session state, so re-feeding the same token is safe.
    Transient(String),
    /// A request exceeded its deadline or a run exceeded its step/wall
    /// budget. Terminal for the affected request.
    Timeout(String),
    /// A request was canceled through its `CancelToken`. Terminal.
    Canceled(String),
    /// An invariant that should be unreachable was violated.
    Invariant(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Resource(m) => write!(f, "resource exhausted: {m}"),
            Error::Transient(m) => write!(f, "transient fault: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Canceled(m) => write!(f, "canceled: {m}"),
            Error::Invariant(m) => write!(f, "invariant violation: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Shorthand constructors used across the crate.
impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
    pub fn resource(msg: impl Into<String>) -> Self {
        Error::Resource(msg.into())
    }
    pub fn transient(msg: impl Into<String>) -> Self {
        Error::Transient(msg.into())
    }
    pub fn timeout(msg: impl Into<String>) -> Self {
        Error::Timeout(msg.into())
    }
    pub fn canceled(msg: impl Into<String>) -> Self {
        Error::Canceled(msg.into())
    }
    pub fn invariant(msg: impl Into<String>) -> Self {
        Error::Invariant(msg.into())
    }

    /// True for resource exhaustion specifically — the scheduler's
    /// preempt-then-recompute trigger (frees blocks held by a victim).
    pub fn is_resource(&self) -> bool {
        matches!(self, Error::Resource(_))
    }

    /// True for failures that may clear if the same step is attempted
    /// again: resource exhaustion (blocks can be freed by retiring
    /// co-tenants) and transient faults (expected to pass). Timeouts,
    /// cancellations and everything else are terminal.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Error::Resource(_) | Error::Transient(_))
    }

    /// True for deadline/budget expiry.
    pub fn is_timeout(&self) -> bool {
        matches!(self, Error::Timeout(_))
    }

    /// True for explicit cancellation.
    pub fn is_canceled(&self) -> bool {
        matches!(self, Error::Canceled(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_roundtrip() {
        let e = Error::config("bad key");
        assert!(e.to_string().contains("bad key"));
        let e = Error::shape("2x3 vs 4x5");
        assert!(e.to_string().contains("2x3"));
    }

    #[test]
    fn retryable_taxonomy() {
        assert!(Error::resource("pool dry").is_retryable());
        assert!(Error::transient("blip").is_retryable());
        assert!(!Error::timeout("deadline").is_retryable());
        assert!(!Error::canceled("user").is_retryable());
        assert!(!Error::runtime("nan").is_retryable());
        assert!(Error::resource("pool dry").is_resource());
        assert!(!Error::transient("blip").is_resource());
        assert!(Error::timeout("t").is_timeout());
        assert!(Error::canceled("c").is_canceled());
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("nope"));
    }
}
