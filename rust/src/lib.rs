//! # LAMP — Look-Ahead Mixed-Precision Inference of Large Language Models
//!
//! Full-system reproduction of Budzinskiy et al., *LAMP: Look-Ahead
//! Mixed-Precision Inference of Large Language Models* (2026), as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas kernels (build-time Python, `python/compile/kernels/`):
//!   PS(μ) rounding, PS(μ)-accumulated matmul, LAMP attention.
//! * **L2** — JAX GPT-2 forward pass lowered to HLO text artifacts.
//! * **L3** — this crate: the serving coordinator, the PJRT runtime that
//!   loads and executes the artifacts, a bit-exact native reference engine,
//!   synthetic-corpus generators, metrics, and the experiment harness that
//!   regenerates every figure and table of the paper.
//!
//! See `DESIGN.md` for the system inventory and the experiment index and
//! `EXPERIMENTS.md` for measured results.
//!
//! ## Quick tour
//!
//! * [`softfloat`] — the PS(μ) custom floating-point format of paper §4.1
//!   (μ mantissa bits, 8 exponent bits, RNE) and mixed-precision dot
//!   products with per-step rounding.
//! * [`lamp`] — the look-ahead mixed-precision selection rules: strict
//!   softmax LAMP (eq. 8), relaxed relative-threshold LAMP (eq. 9),
//!   length-normalized LAMP (App. C.5), componentwise LAMP for activations
//!   (§3.1) and RMS-norm (§3.2), the generic Algorithm 1, and the
//!   Appendix-B counterexamples.
//! * [`model`] — a GPT-2-architecture transformer with PS(μ)-accumulated KQ
//!   inner products and LAMP recomputation, fully instrumented.
//! * [`runtime`] — PJRT wrapper: load `artifacts/*.hlo.txt`, compile once,
//!   execute from the request path.
//! * [`coordinator`] — request router, dynamic batcher, precision-policy
//!   router, engine pool, serving loop.
//! * [`experiments`] — drivers for Figures 1–7 and Table 1.

pub mod benchkit;
pub mod check;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod lamp;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod softfloat;
pub mod tensorio;
pub mod util;

pub use error::{Error, Result};
