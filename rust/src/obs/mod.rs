//! The runtime observability plane: a unified metrics registry, span
//! tracing, and the clock they share — the sensor side of the adaptive
//! control loop (ROADMAP item 4).
//!
//! * [`metrics`] — a central [`metrics::Registry`] of named counters,
//!   gauges, and fixed-bucket histograms. Handles are cheap atomics
//!   (lock-free on the hot path; the registry mutex is touched only at
//!   handle creation and snapshot time), and a [`metrics::Snapshot`]
//!   renders to Prometheus text or stable-keyed JSON.
//! * [`trace`] — per-request lifecycle spans (enqueue → admit → prefill
//!   → decode / draft / verify → preempt/resume → retire/fail) into a
//!   bounded drop-oldest ring, with JSONL and Chrome `trace_event`
//!   exporters.
//! * [`timers`] — sampling scoped timers attributing kernel wall time to
//!   precision sites; compiled out entirely unless the `obs-timers`
//!   cargo feature is on.
//! * [`export`] — the minimal hand-rolled JSON helpers shared by the
//!   exporters and the `lamp obs` CLI (no serde offline).
//!
//! ## Inertness contract
//!
//! Instrumentation never feeds back into scheduling or numerics: every
//! per-request stream is bit-identical with tracing/metrics on or off
//! (including chaos and speculative runs), and trials canonical
//! artifacts are byte-identical — `rust/tests/obs_parity.rs` pins this,
//! and `benches/observability.rs` pins the hot-path overhead budget.
//!
//! ## Clocks and determinism under replay
//!
//! An [`ObsHub`] carries either a wall clock (nanoseconds since hub
//! creation) or a *virtual* clock. `coordinator::replay` always drives
//! schedulers on a virtual hub and advances it once per scheduler
//! iteration, so span timestamps — and, with the scheduler's
//! iteration-counted retry backoff under virtual clocks, the entire
//! span stream — are deterministic across reruns of the same trial.
//!
//! The offline *accuracy* metrics (KL divergence, flip rate, Pareto
//! frontiers) live in [`crate::metrics`]; this module is the runtime
//! twin.

pub mod export;
pub mod metrics;
pub mod timers;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, Registry, Snapshot};
pub use trace::{SpanEvent, SpanKind, Tracer};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// The clock a hub stamps spans with: host wall time, or a virtual tick
/// advanced externally (one tick per scheduler iteration under replay).
enum Clock {
    Wall(Instant),
    Virtual(AtomicU64),
}

/// One observability context: a metrics registry, an optional tracer,
/// and the clock both share. Cloned via `Arc` into every component that
/// reports; a scheduler given no hub creates a private wall-clock one,
/// so the reporting code paths are identical with observability on or
/// off (the inertness argument is "same code, different sink").
pub struct ObsHub {
    registry: Registry,
    tracer: Option<Arc<Tracer>>,
    clock: Arc<Clock>,
}

impl Default for ObsHub {
    fn default() -> Self {
        Self::new()
    }
}

impl ObsHub {
    /// Wall-clock hub with metrics only.
    pub fn new() -> Self {
        ObsHub {
            registry: Registry::new(),
            tracer: None,
            clock: Arc::new(Clock::Wall(Instant::now())),
        }
    }

    /// Attach a span tracer with the given ring capacity.
    pub fn with_tracer(mut self, capacity: usize) -> Self {
        self.tracer = Some(Arc::new(Tracer::new(capacity)));
        self
    }

    /// Switch to a virtual clock (starts at tick 0; see
    /// [`Self::set_virtual`]).
    pub fn with_virtual_clock(mut self) -> Self {
        self.clock = Arc::new(Clock::Virtual(AtomicU64::new(0)));
        self
    }

    /// A child hub: fresh registry, shared tracer and clock. The server
    /// gives each scheduler drive a child so per-drive deltas stay
    /// separable, then folds the child's snapshot back via
    /// [`Registry::absorb`].
    pub fn child(&self) -> Self {
        ObsHub {
            registry: Registry::new(),
            tracer: self.tracer.clone(),
            clock: Arc::clone(&self.clock),
        }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Current timestamp in clock ticks: nanoseconds since hub creation
    /// (wall) or the virtual tick.
    pub fn now(&self) -> u64 {
        match &*self.clock {
            Clock::Wall(epoch) => epoch.elapsed().as_nanos() as u64,
            Clock::Virtual(t) => t.load(Ordering::Relaxed),
        }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(&*self.clock, Clock::Virtual(_))
    }

    /// Advance the virtual clock; no-op on wall-clock hubs.
    pub fn set_virtual(&self, tick: u64) {
        if let Clock::Virtual(t) = &*self.clock {
            t.store(tick, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic_and_not_virtual() {
        let hub = ObsHub::new();
        assert!(!hub.is_virtual());
        let a = hub.now();
        let b = hub.now();
        assert!(b >= a);
        hub.set_virtual(99); // no-op on wall hubs
        assert!(hub.now() < u64::MAX);
    }

    #[test]
    fn virtual_clock_reads_back_ticks_and_children_share_it() {
        let hub = ObsHub::new().with_virtual_clock().with_tracer(16);
        assert!(hub.is_virtual());
        assert_eq!(hub.now(), 0);
        hub.set_virtual(7);
        assert_eq!(hub.now(), 7);
        let child = hub.child();
        assert!(child.is_virtual());
        assert_eq!(child.now(), 7, "children share the parent clock");
        assert!(child.tracer().is_some(), "children share the parent tracer");
        // But not the registry: child counters stay separate.
        child.registry().counter("x").inc();
        assert_eq!(hub.registry().snapshot().counter("x"), None);
    }
}
