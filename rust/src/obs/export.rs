//! Minimal hand-rolled JSON helpers shared by the obs exporters and the
//! `lamp obs` CLI (no serde offline).
//!
//! These are *format-specific* scanners for the line-oriented JSON this
//! crate itself writes (registry snapshots, span JSONL), in the same
//! spirit as `benchkit::record_bench_section`'s reader — not a general
//! JSON parser.

/// Escape a string for embedding in a JSON string literal (backslash,
/// quote, and control characters).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 as a JSON value (`null` for non-finite).
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Locate the raw value text of `"key":` inside a single-line JSON
/// object, returning the value slice with surrounding whitespace
/// stripped (string values keep their quotes).
fn raw_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":");
    let at = line.find(&needle)? + needle.len();
    let rest = line[at..].trim_start();
    if let Some(inner) = rest.strip_prefix('"') {
        // String value: scan to the closing unescaped quote.
        let mut esc = false;
        for (i, c) in inner.char_indices() {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                return Some(&rest[..i + 2]);
            }
        }
        None
    } else if rest.starts_with('[') {
        let end = rest.find(']')?;
        Some(&rest[..=end])
    } else {
        let end = rest
            .find(|c: char| c == ',' || c == '}' || c == ']')
            .unwrap_or(rest.len());
        Some(rest[..end].trim())
    }
}

/// Extract an unescaped string field from a single-line JSON object.
pub fn str_field(line: &str, key: &str) -> Option<String> {
    let raw = raw_field(line, key)?;
    let inner = raw.strip_prefix('"')?.strip_suffix('"')?;
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                if let Some(c) =
                    u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32)
                {
                    out.push(c);
                }
            }
            Some(other) => out.push(other),
            None => {}
        }
    }
    Some(out)
}

/// Extract a u64 field from a single-line JSON object.
pub fn u64_field(line: &str, key: &str) -> Option<u64> {
    raw_field(line, key)?.parse().ok()
}

/// Extract an f64 field from a single-line JSON object.
pub fn f64_field(line: &str, key: &str) -> Option<f64> {
    raw_field(line, key)?.parse().ok()
}

/// Extract a flat numeric array field (`"key": [1, 2.5, 3]`) from a
/// single-line JSON object.
pub fn f64_array_field(line: &str, key: &str) -> Option<Vec<f64>> {
    let raw = raw_field(line, key)?;
    let inner = raw.strip_prefix('[')?.strip_suffix(']')?.trim();
    if inner.is_empty() {
        return Some(Vec::new());
    }
    inner.split(',').map(|s| s.trim().parse().ok()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escape_round_trips_through_str_field() {
        let nasty = "a\"b\\c\nd\te";
        let line = format!("{{\"k\": \"{}\", \"n\": 3}}", json_escape(nasty));
        assert_eq!(str_field(&line, "k").as_deref(), Some(nasty));
        assert_eq!(u64_field(&line, "n"), Some(3));
    }

    #[test]
    fn numeric_and_array_fields() {
        let line = "{\"a\": 7, \"b\": 0.5, \"xs\": [1, 2.5, 3], \"empty\": [], \"s\": \"t\"}";
        assert_eq!(u64_field(line, "a"), Some(7));
        assert_eq!(f64_field(line, "b"), Some(0.5));
        assert_eq!(f64_array_field(line, "xs"), Some(vec![1.0, 2.5, 3.0]));
        assert_eq!(f64_array_field(line, "empty"), Some(vec![]));
        assert_eq!(u64_field(line, "missing"), None);
        // A string value is not a number.
        assert_eq!(u64_field(line, "s"), None);
    }

    #[test]
    fn json_f64_handles_non_finite() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }
}
