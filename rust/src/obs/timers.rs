//! Sampling scoped timers attributing kernel wall time to precision
//! sites — compiled out entirely unless the `obs-timers` cargo feature
//! is enabled.
//!
//! With the feature **off** (the default), [`scoped`] returns a
//! zero-sized guard with no `Drop` impl and every other entry point is
//! an inlined no-op: the instrumented kernels pay nothing, which is how
//! the ≤2% hot-path overhead budget holds for default builds.
//!
//! With the feature **on**, every 64th call per site takes two
//! `Instant` readings and accumulates elapsed nanoseconds into a static
//! per-site slot (relaxed atomics; timing never feeds back into
//! numerics, so streams stay bit-identical). [`publish`] folds the
//! slots into a registry as `site_time.<site>.{calls,sampled,ns}`
//! counters, which `lamp serve --metrics-out` then exports.

use super::metrics::Registry;

/// The instrumented precision sites (the four plan sites of
/// `model::PrecisionPlan` plus the format-dispatched weight matvec).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Site {
    Attention,
    Mlp,
    Norm,
    Sampler,
    Matvec,
}

/// Every site, in slot order.
pub const SITES: [Site; 5] =
    [Site::Attention, Site::Mlp, Site::Norm, Site::Sampler, Site::Matvec];

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::Attention => "attention",
            Site::Mlp => "mlp",
            Site::Norm => "norm",
            Site::Sampler => "sampler",
            Site::Matvec => "matvec",
        }
    }

    fn index(self) -> usize {
        match self {
            Site::Attention => 0,
            Site::Mlp => 1,
            Site::Norm => 2,
            Site::Sampler => 3,
            Site::Matvec => 4,
        }
    }
}

#[cfg(feature = "obs-timers")]
mod imp {
    use super::{Registry, Site, SITES};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Instant;

    /// Sample every 64th call per site: cheap enough for per-row kernel
    /// entry points, frequent enough to attribute wall time.
    const SAMPLE_MASK: u64 = 63;

    struct Slot {
        calls: AtomicU64,
        sampled: AtomicU64,
        ns: AtomicU64,
    }

    #[allow(clippy::declare_interior_mutable_const)]
    const EMPTY_SLOT: Slot =
        Slot { calls: AtomicU64::new(0), sampled: AtomicU64::new(0), ns: AtomicU64::new(0) };
    static SLOTS: [Slot; 5] = [EMPTY_SLOT; 5];

    /// Timer guard; records elapsed time on drop when this call was
    /// sampled.
    pub struct Scoped {
        slot: usize,
        started: Option<Instant>,
    }

    impl Drop for Scoped {
        fn drop(&mut self) {
            if let Some(t0) = self.started {
                let slot = &SLOTS[self.slot];
                slot.sampled.fetch_add(1, Ordering::Relaxed);
                slot.ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    pub fn scoped(site: Site) -> Scoped {
        let slot = site.index();
        let n = SLOTS[slot].calls.fetch_add(1, Ordering::Relaxed);
        let started = if n & SAMPLE_MASK == 0 { Some(Instant::now()) } else { None };
        Scoped { slot, started }
    }

    pub fn enabled() -> bool {
        true
    }

    /// Fold the per-site slots into `registry` as
    /// `site_time.<site>.{calls,sampled,ns}` counters (set-once add of
    /// the current totals; callers publish into a fresh registry or
    /// snapshot deltas themselves).
    pub fn publish(registry: &Registry) {
        for site in SITES {
            let slot = &SLOTS[site.index()];
            let name = site.name();
            registry
                .counter(&format!("site_time.{name}.calls"))
                .add(slot.calls.load(Ordering::Relaxed));
            registry
                .counter(&format!("site_time.{name}.sampled"))
                .add(slot.sampled.load(Ordering::Relaxed));
            registry
                .counter(&format!("site_time.{name}.ns"))
                .add(slot.ns.load(Ordering::Relaxed));
        }
    }

    /// Zero every slot (test isolation).
    pub fn reset() {
        for slot in &SLOTS {
            slot.calls.store(0, Ordering::Relaxed);
            slot.sampled.store(0, Ordering::Relaxed);
            slot.ns.store(0, Ordering::Relaxed);
        }
    }
}

#[cfg(not(feature = "obs-timers"))]
mod imp {
    use super::{Registry, Site};

    /// Zero-sized no-op guard (no `Drop` impl — dropping it compiles to
    /// nothing).
    pub struct Scoped;

    #[inline(always)]
    pub fn scoped(_site: Site) -> Scoped {
        Scoped
    }

    #[inline(always)]
    pub fn enabled() -> bool {
        false
    }

    #[inline(always)]
    pub fn publish(_registry: &Registry) {}

    #[inline(always)]
    pub fn reset() {}
}

pub use imp::{enabled, publish, reset, scoped, Scoped};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_is_droppable_either_way() {
        let g = scoped(Site::Attention);
        drop(g);
        for site in SITES {
            assert!(!site.name().is_empty());
        }
    }

    #[cfg(feature = "obs-timers")]
    #[test]
    fn sampled_timings_publish_as_counters() {
        // The slots are global and other tests (whole-model forwards)
        // hit them concurrently, so assert on lower bounds, not totals.
        for _ in 0..130 {
            let _t = scoped(Site::Mlp);
        }
        let reg = Registry::new();
        publish(&reg);
        let snap = reg.snapshot();
        assert!(snap.counter("site_time.mlp.calls").unwrap_or(0) >= 130);
        // At least calls 0 and 64 of our burst were sampled.
        assert!(snap.counter("site_time.mlp.sampled").unwrap_or(0) >= 2);
        assert!(enabled());
    }

    #[cfg(not(feature = "obs-timers"))]
    #[test]
    fn disabled_timers_publish_nothing() {
        let reg = Registry::new();
        publish(&reg);
        assert!(reg.snapshot().counters.is_empty());
        assert!(!enabled());
    }
}
