//! The unified metrics registry: named counters, gauges, and
//! fixed-bucket histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`s over
//! atomics — updating one is a relaxed atomic op, never a lock. The
//! registry's mutex guards only the name → handle map, touched at
//! handle creation and [`Registry::snapshot`] time. Bucket boundaries
//! are fixed at histogram creation, so two runs of the same workload
//! produce structurally identical snapshots.
//!
//! Percentile convention: exact-sample percentiles everywhere in the
//! crate go through `metrics::stats::percentile` (nearest-rank); a
//! histogram's [`Histogram::quantile`] reuses the same
//! `nearest_rank_index` rank rule over its bucket counts and returns
//! the containing bucket's upper bound — a coarse export-side view,
//! never a second percentile implementation.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::export;
use crate::metrics::stats::nearest_rank_index;

/// Monotonic counter handle.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (f64 stored as bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistogramCore {
    /// Upper bounds of the finite buckets, strictly increasing; an
    /// implicit overflow bucket follows the last bound.
    bounds: Vec<f64>,
    /// One slot per finite bucket plus the overflow bucket.
    counts: Vec<AtomicU64>,
    /// Sum of observed values (f64 bits, CAS-accumulated).
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram handle.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    pub fn observe(&self, v: f64) {
        self.observe_n(v, 1);
    }

    /// Record `n` observations of value `v` (used when folding
    /// pre-aggregated counts, e.g. a retired session's acceptance
    /// histogram).
    pub fn observe_n(&self, v: f64, n: u64) {
        if n == 0 {
            return;
        }
        let idx = self.0.bounds.partition_point(|&b| b < v);
        self.0.counts[idx].fetch_add(n, Ordering::Relaxed);
        let mut cur = self.0.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v * n as f64).to_bits();
            match self.0.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.0.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Coarse quantile from bucket counts: the nearest-rank index rule
    /// of `metrics::stats` applied to the bucketed distribution,
    /// reporting the containing bucket's upper bound (the last finite
    /// bound for the overflow bucket). 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = nearest_rank_index(total as usize, q);
        let mut seen = 0usize;
        for (i, c) in self.0.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed) as usize;
            if seen > rank {
                let j = i.min(self.0.bounds.len().saturating_sub(1));
                return self.0.bounds.get(j).copied().unwrap_or(0.0);
            }
        }
        self.0.bounds.last().copied().unwrap_or(0.0)
    }
}

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    hists: BTreeMap<String, Arc<HistogramCore>>,
}

/// The central name → instrument map. Handle lookups lock; handle
/// updates do not.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// Get or create the named counter.
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.inner.lock().expect("obs registry poisoned");
        Counter(Arc::clone(
            inner.counters.entry(name.to_string()).or_default(),
        ))
    }

    /// Get or create the named gauge (initial value 0.0).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.inner.lock().expect("obs registry poisoned");
        Gauge(Arc::clone(
            inner
                .gauges
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0.0f64.to_bits()))),
        ))
    }

    /// Get or create the named histogram. Bounds must be strictly
    /// increasing; when the name already exists its original bounds win
    /// (bucket layout is fixed for the registry's lifetime).
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        assert!(!bounds.is_empty(), "histogram needs at least one bound");
        let mut inner = self.inner.lock().expect("obs registry poisoned");
        Histogram(Arc::clone(inner.hists.entry(name.to_string()).or_insert_with(
            || {
                Arc::new(HistogramCore {
                    bounds: bounds.to_vec(),
                    counts: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
                    sum_bits: AtomicU64::new(0.0f64.to_bits()),
                })
            },
        )))
    }

    /// Ordered point-in-time copy of every instrument.
    pub fn snapshot(&self) -> Snapshot {
        let inner = self.inner.lock().expect("obs registry poisoned");
        Snapshot {
            counters: inner
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), f64::from_bits(v.load(Ordering::Relaxed))))
                .collect(),
            hists: inner
                .hists
                .iter()
                .map(|(k, h)| HistSnapshot {
                    name: k.clone(),
                    bounds: h.bounds.clone(),
                    counts: h.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
                    sum: f64::from_bits(h.sum_bits.load(Ordering::Relaxed)),
                })
                .collect(),
        }
    }

    /// Fold a snapshot into this registry: counters and histogram
    /// buckets add, gauges take the snapshot's value. Histograms whose
    /// bucket layout disagrees with an existing instrument of the same
    /// name are skipped (layouts are fixed per name). This is how the
    /// server accumulates per-drive child registries.
    pub fn absorb(&self, snap: &Snapshot) {
        for (name, v) in &snap.counters {
            if *v > 0 {
                self.counter(name).add(*v);
            }
        }
        for (name, v) in &snap.gauges {
            self.gauge(name).set(*v);
        }
        for h in &snap.hists {
            let hist = self.histogram(&h.name, &h.bounds);
            if hist.0.bounds != h.bounds || hist.0.counts.len() != h.counts.len() {
                continue;
            }
            for (slot, &n) in hist.0.counts.iter().zip(&h.counts) {
                if n > 0 {
                    slot.fetch_add(n, Ordering::Relaxed);
                }
            }
            let mut cur = hist.0.sum_bits.load(Ordering::Relaxed);
            loop {
                let next = (f64::from_bits(cur) + h.sum).to_bits();
                match hist.0.sum_bits.compare_exchange_weak(
                    cur,
                    next,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSnapshot {
    pub name: String,
    pub bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts.len() == bounds.len() + 1`
    /// (the last slot is the overflow bucket).
    pub counts: Vec<u64>,
    pub sum: f64,
}

/// Ordered point-in-time copy of a registry, renderable as Prometheus
/// text or stable-keyed JSON (and parseable back for the `lamp obs`
/// CLI).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<HistSnapshot>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }

    /// Stable-keyed JSON: three sections, entries in registry (BTreeMap)
    /// order, one instrument per line.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"counters\": {\n");
        let counter_lines = self
            .counters
            .iter()
            .map(|(k, v)| format!("    \"{}\": {v}", export::json_escape(k)))
            .collect::<Vec<_>>()
            .join(",\n");
        out.push_str(&counter_lines);
        out.push_str("\n  },\n  \"gauges\": {\n");
        let gauge_lines = self
            .gauges
            .iter()
            .map(|(k, v)| {
                format!("    \"{}\": {}", export::json_escape(k), export::json_f64(*v))
            })
            .collect::<Vec<_>>()
            .join(",\n");
        out.push_str(&gauge_lines);
        out.push_str("\n  },\n  \"histograms\": {\n");
        let hist_lines = self
            .hists
            .iter()
            .map(|h| {
                format!(
                    "    \"{}\": {{\"bounds\": [{}], \"counts\": [{}], \"sum\": {}}}",
                    export::json_escape(&h.name),
                    h.bounds.iter().map(|b| export::json_f64(*b)).collect::<Vec<_>>().join(", "),
                    h.counts.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(", "),
                    export::json_f64(h.sum)
                )
            })
            .collect::<Vec<_>>()
            .join(",\n");
        out.push_str(&hist_lines);
        out.push_str("\n  }\n}\n");
        out
    }

    /// Parse the format [`Self::to_json`] writes (line-oriented, like
    /// the BENCH record reader — not a general JSON parser).
    pub fn from_json(text: &str) -> crate::error::Result<Snapshot> {
        let mut snap = Snapshot::default();
        let mut section = "";
        for line in text.lines() {
            let trimmed = line.trim().trim_end_matches(',');
            if trimmed.is_empty() || trimmed == "{" || trimmed == "}" {
                continue;
            }
            match trimmed {
                "\"counters\": {" => {
                    section = "counters";
                    continue;
                }
                "\"gauges\": {" => {
                    section = "gauges";
                    continue;
                }
                "\"histograms\": {" => {
                    section = "histograms";
                    continue;
                }
                _ => {}
            }
            let Some((key, val)) = trimmed.split_once(':') else { continue };
            let name = key.trim().trim_matches('"').to_string();
            let val = val.trim();
            match section {
                "counters" => {
                    let v = val.parse::<u64>().map_err(|_| {
                        crate::error::Error::config(format!("bad counter value: {trimmed}"))
                    })?;
                    snap.counters.push((name, v));
                }
                "gauges" => {
                    let v = val.parse::<f64>().map_err(|_| {
                        crate::error::Error::config(format!("bad gauge value: {trimmed}"))
                    })?;
                    snap.gauges.push((name, v));
                }
                "histograms" => {
                    let bounds = export::f64_array_field(val, "bounds").ok_or_else(|| {
                        crate::error::Error::config(format!("histogram missing bounds: {trimmed}"))
                    })?;
                    let counts = export::f64_array_field(val, "counts")
                        .map(|v| v.into_iter().map(|x| x as u64).collect::<Vec<_>>())
                        .ok_or_else(|| {
                            crate::error::Error::config(format!(
                                "histogram missing counts: {trimmed}"
                            ))
                        })?;
                    let sum = export::f64_field(val, "sum").unwrap_or(0.0);
                    snap.hists.push(HistSnapshot { name, bounds, counts, sum });
                }
                _ => {}
            }
        }
        Ok(snap)
    }

    /// Prometheus text exposition: counters, gauges, and cumulative
    /// histogram buckets with `+Inf`, `_sum`, `_count` series.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for h in &self.hists {
            let n = prom_name(&h.name);
            out.push_str(&format!("# TYPE {n} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in h.counts.iter().enumerate() {
                cum += c;
                let le = match h.bounds.get(i) {
                    Some(b) => b.to_string(),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{n}_sum {}\n{n}_count {cum}\n", h.sum));
        }
        out
    }
}

/// Sanitize a registry name into the Prometheus charset.
fn prom_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_round_trip() {
        let r = Registry::new();
        let c = r.counter("sched.steps");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same name, same instrument.
        assert_eq!(r.counter("sched.steps").get(), 5);
        let g = r.gauge("kv.occupancy");
        g.set(0.75);
        assert_eq!(g.get(), 0.75);
        let snap = r.snapshot();
        assert_eq!(snap.counter("sched.steps"), Some(5));
        assert_eq!(snap.gauge("kv.occupancy"), Some(0.75));
        assert_eq!(snap.counter("missing"), None);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let r = Registry::new();
        let h = r.histogram("lat", &[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 106.5).abs() < 1e-12);
        let snap = r.snapshot();
        let hs = snap.hist("lat").unwrap();
        assert_eq!(hs.counts, vec![1, 2, 1, 1]);
        // Boundary values land in the bucket whose upper bound they equal.
        h.observe(2.0);
        assert_eq!(r.snapshot().hist("lat").unwrap().counts, vec![1, 3, 1, 1]);
        // Median of 6 observations: rank 3 falls in the le=2 bucket.
        assert_eq!(h.quantile(0.5), 2.0);
        // Max quantile lands in overflow, reported as the last bound.
        assert_eq!(h.quantile(1.0), 4.0);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let r = Registry::new();
        r.counter("a.count").add(3);
        r.gauge("b.rate").set(0.125);
        r.histogram("c.lat", &[0.5, 1.0]).observe(0.7);
        let snap = r.snapshot();
        let json = snap.to_json();
        let back = Snapshot::from_json(&json).unwrap();
        assert_eq!(back, snap);
        // Deterministic output: render twice, identical bytes.
        assert_eq!(json, r.snapshot().to_json());
    }

    #[test]
    fn prometheus_rendering_is_cumulative() {
        let r = Registry::new();
        r.counter("sched.steps").add(2);
        r.histogram("lat", &[1.0, 2.0]).observe(0.5);
        r.histogram("lat", &[1.0, 2.0]).observe(5.0);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE sched_steps counter"), "{text}");
        assert!(text.contains("sched_steps 2"), "{text}");
        assert!(text.contains("lat_bucket{le=\"1\"} 1"), "{text}");
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("lat_count 2"), "{text}");
    }

    #[test]
    fn absorb_adds_counters_and_merges_histograms() {
        let parent = Registry::new();
        parent.counter("n").add(1);
        parent.histogram("h", &[1.0]).observe(0.5);
        let child = Registry::new();
        child.counter("n").add(2);
        child.gauge("g").set(3.0);
        child.histogram("h", &[1.0]).observe(2.0);
        parent.absorb(&child.snapshot());
        let snap = parent.snapshot();
        assert_eq!(snap.counter("n"), Some(3));
        assert_eq!(snap.gauge("g"), Some(3.0));
        let h = snap.hist("h").unwrap();
        assert_eq!(h.counts, vec![1, 1]);
        assert!((h.sum - 2.5).abs() < 1e-12);
        // Mismatched layout: skipped, not corrupted.
        let odd = Registry::new();
        odd.histogram("h", &[9.0]).observe(1.0);
        parent.absorb(&odd.snapshot());
        assert_eq!(parent.snapshot().hist("h").unwrap().counts, vec![1, 1]);
    }
}
