//! Per-request span tracing: the scheduler records one [`SpanEvent`]
//! per lifecycle transition and per unit of work into a bounded
//! drop-oldest ring.
//!
//! Timestamps are [`ObsHub::now`](super::ObsHub::now) ticks —
//! nanoseconds on a wall-clock hub, scheduler iterations on a virtual
//! one — so traces recorded under `coordinator::replay` are
//! deterministic across reruns. Export as JSONL (one span per line,
//! parseable by [`parse_jsonl`] for CLI filtering) or as Chrome
//! `trace_event` JSON for flamegraph-style inspection in
//! `chrome://tracing` / Perfetto (`tid` = request id, so each request
//! renders as its own track).

use std::collections::VecDeque;
use std::sync::Mutex;

use super::export;

/// What a span covers in the request lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpanKind {
    /// Request entered the waiting queue.
    Enqueue,
    /// Request bound to a slot (fresh admission).
    Admit,
    /// Preempted request re-bound to a slot for prefix recompute.
    Resume,
    /// One prefill chunk fed.
    Prefill,
    /// One committed decode step (sample + feed).
    Decode,
    /// One speculative draft step against scratch KV.
    Draft,
    /// One batched verify + commit of a speculation round.
    Verify,
    /// Slot preempted on pool exhaustion; progress requeued.
    Preempt,
    /// Request retired normally.
    Retire,
    /// Request failed terminally.
    Fail,
    /// An iteration that performed no unit of work (default).
    #[default]
    Idle,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Admit => "admit",
            SpanKind::Resume => "resume",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::Draft => "draft",
            SpanKind::Verify => "verify",
            SpanKind::Preempt => "preempt",
            SpanKind::Retire => "retire",
            SpanKind::Fail => "fail",
            SpanKind::Idle => "idle",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        Some(match s {
            "enqueue" => SpanKind::Enqueue,
            "admit" => SpanKind::Admit,
            "resume" => SpanKind::Resume,
            "prefill" => SpanKind::Prefill,
            "decode" => SpanKind::Decode,
            "draft" => SpanKind::Draft,
            "verify" => SpanKind::Verify,
            "preempt" => SpanKind::Preempt,
            "retire" => SpanKind::Retire,
            "fail" => SpanKind::Fail,
            "idle" => SpanKind::Idle,
            _ => return None,
        })
    }
}

/// One recorded span. `start`/`end` are hub clock ticks; instantaneous
/// lifecycle markers record `start == end`.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub request: u64,
    pub kind: SpanKind,
    pub start: u64,
    pub end: u64,
    /// Small free-form annotation (e.g. `tokens=3`); empty when unused.
    pub detail: String,
}

struct TracerInner {
    events: VecDeque<SpanEvent>,
    dropped: u64,
}

/// Bounded drop-oldest span ring. `record` takes an uncontended mutex:
/// the scheduler only records from its single-threaded harvest/admit
/// paths, never from the parallel slot fan-out.
pub struct Tracer {
    capacity: usize,
    inner: Mutex<TracerInner>,
}

impl Tracer {
    pub fn new(capacity: usize) -> Self {
        Tracer {
            capacity: capacity.max(1),
            inner: Mutex::new(TracerInner { events: VecDeque::new(), dropped: 0 }),
        }
    }

    pub fn record(&self, event: SpanEvent) {
        let mut inner = self.inner.lock().expect("tracer poisoned");
        if inner.events.len() >= self.capacity {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back(event);
    }

    /// Record an instantaneous lifecycle marker (`start == end`, no
    /// detail).
    pub fn instant(&self, request: u64, kind: SpanKind, tick: u64) {
        self.record(SpanEvent { request, kind, start: tick, end: tick, detail: String::new() });
    }

    /// Ordered copy of the ring (oldest first).
    pub fn events(&self) -> Vec<SpanEvent> {
        self.inner.lock().expect("tracer poisoned").events.iter().cloned().collect()
    }

    /// Spans evicted by the ring bound so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().expect("tracer poisoned").dropped
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("tracer poisoned").events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("tracer poisoned");
        inner.events.clear();
        inner.dropped = 0;
    }
}

/// Render spans as JSONL: one stable-keyed object per line.
pub fn to_jsonl(events: &[SpanEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&format!(
            "{{\"request\": {}, \"kind\": \"{}\", \"start\": {}, \"end\": {}, \"detail\": \"{}\"}}\n",
            e.request,
            e.kind.as_str(),
            e.start,
            e.end,
            export::json_escape(&e.detail)
        ));
    }
    out
}

/// Parse the JSONL format [`to_jsonl`] writes; malformed lines are
/// skipped.
pub fn parse_jsonl(text: &str) -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(request) = export::u64_field(line, "request") else { continue };
        let Some(kind) =
            export::str_field(line, "kind").as_deref().and_then(SpanKind::parse)
        else {
            continue;
        };
        let Some(start) = export::u64_field(line, "start") else { continue };
        let Some(end) = export::u64_field(line, "end") else { continue };
        let detail = export::str_field(line, "detail").unwrap_or_default();
        out.push(SpanEvent { request, kind, start, end, detail });
    }
    out
}

/// Render spans as a Chrome `trace_event` JSON array (complete events,
/// `ph: "X"`; load in `chrome://tracing` or Perfetto). `ts`/`dur` are
/// hub ticks; `tid` is the request id so each request gets its own row.
pub fn to_chrome(events: &[SpanEvent]) -> String {
    let body = events
        .iter()
        .map(|e| {
            format!(
                "{{\"name\": \"{}\", \"cat\": \"lamp\", \"ph\": \"X\", \"ts\": {}, \
                 \"dur\": {}, \"pid\": 0, \"tid\": {}, \"args\": {{\"detail\": \"{}\"}}}}",
                e.kind.as_str(),
                e.start,
                e.end.saturating_sub(e.start),
                e.request,
                export::json_escape(&e.detail)
            )
        })
        .collect::<Vec<_>>()
        .join(",\n");
    format!("[\n{body}\n]\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(request: u64, kind: SpanKind, t: u64) -> SpanEvent {
        SpanEvent { request, kind, start: t, end: t + 1, detail: format!("t={t}") }
    }

    #[test]
    fn ring_drops_oldest_and_counts_drops() {
        let tr = Tracer::new(3);
        for t in 0..5 {
            tr.record(span(1, SpanKind::Decode, t));
        }
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.dropped(), 2);
        let starts: Vec<u64> = tr.events().iter().map(|e| e.start).collect();
        assert_eq!(starts, vec![2, 3, 4]);
        tr.clear();
        assert!(tr.is_empty());
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn jsonl_round_trips() {
        let events = vec![
            span(7, SpanKind::Prefill, 0),
            span(7, SpanKind::Decode, 1),
            SpanEvent {
                request: 8,
                kind: SpanKind::Fail,
                start: 2,
                end: 2,
                detail: "error \"quoted\"".to_string(),
            },
        ];
        let text = to_jsonl(&events);
        assert_eq!(text.lines().count(), 3);
        let back = parse_jsonl(&text);
        assert_eq!(back, events);
        // Malformed lines are skipped, not fatal.
        assert_eq!(parse_jsonl("not json\n{\"request\": 1}\n").len(), 0);
    }

    #[test]
    fn chrome_export_is_a_complete_event_array() {
        let text = to_chrome(&[span(3, SpanKind::Verify, 10)]);
        assert!(text.starts_with("[\n"));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"ph\": \"X\""));
        assert!(text.contains("\"tid\": 3"));
        assert!(text.contains("\"ts\": 10"));
        assert!(text.contains("\"dur\": 1"));
    }

    #[test]
    fn span_kinds_round_trip_their_names() {
        for kind in [
            SpanKind::Enqueue,
            SpanKind::Admit,
            SpanKind::Resume,
            SpanKind::Prefill,
            SpanKind::Decode,
            SpanKind::Draft,
            SpanKind::Verify,
            SpanKind::Preempt,
            SpanKind::Retire,
            SpanKind::Fail,
            SpanKind::Idle,
        ] {
            assert_eq!(SpanKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(SpanKind::parse("nope"), None);
    }
}
