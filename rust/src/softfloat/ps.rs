//! The [`Ps`] value type: an FP32 payload constrained to a PS(μ) grid, plus
//! [`PsFormat`] metadata describing the format family of paper §4.1.

use super::round::{round_to_mantissa, unit_roundoff};
use std::fmt;

/// Metadata for the PS(μ) format family: μ mantissa bits, 8 exponent bits,
/// one sign bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PsFormat {
    /// Number of explicit mantissa bits, 1..=23.
    pub mu: u32,
}

impl PsFormat {
    pub const FP32: PsFormat = PsFormat { mu: 23 };
    pub const TF32: PsFormat = PsFormat { mu: 10 };
    pub const BF16: PsFormat = PsFormat { mu: 7 };

    /// Construct; panics unless 1 <= mu <= 23.
    pub fn new(mu: u32) -> Self {
        assert!((1..=23).contains(&mu), "mu={mu} out of range");
        PsFormat { mu }
    }

    /// Unit round-off u = 2^(-μ-1).
    pub fn unit_roundoff(self) -> f64 {
        unit_roundoff(self.mu)
    }

    /// Well-known name if this format matches a standard one.
    pub fn name(self) -> String {
        match self.mu {
            23 => "FP32".to_string(),
            10 => "TF32".to_string(),
            7 => "BF16".to_string(),
            mu => format!("PS({mu})"),
        }
    }

    /// Quantize an f32 onto this format's grid (RNE).
    #[inline]
    pub fn quantize(self, x: f32) -> f32 {
        round_to_mantissa(x, self.mu)
    }
}

impl fmt::Display for PsFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// An FP32 payload guaranteed to lie on the PS(μ) grid.
///
/// Arithmetic is FP32 multiply/add followed by a rounding step — exactly the
/// paper's simulated accumulator `round(c + a·b)`.
#[derive(Debug, Clone, Copy)]
pub struct Ps {
    value: f32,
    fmt: PsFormat,
}

impl Ps {
    /// Quantize `x` into format `fmt`.
    pub fn new(x: f32, fmt: PsFormat) -> Self {
        Ps { value: fmt.quantize(x), fmt }
    }

    /// The FP32 payload (always on the grid).
    #[inline]
    pub fn get(self) -> f32 {
        self.value
    }

    /// The format.
    pub fn format(self) -> PsFormat {
        self.fmt
    }

    /// Fused accumulate: `round(self + a*b)` with FP32 multiply and add.
    #[inline]
    pub fn fma(self, a: f32, b: f32) -> Ps {
        Ps::new(self.value + a * b, self.fmt)
    }

    /// `round(self + rhs)`.
    #[inline]
    pub fn add(self, rhs: f32) -> Ps {
        Ps::new(self.value + rhs, self.fmt)
    }

    /// `round(self * rhs)`.
    #[inline]
    pub fn mul(self, rhs: f32) -> Ps {
        Ps::new(self.value * rhs, self.fmt)
    }
}

impl PartialEq for Ps {
    fn eq(&self, other: &Self) -> bool {
        self.value.to_bits() == other.value.to_bits() && self.fmt == other.fmt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn named_formats() {
        assert_eq!(PsFormat::FP32.name(), "FP32");
        assert_eq!(PsFormat::TF32.name(), "TF32");
        assert_eq!(PsFormat::BF16.name(), "BF16");
        assert_eq!(PsFormat::new(4).name(), "PS(4)");
    }

    #[test]
    fn quantize_on_grid() {
        let f = PsFormat::new(5);
        let q = f.quantize(std::f32::consts::PI);
        assert_eq!(f.quantize(q), q); // idempotent
        let low = q.to_bits() & ((1u32 << 18) - 1);
        assert_eq!(low, 0);
    }

    #[test]
    fn fma_rounds_each_step() {
        // BF16 accumulator: 256 + 0.5 rounds back to 256 (0.5 < half ulp at
        // 256 which is 2^8 * 2^-8 = 1 → tie, rounds to even = 256).
        let acc = Ps::new(256.0, PsFormat::BF16);
        let r = acc.fma(0.5, 1.0);
        assert_eq!(r.get(), 256.0);
        // FP32 accumulator keeps it.
        let acc = Ps::new(256.0, PsFormat::FP32);
        assert_eq!(acc.fma(0.5, 1.0).get(), 256.5);
    }

    #[test]
    fn display() {
        assert_eq!(format!("{}", PsFormat::new(7)), "BF16");
    }
}
