//! Bit-exact rounding of FP32 values to μ mantissa bits.
//!
//! This is the software simulation of the paper's PS(μ) format (§4.1):
//! "we implement PS(μ) numbers via FP32 numbers rounded to μ mantissa bits
//! according to the round-to-nearest-ties-to-even mode".
//!
//! The same bit-twiddling algorithm is implemented in the L1 Pallas kernel
//! (`python/compile/kernels/ps_round.py`); `python/tests/test_ps_round.py`
//! and the cross-layer integration test pin the two implementations to each
//! other through golden vectors.

use crate::util::Rng;

/// Rounding mode for PS(μ) conversion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Round to nearest, ties to even (IEEE default; the paper's mode).
    NearestEven,
    /// Stochastic rounding: round up with probability proportional to the
    /// discarded fraction. Extension discussed in §2.2.1 (c_g ~ √k bound).
    Stochastic,
}

/// Round an FP32 value to `mu` mantissa bits with round-to-nearest-ties-to-even.
///
/// * `mu` must be in `1..=23`; `mu == 23` is the identity.
/// * NaNs and infinities are returned unchanged.
/// * Subnormals are rounded on their raw bit patterns, which matches rounding
///   the subnormal mantissa field (the exponent field is zero).
/// * Mantissa overflow carries into the exponent, which is correct RNE
///   behaviour (e.g. 1.9999 → 2.0); overflow past the max exponent yields ±inf.
#[inline]
pub fn round_to_mantissa(x: f32, mu: u32) -> f32 {
    assert!((1..=23).contains(&mu), "mu={mu} out of range 1..=23");
    if mu == 23 || !x.is_finite() {
        return x;
    }
    let shift = 23 - mu;
    let u = x.to_bits();
    // RNE on the integer representation: add (half-ulp - 1) + lsb-of-kept,
    // then truncate. Sign bit participates only via the kept-field carry,
    // which cannot propagate into it for finite inputs that round to finite
    // values; rounding past f32::MAX correctly lands on the infinity pattern.
    let lsb = (u >> shift) & 1;
    let bias = (1u32 << (shift - 1)) - 1 + lsb;
    let r = (u.wrapping_add(bias) >> shift) << shift;
    f32::from_bits(r)
}

/// Stochastically round an FP32 value to `mu` mantissa bits.
///
/// The discarded low bits `frac` of the mantissa are compared against a
/// uniform random draw; the value rounds away from zero iff
/// `draw < frac / 2^shift`. Unbiased: E[round(x)] = x for finite x.
#[inline]
pub fn round_to_mantissa_stochastic(x: f32, mu: u32, rng: &mut Rng) -> f32 {
    assert!((1..=23).contains(&mu), "mu={mu} out of range 1..=23");
    if mu == 23 || !x.is_finite() {
        return x;
    }
    let shift = 23 - mu;
    let u = x.to_bits();
    let frac = u & ((1u32 << shift) - 1);
    let draw = rng.next_u32() & ((1u32 << shift) - 1);
    let r = if draw < frac {
        ((u >> shift) + 1) << shift
    } else {
        (u >> shift) << shift
    };
    f32::from_bits(r)
}

/// Round with the given [`RoundMode`].
#[inline]
pub fn round_with_mode(x: f32, mu: u32, mode: RoundMode, rng: &mut Rng) -> f32 {
    match mode {
        RoundMode::NearestEven => round_to_mantissa(x, mu),
        RoundMode::Stochastic => round_to_mantissa_stochastic(x, mu, rng),
    }
}

/// The unit in the last place of `x` in the PS(μ) format: the spacing of
/// representable PS(μ) numbers at the magnitude of `x`.
pub fn ulp_at(x: f32, mu: u32) -> f32 {
    assert!((1..=23).contains(&mu));
    if !x.is_finite() {
        return f32::NAN;
    }
    if x == 0.0 {
        // Spacing of subnormal PS(μ) numbers.
        return f32::from_bits(1u32 << (23 - mu));
    }
    let e = (x.abs().to_bits() >> 23) as i32 - 127;
    // ulp = 2^(e - mu); may be subnormal.
    let exp = e - mu as i32;
    if exp >= -126 {
        f32::from_bits(((exp + 127) as u32) << 23)
    } else {
        // Subnormal spacing: 2^exp as a subnormal has its single mantissa
        // bit at position exp + 149 (value of bit p is 2^(p-149)).
        let p = exp + 149;
        if p < 0 {
            0.0
        } else {
            f32::from_bits(1u32 << p as u32)
        }
    }
}

/// The unit round-off u(μ) = 2^(−μ−1) of the PS(μ) format.
pub fn unit_roundoff(mu: u32) -> f64 {
    assert!((1..=23).contains(&mu));
    (2.0f64).powi(-(mu as i32) - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_at_mu23() {
        let xs = [0.0f32, -1.5, 3.14159, 1e-38, 1e38, f32::MIN_POSITIVE];
        for &x in &xs {
            assert_eq!(round_to_mantissa(x, 23).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn bf16_examples() {
        // BF16 = PS(7). 1 + 2^-8 is exactly halfway between 1 and 1+2^-7:
        // ties-to-even rounds down to 1.0.
        let x = 1.0f32 + 2.0f32.powi(-8);
        assert_eq!(round_to_mantissa(x, 7), 1.0);
        // 1 + 3*2^-8 is halfway between 1+2^-7 and 1+2^-6: ties-to-even
        // rounds to even mantissa = 1 + 2^-6.
        let x = 1.0f32 + 3.0 * 2.0f32.powi(-8);
        assert_eq!(round_to_mantissa(x, 7), 1.0 + 2.0f32.powi(-6));
        // Slightly above the tie rounds up.
        let x = 1.0f32 + 2.0f32.powi(-8) + 2.0f32.powi(-20);
        assert_eq!(round_to_mantissa(x, 7), 1.0 + 2.0f32.powi(-7));
    }

    #[test]
    fn sign_symmetry() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 100.0;
            for mu in [1, 4, 7, 10, 16, 23] {
                assert_eq!(
                    round_to_mantissa(-x, mu).to_bits(),
                    (-round_to_mantissa(x, mu)).to_bits(),
                    "x={x} mu={mu}"
                );
            }
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 1e6;
            for mu in [1, 3, 7, 10, 15] {
                let r = round_to_mantissa(x, mu);
                assert_eq!(round_to_mantissa(r, mu).to_bits(), r.to_bits());
            }
        }
    }

    #[test]
    fn error_within_half_ulp() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 1e3;
            for mu in [2, 5, 7, 10, 12] {
                let r = round_to_mantissa(x, mu);
                let rel = ((r - x) / x).abs() as f64;
                // |δ| <= u = 2^(-mu-1) for normal x.
                assert!(
                    rel <= unit_roundoff(mu) * (1.0 + 1e-6),
                    "x={x} mu={mu} rel={rel:e}"
                );
            }
        }
    }

    #[test]
    fn mantissa_bits_cleared() {
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 1e4;
            for mu in [1, 4, 7, 10] {
                let r = round_to_mantissa(x, mu);
                if r.is_finite() {
                    let low = r.to_bits() & ((1u32 << (23 - mu)) - 1);
                    assert_eq!(low, 0, "x={x} mu={mu}");
                }
            }
        }
    }

    #[test]
    fn carry_into_exponent() {
        // Largest PS-representable mantissa rounds up to the next binade.
        let x = 1.9999999f32;
        assert_eq!(round_to_mantissa(x, 4), 2.0);
    }

    #[test]
    fn overflow_to_infinity() {
        let x = f32::MAX; // mantissa all ones
        let r = round_to_mantissa(x, 4);
        assert!(r.is_infinite() && r > 0.0);
    }

    #[test]
    fn specials_passthrough() {
        assert!(round_to_mantissa(f32::NAN, 7).is_nan());
        assert_eq!(round_to_mantissa(f32::INFINITY, 7), f32::INFINITY);
        assert_eq!(round_to_mantissa(f32::NEG_INFINITY, 7), f32::NEG_INFINITY);
        assert_eq!(round_to_mantissa(0.0, 1).to_bits(), 0.0f32.to_bits());
        assert_eq!(round_to_mantissa(-0.0, 1).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn stochastic_unbiased() {
        let mut rng = Rng::new(5);
        // x exactly 1/4 of the way between two PS(4) neighbours.
        let mu = 4;
        let base = 1.0f32;
        let step = 2.0f32.powi(-(mu as i32));
        let x = base + 0.25 * step;
        let n = 100_000;
        let mut ups = 0usize;
        for _ in 0..n {
            let r = round_to_mantissa_stochastic(x, mu, &mut rng);
            assert!(r == base || r == base + step);
            if r == base + step {
                ups += 1;
            }
        }
        let p = ups as f64 / n as f64;
        assert!((p - 0.25).abs() < 0.01, "p={p}");
    }

    #[test]
    fn stochastic_carry_into_exponent() {
        // All 23 mantissa bits set: the kept PS(4) field is maximal and the
        // discarded fraction is 2^19 − 1, so the up-round (probability
        // 1 − 2⁻¹⁹ per draw) must carry cleanly into the exponent — the
        // only representable outcomes are the truncation and the next
        // binade, never a garbled mantissa.
        let mut rng = Rng::new(8);
        let x = f32::from_bits(0x3FFF_FFFF); // just below 2.0
        let down = f32::from_bits((0x3FFF_FFFFu32 >> 19) << 19); // 1.9375
        let mut saw_carry = false;
        for _ in 0..64 {
            let r = round_to_mantissa_stochastic(x, 4, &mut rng);
            assert!(r == 2.0 || r == down, "r={r}");
            saw_carry |= r == 2.0;
        }
        assert!(saw_carry, "carry into the exponent never happened");
        // Same mechanism at the top binade: f32::MAX's up-round is the
        // infinity bit pattern.
        let max_down = f32::from_bits((f32::MAX.to_bits() >> 19) << 19);
        let mut saw_inf = false;
        for _ in 0..64 {
            let r = round_to_mantissa_stochastic(f32::MAX, 4, &mut rng);
            assert!(r == f32::INFINITY || r == max_down, "r={r}");
            saw_inf |= r == f32::INFINITY;
        }
        assert!(saw_inf, "max-mantissa overflow never reached infinity");
    }

    #[test]
    fn stochastic_exact_values_fixed() {
        let mut rng = Rng::new(6);
        // Exactly representable values never move.
        for mu in [2, 7, 12] {
            let x = round_to_mantissa(3.7, mu);
            for _ in 0..100 {
                assert_eq!(round_to_mantissa_stochastic(x, mu, &mut rng), x);
            }
        }
    }

    #[test]
    fn unit_roundoff_values() {
        assert_eq!(unit_roundoff(23), 2.0f64.powi(-24)); // fp32
        assert_eq!(unit_roundoff(10), 2.0f64.powi(-11)); // tf32
        assert_eq!(unit_roundoff(7), 2.0f64.powi(-8)); // bf16
    }

    #[test]
    fn ulp_normal() {
        // At 1.0 <= x < 2, PS(7) ulp is 2^-7.
        assert_eq!(ulp_at(1.0, 7), 2.0f32.powi(-7));
        assert_eq!(ulp_at(1.5, 7), 2.0f32.powi(-7));
        assert_eq!(ulp_at(2.0, 7), 2.0f32.powi(-6));
        assert_eq!(ulp_at(-2.0, 7), 2.0f32.powi(-6));
    }

    #[test]
    fn rounding_moves_at_most_one_ulp() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 256.0;
            for mu in [3, 7, 11] {
                let r = round_to_mantissa(x, mu);
                assert!((r - x).abs() <= 0.5 * ulp_at(x, mu) * 1.0000001,
                    "x={x} mu={mu} r={r}");
            }
        }
    }
}
