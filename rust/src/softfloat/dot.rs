//! Inner products under different accumulation regimes.
//!
//! The paper's mixed-precision model (§4.1): operands are FP32, the
//! multiply-add happens in FP32, and the running accumulator is rounded to
//! PS(μ) after every step — `c ← round(fma(a, b, c))`. The fused
//! multiply-add (one rounding) is the canonical step: it matches both the
//! hardware FMA the XLA CPU backend contracts to (so the native and PJRT
//! engines agree bit-for-bit on PS scores) and the FMA-based
//! mixed-precision algorithms of §2.2.1. LAMP then *recomputes* a selected
//! sparse subset of inner products with a more accurate method (here: FP32
//! accumulation, the paper's choice; Kahan-compensated summation is
//! provided as the "more accurate algorithm" variant of §2.2.1).

use super::round::{round_to_mantissa, round_to_mantissa_stochastic};
use crate::util::Rng;

/// How an inner product is accumulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumMode {
    /// Per-step rounding to PS(μ) with RNE — the paper's low-precision path.
    PsNearest { mu: u32 },
    /// Per-step stochastic rounding to PS(μ) — §2.2.1 extension (c_g ~ √k).
    PsStochastic { mu: u32 },
    /// Plain FP32 accumulation — the paper's recomputation path.
    Fp32,
    /// Kahan-compensated FP32 — "more accurate algorithm" with c_g = O(1).
    Kahan,
}

/// Inner product with per-step PS(μ) rounding (RNE):
/// `c_0 = 0; c_i = round(fma(a_i, b_i, c_{i-1}))`.
#[inline]
pub fn dot_ps(a: &[f32], b: &[f32], mu: u32) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut c = 0.0f32;
    for i in 0..a.len() {
        c = round_to_mantissa(a[i].mul_add(b[i], c), mu);
    }
    c
}

/// Inner product with per-step stochastic PS(μ) rounding.
#[inline]
pub fn dot_ps_stochastic(a: &[f32], b: &[f32], mu: u32, rng: &mut Rng) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut c = 0.0f32;
    for i in 0..a.len() {
        c = round_to_mantissa_stochastic(a[i].mul_add(b[i], c), mu, rng);
    }
    c
}

/// Plain FP32 inner product (sequential FMA order, matching `dot_ps` at
/// μ=23 bit-for-bit).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut c = 0.0f32;
    for i in 0..a.len() {
        c = a[i].mul_add(b[i], c);
    }
    c
}

/// Kahan-compensated inner product: error constant O(1) instead of O(k).
#[inline]
pub fn dot_kahan(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    let mut comp = 0.0f32;
    for i in 0..a.len() {
        let y = a[i] * b[i] - comp;
        let t = s + y;
        comp = (t - s) - y;
        s = t;
    }
    s
}

/// Double-precision reference (used only in tests/metrics, never on the
/// simulated low-precision path).
pub fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Fused causal score-row kernel: computes a whole attention score row in
/// one call,
///
/// ```text
///   out[j] = dot_ps(q, keys[j·stride .. j·stride + q.len()], mu) · scale
///   for j in 0..n
/// ```
///
/// **Bit-identical to the per-dot [`dot_ps`] loop**: each output keeps its
/// own accumulator with exactly the per-step `round(fma(..))` chain of the
/// paper's PS(μ) model; fusion only interleaves *independent* chains so the
/// FMA+round latency of one chain hides behind its neighbours (the chains
/// are serially dependent internally, so a single dot is latency-bound).
/// With a vector backend active the kernel interleaves eight chains per
/// register with a lanewise-identical rounding primitive
/// ([`crate::linalg::simd::score_row_ps_simd`]); otherwise the scalar body
/// below interleaves four — both produce identical bits because the
/// per-output chain never changes. `keys` is the flat row-major K buffer
/// offset to the head's first column; `stride` is the matrix row stride
/// (d_model).
pub fn score_row_ps(
    q: &[f32],
    keys: &[f32],
    stride: usize,
    n: usize,
    mu: u32,
    scale: f32,
    out: &mut [f32],
) {
    let hd = q.len();
    if n == 0 {
        return;
    }
    assert!(out.len() >= n, "score_row_ps: out too short");
    assert!(
        (n - 1) * stride + hd <= keys.len(),
        "score_row_ps: keys buffer too short"
    );
    if crate::linalg::simd::score_row_ps_simd(q, keys, stride, n, mu, scale, out) {
        return;
    }
    let mut j = 0;
    while j + 4 <= n {
        let k0 = &keys[j * stride..j * stride + hd];
        let k1 = &keys[(j + 1) * stride..(j + 1) * stride + hd];
        let k2 = &keys[(j + 2) * stride..(j + 2) * stride + hd];
        let k3 = &keys[(j + 3) * stride..(j + 3) * stride + hd];
        let (mut c0, mut c1, mut c2, mut c3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (p, &qp) in q.iter().enumerate() {
            c0 = round_to_mantissa(qp.mul_add(k0[p], c0), mu);
            c1 = round_to_mantissa(qp.mul_add(k1[p], c1), mu);
            c2 = round_to_mantissa(qp.mul_add(k2[p], c2), mu);
            c3 = round_to_mantissa(qp.mul_add(k3[p], c3), mu);
        }
        out[j] = c0 * scale;
        out[j + 1] = c1 * scale;
        out[j + 2] = c2 * scale;
        out[j + 3] = c3 * scale;
        j += 4;
    }
    while j < n {
        out[j] = dot_ps(q, &keys[j * stride..j * stride + hd], mu) * scale;
        j += 1;
    }
}

/// Accumulate with the given [`AccumMode`].
pub fn dot_with_mode(a: &[f32], b: &[f32], mode: AccumMode, rng: &mut Rng) -> f32 {
    match mode {
        AccumMode::PsNearest { mu } => dot_ps(a, b, mu),
        AccumMode::PsStochastic { mu } => dot_ps_stochastic(a, b, mu, rng),
        AccumMode::Fp32 => dot_f32(a, b),
        AccumMode::Kahan => dot_kahan(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * scale).collect()
    }

    #[test]
    fn ps23_matches_fp32_sequential() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let n = rng.range(1, 128);
            let a = randvec(&mut rng, n, 2.0);
            let b = randvec(&mut rng, n, 2.0);
            assert_eq!(dot_ps(&a, &b, 23).to_bits(), dot_f32(&a, &b).to_bits());
        }
    }

    #[test]
    fn low_mu_is_less_accurate() {
        let mut rng = Rng::new(2);
        let n = 256;
        let (mut err4, mut err10) = (0.0f64, 0.0f64);
        for _ in 0..50 {
            let a = randvec(&mut rng, n, 2.0);
            let b = randvec(&mut rng, n, 2.0);
            let exact = dot_f64(&a, &b);
            err4 += (dot_ps(&a, &b, 4) as f64 - exact).abs();
            err10 += (dot_ps(&a, &b, 10) as f64 - exact).abs();
        }
        assert!(err4 > err10 * 4.0, "err4={err4} err10={err10}");
    }

    #[test]
    fn kahan_beats_naive_on_hard_sums() {
        // Alternating large/small values expose naive accumulation error.
        let n = 4000;
        let mut a = Vec::with_capacity(n);
        for i in 0..n {
            a.push(if i % 2 == 0 { 1e6f32 } else { 0.123f32 });
        }
        let b = vec![1.0f32; n];
        let exact = dot_f64(&a, &b);
        let e_naive = (dot_f32(&a, &b) as f64 - exact).abs();
        let e_kahan = (dot_kahan(&a, &b) as f64 - exact).abs();
        assert!(e_kahan <= e_naive, "kahan={e_kahan} naive={e_naive}");
    }

    #[test]
    fn empty_dot_is_zero() {
        assert_eq!(dot_ps(&[], &[], 7), 0.0);
        assert_eq!(dot_f32(&[], &[]), 0.0);
        assert_eq!(dot_kahan(&[], &[]), 0.0);
    }

    #[test]
    fn stochastic_mean_close_to_exact() {
        let mut rng = Rng::new(3);
        let n = 64;
        let a = randvec(&mut rng, n, 1.0);
        let b = randvec(&mut rng, n, 1.0);
        let exact = dot_f64(&a, &b);
        let trials = 2000;
        let mut mean = 0.0f64;
        for _ in 0..trials {
            mean += dot_ps_stochastic(&a, &b, 4, &mut rng) as f64;
        }
        mean /= trials as f64;
        // Deterministic RNE can have bias of order u*k*|dot| — stochastic
        // mean should sit close to exact relative to one PS(4) ulp of the
        // running magnitude.
        let tol = 2.0f64.powi(-5) * a.iter().map(|x| x.abs() as f64).sum::<f64>() * 0.5;
        assert!((mean - exact).abs() < tol, "mean={mean} exact={exact} tol={tol}");
    }

    #[test]
    fn mode_dispatch() {
        let mut rng = Rng::new(4);
        let a = randvec(&mut rng, 32, 1.0);
        let b = randvec(&mut rng, 32, 1.0);
        assert_eq!(
            dot_with_mode(&a, &b, AccumMode::Fp32, &mut rng),
            dot_f32(&a, &b)
        );
        assert_eq!(
            dot_with_mode(&a, &b, AccumMode::PsNearest { mu: 7 }, &mut rng),
            dot_ps(&a, &b, 7)
        );
        assert_eq!(
            dot_with_mode(&a, &b, AccumMode::Kahan, &mut rng),
            dot_kahan(&a, &b)
        );
    }

    #[test]
    fn score_row_matches_per_dot_bitwise() {
        // The fused kernel's contract: bit-identical to the scalar loop for
        // every (mu, row length, head width, stride, offset) combination.
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let hd = rng.range(1, 24);
            let n = rng.range(1, 19); // crosses the 4-wide block boundary
            let stride = hd + rng.range(0, 9);
            let off = rng.range(0, 5).min(stride - hd);
            let q = randvec(&mut rng, hd, 2.0);
            let keys = randvec(&mut rng, n * stride + off, 2.0);
            for mu in [1u32, 4, 11, 23] {
                let scale = 1.0 / (hd as f32).sqrt();
                let mut out = vec![0.0f32; n];
                score_row_ps(&q, &keys[off..], stride, n, mu, scale, &mut out);
                for j in 0..n {
                    let kj = &keys[off + j * stride..off + j * stride + hd];
                    let want = dot_ps(&q, kj, mu) * scale;
                    assert_eq!(
                        out[j].to_bits(),
                        want.to_bits(),
                        "j={j} mu={mu} hd={hd} n={n}"
                    );
                }
            }
        }
    }

    #[test]
    fn score_row_empty() {
        let mut out: Vec<f32> = Vec::new();
        score_row_ps(&[1.0, 2.0], &[], 2, 0, 4, 1.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn error_bound_cg_k() {
        // |dot_ps - exact| <= k * u * sum|a_i b_i| to first order (c_g = k
        // for deterministic rounding, §2.2.1). Check with slack factor 2.
        let mut rng = Rng::new(5);
        for mu in [4u32, 7, 10] {
            let u = 2.0f64.powi(-(mu as i32) - 1);
            for _ in 0..100 {
                let n = rng.range(2, 200);
                let a = randvec(&mut rng, n, 2.0);
                let b = randvec(&mut rng, n, 2.0);
                let exact = dot_f64(&a, &b);
                let got = dot_ps(&a, &b, mu) as f64;
                let bound: f64 = 2.0
                    * n as f64
                    * u
                    * a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum::<f64>();
                assert!(
                    (got - exact).abs() <= bound + 1e-12,
                    "n={n} mu={mu} err={} bound={bound}",
                    (got - exact).abs()
                );
            }
        }
    }
}
