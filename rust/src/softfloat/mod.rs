//! The PS(μ) custom floating-point format of paper §4.1 and the
//! mixed-precision accumulation primitives built on it.
//!
//! `PS(μ)` has μ ∈ {1..23} mantissa bits, 8 exponent bits and one sign bit:
//! it coincides with FP32 at μ=23, TF32 at μ=10, and BF16 at μ=7. Values are
//! represented as FP32 numbers rounded to μ mantissa bits with
//! round-to-nearest-ties-to-even (RNE), exactly as the paper simulates.
//!
//! * [`round`] — bit-exact RNE rounding (and a stochastic-rounding
//!   extension, cf. §2.2.1 of the paper / Connolly–Higham–Mary).
//! * [`ps`] — the [`ps::Ps`] wrapper type and format metadata.
//! * [`dot`] — inner products with per-step `round(c + a·b)` accumulation
//!   (the paper's simulated low-precision accumulator) and higher-accuracy
//!   alternatives (FP32, compensated/Kahan) used for LAMP recomputation.

pub mod dot;
pub mod ps;
pub mod round;

pub use dot::{dot_f32, dot_kahan, dot_ps, AccumMode};
pub use ps::{Ps, PsFormat};
pub use round::{round_to_mantissa, round_to_mantissa_stochastic, ulp_at, RoundMode};
