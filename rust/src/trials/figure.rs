//! Figure trials: paper-figure computations replayed as byte-exact
//! artifacts.
//!
//! A `[figure]` manifest (see `trials::manifest`) pins everything a figure
//! driver needs — model config, weights seed, evaluation-panel shape, and
//! the μ sweep — so the numbers behind a rendered figure are reproducible
//! the same way a serving trial is: `lamp trials run fig1` twice and
//! `lamp trials diff` the artifacts. `lamp exp fig1` routes through the
//! same row computation, so the human table and the canonical artifact
//! can never disagree.
//!
//! Unlike serving canonicals (integer counters only), figure canonicals
//! carry floating-point KL values. That is sound here because every value
//! is the result of an order-pinned reduction: the thread pool returns
//! results in submission order, the engine's kernels are bitwise identical
//! across SIMD/scalar dispatch (the scalar-replay contract in
//! `linalg::simd`), and weights come from the seeded generator, never from
//! trained artifacts on disk. Each float is printed both in decimal and as
//! its exact bit pattern, so a diff catches even sub-ULP drift.

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{PrecisionPolicy, Rule};
use crate::error::{Error, Result};
use crate::experiments::common::{EvalOptions, EvalPanel};
use crate::model::Weights;
use crate::util::Rng;

use super::manifest::{FigureSpec, TrialManifest};
use super::runner::TrialRun;

/// One μ point of the fig1 sweep: KL vs the FP32 reference for uniform
/// PS(μ), LAMP (strict, threshold τ), and the random baseline at the same
/// threshold, plus LAMP's recompute budget as an exact integer ratio.
#[derive(Debug, Clone)]
pub struct FigureRow {
    pub mu: u32,
    pub kl_uniform: f64,
    pub kl_lamp: f64,
    pub kl_random: f64,
    pub recomputed: usize,
    pub causal_total: usize,
}

/// Compute the fig1 rows a manifest describes. Deterministic: same
/// manifest ⇒ identical `f64` bits, at any worker count, on any host.
pub fn rows(manifest: &TrialManifest, fig: &FigureSpec) -> Result<Vec<FigureRow>> {
    if fig.exp != "fig1" {
        return Err(Error::config(format!("unknown figure driver {:?}", fig.exp)));
    }
    let mut rng = Rng::new(manifest.weights_seed);
    let weights = Arc::new(Weights::random(&manifest.model, &mut rng)?);
    let opts = EvalOptions {
        num_seqs: fig.num_seqs,
        seq_len: fig.seq_len,
        stream_seed: manifest.seed,
        workers: manifest.workers.max(1),
        // Never read trained weights from disk: the artifact must pin the
        // same bytes on a fresh checkout.
        artifacts: None,
        quick: false,
    };
    let panel = EvalPanel::build(weights, fig.domain, &opts)?;
    let mut out = Vec::with_capacity(fig.mu_grid.len());
    for &mu in &fig.mu_grid {
        let uni = panel.evaluate(&PrecisionPolicy::uniform(mu), 0)?;
        let lamp = panel.evaluate(&PrecisionPolicy::lamp(mu, fig.tau, Rule::Strict), 0)?;
        let rand = panel.evaluate(&PrecisionPolicy::lamp(mu, fig.tau, Rule::Random), 0)?;
        out.push(FigureRow {
            mu,
            kl_uniform: uni.kl,
            kl_lamp: lamp.kl,
            kl_random: rand.kl,
            recomputed: lamp.recomputed,
            causal_total: lamp.causal_total,
        });
    }
    Ok(out)
}

/// Pin a float for the canonical artifact: human-readable decimal plus the
/// exact bit pattern (sub-ULP drift shows up as a byte diff).
fn pin_f64(v: f64) -> String {
    format!("{v:.12e} bits={:016x}", v.to_bits())
}

/// Run a figure trial end to end: compute the rows and render both the
/// canonical artifact and the human summary.
pub fn run(manifest: &TrialManifest, fig: &FigureSpec) -> Result<TrialRun> {
    let t0 = Instant::now();
    let rows = rows(manifest, fig)?;

    let mut out = String::new();
    out.push_str(&format!("trial = {}\n", manifest.name));
    out.push_str(&format!("seed = {}\n", manifest.seed));
    out.push_str(&format!("model = {}\n", manifest.model.name));
    out.push_str(&format!("figure = {}\n", fig.exp));
    out.push_str(&format!(
        "panel = {} num_seqs={} seq_len={}\n",
        fig.domain.name(),
        fig.num_seqs,
        fig.seq_len
    ));
    out.push_str(&format!("tau = {}\n", fig.tau));
    out.push_str(&format!("weights = random(seed={})\n", manifest.weights_seed));
    let grid: Vec<String> = fig.mu_grid.iter().map(|m| m.to_string()).collect();
    out.push_str(&format!("mu_grid = {}\n", grid.join(",")));
    for r in &rows {
        out.push_str(&format!("[mu {}]\n", r.mu));
        out.push_str(&format!("kl_uniform = {}\n", pin_f64(r.kl_uniform)));
        out.push_str(&format!("kl_lamp = {}\n", pin_f64(r.kl_lamp)));
        out.push_str(&format!("kl_random = {}\n", pin_f64(r.kl_random)));
        out.push_str(&format!("recompute = {}/{}\n", r.recomputed, r.causal_total));
    }

    let display = format!(
        "trial {}: figure {} over {} mu points, {} panel {}x{} (model {}), {:.3}s wall\n",
        manifest.name,
        fig.exp,
        rows.len(),
        fig.domain.name(),
        fig.num_seqs,
        fig.seq_len,
        manifest.model.name,
        t0.elapsed().as_secs_f64()
    );
    Ok(TrialRun { canonical: out, display })
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = "\
name = fig-tiny\n\
seed = 5\n\
[model]\n\
config = nano\n\
weights-seed = 3\n\
[figure]\n\
exp = fig1\n\
mu-grid = 2, 7\n\
num-seqs = 2\n\
seq-len = 10\n\
tau = 0.1\n";

    #[test]
    fn figure_trial_replays_byte_identically_across_worker_counts() {
        let mut manifest = TrialManifest::parse(TINY).unwrap();
        let fig = manifest.figure.clone().unwrap();
        let base = run(&manifest, &fig).unwrap();
        for workers in [1usize, 4] {
            manifest.workers = workers;
            let again = run(&manifest, &fig).unwrap();
            assert_eq!(base.canonical, again.canonical, "workers={workers} diverged");
        }
        assert!(base.canonical.starts_with("trial = fig-tiny\n"));
        assert!(base.canonical.contains("\n[mu 2]\n"));
        assert!(base.canonical.contains("bits="), "floats must be bit-pinned");
        assert!(base.canonical.ends_with('\n'));
        assert!(!base.display.is_empty());
    }

    #[test]
    fn figure_rows_are_sane() {
        let manifest = TrialManifest::parse(TINY).unwrap();
        let fig = manifest.figure.clone().unwrap();
        let rows = rows(&manifest, &fig).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.kl_uniform.is_finite() && r.kl_uniform >= 0.0);
            assert!(r.kl_lamp.is_finite() && r.kl_lamp >= 0.0);
            assert!(r.kl_random.is_finite() && r.kl_random >= 0.0);
            assert!(r.recomputed <= r.causal_total);
        }
        // At mu=2 low-precision accumulation visibly perturbs the logits.
        assert!(rows[0].kl_uniform > 0.0);
    }
}
