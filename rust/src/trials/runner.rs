//! Build the engine a manifest describes, replay its trace, and render
//! both outputs: the canonical byte-exact artifact and a human summary.

use std::sync::Arc;

use crate::coordinator::{
    replay, FaultInjector, KvCacheOptions, NativeEngine, ReplayOptions, ReplayReport,
    SchedulerOptions,
};
use crate::error::Result;
use crate::model::Weights;
use crate::obs::ObsHub;
use crate::util::{Rng, ThreadPool};

use super::manifest::TrialManifest;
use super::output;

/// The result of one trial run.
#[derive(Debug)]
pub struct TrialRun {
    /// Byte-exact artifact: same manifest + seed ⇒ identical bytes (see
    /// `trials::output` for what it may contain).
    pub canonical: String,
    /// Human-readable summary including wall-clock and schedule-dependent
    /// numbers — explicitly NOT deterministic.
    pub display: String,
}

/// Run a trial end to end.
pub fn run(manifest: &TrialManifest) -> Result<TrialRun> {
    run_with_obs(manifest, None)
}

/// Run a trial end to end, reporting into `obs` when given. Pass a hub
/// built with `with_virtual_clock()` (plus a tracer for span capture):
/// replay drives the virtual ticks, so `lamp trials run --trace-out`
/// dumps a trace that is deterministic across reruns. Observability is
/// inert: the canonical artifact is byte-identical with or without a
/// hub (`rust/tests/obs_parity.rs` pins this).
pub fn run_with_obs(
    manifest: &TrialManifest,
    obs: Option<Arc<ObsHub>>,
) -> Result<TrialRun> {
    if let Some(fig) = &manifest.figure {
        return super::figure::run(manifest, fig);
    }
    let trace = manifest
        .trace
        .as_ref()
        .expect("manifest build guarantees trace xor figure")
        .generate()?;

    let mut rng = Rng::new(manifest.weights_seed);
    let weights = Weights::random(&manifest.model, &mut rng)?;
    let mut engine = NativeEngine::new(weights);
    if let Some(fmt) = manifest.weight_format {
        engine = engine.with_weight_format(fmt)?;
    }
    if let Some(fmt) = manifest.kv_format {
        let mut kv = KvCacheOptions::serving(&manifest.model, fmt, manifest.max_sessions);
        if let Some(tau) = manifest.repair_tau {
            kv = kv.with_repair_tau(tau);
        }
        engine = engine.with_kv_cache(kv)?;
    }

    let pool = if manifest.workers > 0 {
        Some(Arc::new(ThreadPool::new(manifest.workers)))
    } else {
        None
    };
    let opts = ReplayOptions {
        policy: manifest.policy,
        scheduler: SchedulerOptions {
            max_sessions: manifest.max_sessions,
            prefill_chunk: manifest.prefill_chunk,
            pool,
            obs,
            ..Default::default()
        },
        eos: None,
        max_steps: None,
    };

    let report = match &manifest.faults {
        Some(plan) => {
            let injector = FaultInjector::new(engine, plan.clone())?;
            replay(&injector, &trace, &opts)?
        }
        None => replay(&engine, &trace, &opts)?,
    };

    let canonical = output::canonical(manifest, &trace, &report);
    let display = display_summary(manifest, &report);
    Ok(TrialRun { canonical, display })
}

/// Human summary with the wall-clock numbers the canonical artifact
/// deliberately leaves out.
fn display_summary(manifest: &TrialManifest, report: &ReplayReport) -> String {
    let m = &report.metrics;
    let mut out = format!(
        "trial {}: {} completed, {} failed, {} tokens in {} scheduler iterations \
         ({:.3}s wall)\n",
        manifest.name,
        report.responses.len(),
        report.failures.len(),
        m.generated_tokens,
        report.steps,
        report.wall_s
    );
    out.push_str(&format!(
        "  ttft p50/p95 = {:.2}/{:.2} ms, itl p50/p95 = {:.3}/{:.3} ms, \
         mean active sessions = {:.2}\n",
        1e3 * m.ttft_p50_s,
        1e3 * m.ttft_p95_s,
        1e3 * m.itl_p50_s,
        1e3 * m.itl_p95_s,
        m.mean_active_sessions
    ));
    out.push_str(&format!(
        "  kv = {} ({}/{} blocks), prefix share hits = {}, retries = {}, \
         faults injected = {}\n",
        m.kv_format,
        m.kv_blocks_used,
        m.kv_blocks_capacity,
        m.prefix_share_hits,
        m.retries,
        m.faults_injected
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trials;

    #[test]
    fn builtin_manifests_parse_and_run_deterministically() {
        for (name, text) in trials::BUILTIN {
            let manifest = TrialManifest::parse(text)
                .unwrap_or_else(|e| panic!("builtin manifest {name}: {e}"));
            assert_eq!(manifest.name, name, "builtin name must match its registry key");
            let a = run(&manifest).unwrap_or_else(|e| panic!("trial {name}: {e}"));
            let b = run(&manifest).unwrap();
            assert_eq!(a.canonical, b.canonical, "trial {name} is nondeterministic");
            assert!(a.canonical.contains(&format!("trial = {name}")));
            assert!(!a.display.is_empty());
        }
    }

    #[test]
    fn prefix_chat_trial_exercises_the_shared_kv_pool() {
        let manifest = TrialManifest::parse(trials::builtin("prefix-chat").unwrap()).unwrap();
        assert!(manifest.kv_format.is_some(), "prefix-chat trial must use the kv pool");
        let out = run(&manifest).unwrap();
        assert!(out.canonical.contains("outcome = completed"));
        assert!(
            out.display.contains("prefix share hits"),
            "display must surface sharing: {}",
            out.display
        );
    }

    #[test]
    fn chaos_trial_reports_outcomes_deterministically() {
        let manifest = TrialManifest::parse(trials::builtin("chaos-replay").unwrap()).unwrap();
        assert!(manifest.faults.is_some());
        let a = run(&manifest).unwrap();
        let b = run(&manifest).unwrap();
        assert_eq!(a.canonical, b.canonical, "fault verdicts must replay identically");
    }
}
