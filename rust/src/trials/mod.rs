//! Trials: declarative, deterministic serving measurements.
//!
//! A *trial* bundles a model config, precision policy, workload trace,
//! scheduler shape and optional fault plan into one manifest
//! ([`manifest::TrialManifest`]), replays it through the unmodified
//! scheduler ([`crate::coordinator::replay`]), and renders a canonical
//! byte-exact artifact ([`output::canonical`]): same manifest + seed ⇒
//! identical bytes on any machine. That artifact is the repo's
//! reproduce-every-number primitive — `lamp trials run <name>` twice and
//! `lamp trials diff` the results (see DESIGN.md §Trials).
//!
//! Seven manifests ship with the crate (the [`BUILTIN`] registry): six
//! serving workloads plus the `fig1` figure trial, which replays a paper
//! figure's computation as a byte-exact artifact (see [`figure`]); any
//! `.trial` file on disk runs the same way.

pub mod figure;
pub mod manifest;
pub mod output;
pub mod runner;

pub use manifest::{FigureSpec, TrialManifest};
pub use output::{canonical, first_divergence, token_fingerprint};
pub use runner::{run, run_with_obs, TrialRun};

/// The bundled trial manifests, compiled into the binary so CI and a
/// fresh checkout agree on the exact bytes being replayed.
pub const BUILTIN: [(&str, &str); 7] = [
    ("prefix-chat", include_str!("manifests/prefix-chat.trial")),
    ("long-context", include_str!("manifests/long-context.trial")),
    ("bursty", include_str!("manifests/bursty.trial")),
    ("poisson-mix", include_str!("manifests/poisson-mix.trial")),
    ("adversarial", include_str!("manifests/adversarial.trial")),
    ("chaos-replay", include_str!("manifests/chaos-replay.trial")),
    ("fig1", include_str!("manifests/fig1.trial")),
];

/// Look up a bundled manifest's text by name.
pub fn builtin(name: &str) -> Option<&'static str> {
    BUILTIN.iter().find(|(n, _)| *n == name).map(|(_, text)| *text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_registry_resolves() {
        assert!(builtin("prefix-chat").is_some());
        assert!(builtin("nope").is_none());
        for (name, text) in BUILTIN {
            assert!(text.contains(&format!("name = {name}")), "{name}");
        }
    }
}
