//! Declarative trial manifests.
//!
//! A trial is everything needed to reproduce one serving measurement:
//! model config, precision policy, workload trace, scheduler shape, and
//! an optional fault plan — plus the seed that makes the whole thing
//! deterministic. Manifests are a simple INI-style on-disk format:
//!
//! ```text
//! # Prefix-heavy chat over the shared paged KV cache.
//! name = prefix-chat-nano
//! seed = 42
//!
//! [model]
//! config = nano
//! weights-seed = 7
//!
//! [policy]
//! tier = balanced            # or mu/tau/rule for a custom plan
//!
//! [workload]
//! trace = prefix-chat
//! requests = 9
//! sessions = 3
//!
//! [scheduler]
//! max-sessions = 4
//! workers = 0                # 0 = step sessions sequentially
//!
//! [kv]
//! format = bf16              # paged KV pool with prefix sharing
//!
//! [faults]
//! plan = chaos               # quiet | chaos
//! ```
//!
//! `#`/`;` start comments; unknown sections or keys are typed errors, not
//! silently ignored — a manifest that parses runs exactly what it says.
//!
//! Besides serving replays, a manifest may instead describe a *figure*
//! trial — a paper-figure computation replayed as a byte-exact artifact
//! (see `trials::figure`). A figure trial carries a `[figure]` section in
//! place of `[workload]`:
//!
//! ```text
//! name = fig1
//! seed = 42
//!
//! [figure]
//! exp = fig1                 # which figure driver
//! mu-grid = 2,4,7,10,16,23   # mantissa-bit sweep
//! num-seqs = 3               # evaluation panel size
//! seq-len = 32
//! domain = web
//! tau = 0.1                  # LAMP threshold for the adaptive series
//! ```

use crate::coordinator::{FaultPlan, PrecisionPolicy, Rule, WeightFormat};
use crate::data::traces::{TraceKind, TraceSpec};
use crate::data::Domain;
use crate::error::{Error, Result};
use crate::model::ModelConfig;

/// A figure-driver trial: replays a paper-figure computation instead of a
/// serving trace. Which fields matter is fixed by `exp`; today the only
/// driver is `fig1` (KL vs μ for uniform/LAMP/random at threshold τ).
#[derive(Debug, Clone, PartialEq)]
pub struct FigureSpec {
    /// Figure driver name (`fig1`).
    pub exp: String,
    /// Mantissa-bit sweep, in manifest order.
    pub mu_grid: Vec<u32>,
    /// Evaluation-panel sequences.
    pub num_seqs: usize,
    /// Tokens per panel sequence (clamped to the model's seq).
    pub seq_len: usize,
    /// Synthetic corpus domain for the panel.
    pub domain: Domain,
    /// LAMP threshold shared by the strict and random series.
    pub tau: f32,
}

/// A fully resolved trial description.
#[derive(Debug, Clone)]
pub struct TrialManifest {
    pub name: String,
    /// Root seed: reused as the trace seed.
    pub seed: u64,
    pub model: ModelConfig,
    pub weights_seed: u64,
    pub policy: PrecisionPolicy,
    /// How the manifest spelled the policy (tier name or custom label).
    pub policy_label: String,
    /// Serving workload; `None` exactly when this is a figure trial.
    pub trace: Option<TraceSpec>,
    /// Figure computation; `None` exactly when this is a serving trial.
    pub figure: Option<FigureSpec>,
    pub max_sessions: usize,
    pub prefill_chunk: usize,
    /// Thread-pool size for session stepping; 0 = sequential.
    pub workers: usize,
    /// Paged-KV storage format; `None` runs without a shared pool.
    pub kv_format: Option<WeightFormat>,
    pub repair_tau: Option<f32>,
    /// Mixed-precision weight storage; `None` keeps f32.
    pub weight_format: Option<WeightFormat>,
    pub faults: Option<FaultPlan>,
    /// "none", "quiet", or "chaos" — for reports.
    pub fault_label: String,
}

/// Raw key-value state collected during the first parse pass.
#[derive(Default)]
struct Raw {
    name: Option<String>,
    seed: Option<u64>,
    model: Option<String>,
    weights_seed: Option<u64>,
    tier: Option<String>,
    mu: Option<u32>,
    tau: Option<f32>,
    rule: Option<String>,
    trace: Option<String>,
    requests: Option<usize>,
    sessions: Option<usize>,
    prefix_len: Option<usize>,
    turn_tokens: Option<usize>,
    new_tokens: Option<usize>,
    zipf_s: Option<f64>,
    burst: Option<usize>,
    gap_steps: Option<usize>,
    rate: Option<f64>,
    topk: Option<usize>,
    max_sessions: Option<usize>,
    prefill_chunk: Option<usize>,
    workers: Option<usize>,
    kv_format: Option<String>,
    repair_tau: Option<f32>,
    weight_format: Option<String>,
    fault_plan: Option<String>,
    fault_seed: Option<u64>,
    figure_exp: Option<String>,
    figure_mu_grid: Option<String>,
    figure_num_seqs: Option<usize>,
    figure_seq_len: Option<usize>,
    figure_domain: Option<String>,
    figure_tau: Option<f32>,
}

fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
    value
        .parse()
        .map_err(|_| Error::config(format!("trial manifest: bad value {value:?} for {key:?}")))
}

impl TrialManifest {
    /// Parse a manifest from its on-disk text.
    pub fn parse(text: &str) -> Result<TrialManifest> {
        let mut raw = Raw::default();
        let mut section = String::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = match line.find(['#', ';']) {
                Some(idx) => &line[..idx],
                None => line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or_else(|| {
                    Error::config(format!("trial manifest line {}: bad section", lineno + 1))
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::config(format!(
                    "trial manifest line {}: expected `key = value`, got {line:?}",
                    lineno + 1
                ))
            })?;
            let key = key.trim();
            let value = value.trim();
            raw.set(&section, key, value)?;
        }
        raw.build()
    }
}

impl Raw {
    fn set(&mut self, section: &str, key: &str, value: &str) -> Result<()> {
        match (section, key) {
            ("", "name") => self.name = Some(value.to_string()),
            ("", "seed") => self.seed = Some(parse_num(key, value)?),
            ("model", "config") => self.model = Some(value.to_string()),
            ("model", "weights-seed") => self.weights_seed = Some(parse_num(key, value)?),
            ("policy", "tier") => self.tier = Some(value.to_string()),
            ("policy", "mu") => self.mu = Some(parse_num(key, value)?),
            ("policy", "tau") => self.tau = Some(parse_num(key, value)?),
            ("policy", "rule") => self.rule = Some(value.to_string()),
            ("workload", "trace") => self.trace = Some(value.to_string()),
            ("workload", "requests") => self.requests = Some(parse_num(key, value)?),
            ("workload", "sessions") => self.sessions = Some(parse_num(key, value)?),
            ("workload", "prefix-len") => self.prefix_len = Some(parse_num(key, value)?),
            ("workload", "turn-tokens") => self.turn_tokens = Some(parse_num(key, value)?),
            ("workload", "new-tokens") => self.new_tokens = Some(parse_num(key, value)?),
            ("workload", "zipf-s") => self.zipf_s = Some(parse_num(key, value)?),
            ("workload", "burst") => self.burst = Some(parse_num(key, value)?),
            ("workload", "gap-steps") => self.gap_steps = Some(parse_num(key, value)?),
            ("workload", "rate") => self.rate = Some(parse_num(key, value)?),
            ("workload", "topk") => self.topk = Some(parse_num(key, value)?),
            ("scheduler", "max-sessions") => self.max_sessions = Some(parse_num(key, value)?),
            ("scheduler", "prefill-chunk") => self.prefill_chunk = Some(parse_num(key, value)?),
            ("scheduler", "workers") => self.workers = Some(parse_num(key, value)?),
            ("kv", "format") => self.kv_format = Some(value.to_string()),
            ("kv", "repair-tau") => self.repair_tau = Some(parse_num(key, value)?),
            ("weights", "format") => self.weight_format = Some(value.to_string()),
            ("faults", "plan") => self.fault_plan = Some(value.to_string()),
            ("faults", "seed") => self.fault_seed = Some(parse_num(key, value)?),
            ("figure", "exp") => self.figure_exp = Some(value.to_string()),
            ("figure", "mu-grid") => self.figure_mu_grid = Some(value.to_string()),
            ("figure", "num-seqs") => self.figure_num_seqs = Some(parse_num(key, value)?),
            ("figure", "seq-len") => self.figure_seq_len = Some(parse_num(key, value)?),
            ("figure", "domain") => self.figure_domain = Some(value.to_string()),
            ("figure", "tau") => self.figure_tau = Some(parse_num(key, value)?),
            _ => {
                let place = if section.is_empty() {
                    "top level".to_string()
                } else {
                    format!("section [{section}]")
                };
                return Err(Error::config(format!(
                    "trial manifest: unknown key {key:?} in {place}"
                )));
            }
        }
        Ok(())
    }

    /// Resolve the `[figure]` section, if present. Stray figure keys
    /// without `exp` are typed errors like any other unknown state.
    fn build_figure(&self) -> Result<Option<FigureSpec>> {
        let exp = match &self.figure_exp {
            Some(exp) => exp,
            None => {
                if self.figure_mu_grid.is_some()
                    || self.figure_num_seqs.is_some()
                    || self.figure_seq_len.is_some()
                    || self.figure_domain.is_some()
                    || self.figure_tau.is_some()
                {
                    return Err(Error::config(
                        "trial manifest: [figure] keys require [figure] `exp`",
                    ));
                }
                return Ok(None);
            }
        };
        if exp != "fig1" {
            return Err(Error::config(format!(
                "trial manifest: unknown figure driver {exp:?} (expected fig1)"
            )));
        }
        let grid_text = self
            .figure_mu_grid
            .as_deref()
            .ok_or_else(|| Error::config("trial manifest: missing [figure] `mu-grid`"))?;
        let mut mu_grid = Vec::new();
        for part in grid_text.split(',') {
            let mu: u32 = parse_num("mu-grid", part.trim())?;
            if !(1..=23).contains(&mu) {
                return Err(Error::config(format!(
                    "trial manifest: [figure] mu-grid entry {mu} out of 1..=23"
                )));
            }
            mu_grid.push(mu);
        }
        let domain_name = self.figure_domain.as_deref().unwrap_or("web");
        let domain = Domain::by_name(domain_name).ok_or_else(|| {
            Error::config(format!("trial manifest: unknown [figure] domain {domain_name:?}"))
        })?;
        let tau = self.figure_tau.unwrap_or(0.1);
        if !tau.is_finite() || tau <= 0.0 {
            return Err(Error::config(format!(
                "trial manifest: [figure] tau must be finite and positive, got {tau}"
            )));
        }
        let num_seqs = self.figure_num_seqs.unwrap_or(3);
        let seq_len = self.figure_seq_len.unwrap_or(32);
        if num_seqs == 0 || seq_len < 2 {
            return Err(Error::config(
                "trial manifest: [figure] needs num-seqs >= 1 and seq-len >= 2",
            ));
        }
        Ok(Some(FigureSpec { exp: exp.clone(), mu_grid, num_seqs, seq_len, domain, tau }))
    }

    fn build(self) -> Result<TrialManifest> {
        // Resolve `[figure]` before any field is moved out of `self`.
        let figure = self.build_figure()?;
        let name = self
            .name
            .ok_or_else(|| Error::config("trial manifest: missing top-level `name`"))?;
        let seed = self.seed.unwrap_or(1);
        let model = ModelConfig::by_name(self.model.as_deref().unwrap_or("nano"))?;

        let (policy, policy_label) = match (&self.tier, self.mu) {
            (Some(tier), None) => (PrecisionPolicy::tier(tier)?, tier.clone()),
            (None, Some(mu)) => {
                let tau = self.tau.ok_or_else(|| {
                    Error::config("trial manifest: [policy] mu requires tau")
                })?;
                let rule = Rule::by_name(self.rule.as_deref().unwrap_or("relaxed"))?;
                let policy = PrecisionPolicy::lamp(mu, tau, rule);
                (policy, format!("lamp(mu={mu}, tau={tau}, rule={})", rule.name()))
            }
            (None, None) => (PrecisionPolicy::tier("balanced")?, "balanced".to_string()),
            (Some(_), Some(_)) => {
                return Err(Error::config(
                    "trial manifest: [policy] tier and mu/tau are mutually exclusive",
                ))
            }
        };

        if let Some(fig) = figure {
            if self.trace.is_some() {
                return Err(Error::config(
                    "trial manifest: [figure] and [workload] are mutually exclusive",
                ));
            }
            if self.tier.is_some() || self.mu.is_some() {
                return Err(Error::config(
                    "trial manifest: [policy] does not apply to figure trials \
                     (the figure fixes its own policy ladder)",
                ));
            }
            if self.kv_format.is_some()
                || self.repair_tau.is_some()
                || self.weight_format.is_some()
                || self.fault_plan.is_some()
            {
                return Err(Error::config(
                    "trial manifest: [kv]/[weights]/[faults] do not apply to figure trials",
                ));
            }
            return Ok(TrialManifest {
                name,
                seed,
                model,
                weights_seed: self.weights_seed.unwrap_or(7),
                policy,
                policy_label,
                trace: None,
                figure: Some(fig),
                max_sessions: self.max_sessions.unwrap_or(4),
                prefill_chunk: self.prefill_chunk.unwrap_or(8),
                workers: self.workers.unwrap_or(0),
                kv_format: None,
                repair_tau: None,
                weight_format: None,
                faults: None,
                fault_label: "none".to_string(),
            });
        }

        let kind_name = self.trace.ok_or_else(|| {
            Error::config("trial manifest: missing [workload] `trace` (or [figure] `exp`)")
        })?;
        let kind = TraceKind::by_name(&kind_name)?;
        let mut trace = TraceSpec::new(kind, model.vocab, model.seq);
        trace.seed = seed;
        if let Some(v) = self.requests {
            trace.requests = v;
        }
        if let Some(v) = self.sessions {
            trace.sessions = v;
        }
        if let Some(v) = self.prefix_len {
            trace.prefix_len = v;
        }
        if let Some(v) = self.turn_tokens {
            trace.turn_tokens = v;
        }
        if let Some(v) = self.new_tokens {
            trace.new_tokens = v;
        }
        if let Some(v) = self.zipf_s {
            trace.zipf_s = v;
        }
        if let Some(v) = self.burst {
            trace.burst = v;
        }
        if let Some(v) = self.gap_steps {
            trace.gap_steps = v;
        }
        if let Some(v) = self.rate {
            trace.rate = v;
        }
        if let Some(v) = self.topk {
            trace.topk = v;
        }
        trace.validate()?;

        let kv_format = match &self.kv_format {
            Some(name) => Some(WeightFormat::by_name(name)?),
            None => None,
        };
        if self.repair_tau.is_some() && kv_format.is_none() {
            return Err(Error::config(
                "trial manifest: [kv] repair-tau requires [kv] format",
            ));
        }
        let weight_format = match &self.weight_format {
            Some(name) => Some(WeightFormat::by_name(name)?),
            None => None,
        };

        let fault_seed = self.fault_seed.unwrap_or(seed);
        let (faults, fault_label) = match self.fault_plan.as_deref() {
            None => (None, "none".to_string()),
            Some("quiet") => (Some(FaultPlan::quiet(fault_seed)), "quiet".to_string()),
            Some("chaos") => (Some(FaultPlan::chaos(fault_seed)), "chaos".to_string()),
            Some(other) => {
                return Err(Error::config(format!(
                    "trial manifest: unknown fault plan {other:?} (quiet|chaos)"
                )))
            }
        };

        Ok(TrialManifest {
            name,
            seed,
            model,
            weights_seed: self.weights_seed.unwrap_or(7),
            policy,
            policy_label,
            trace: Some(trace),
            figure: None,
            max_sessions: self.max_sessions.unwrap_or(4),
            prefill_chunk: self.prefill_chunk.unwrap_or(8),
            workers: self.workers.unwrap_or(0),
            kv_format,
            repair_tau: self.repair_tau,
            weight_format,
            faults,
            fault_label,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# comment\n\
name = demo\n\
seed = 42\n\
\n\
[model]\n\
config = nano\n\
weights-seed = 9\n\
\n\
[policy]\n\
tier = balanced\n\
\n\
[workload]\n\
trace = prefix-chat   ; inline comment\n\
requests = 9\n\
sessions = 3\n\
prefix-len = 8\n\
turn-tokens = 3\n\
new-tokens = 4\n\
\n\
[scheduler]\n\
max-sessions = 4\n\
workers = 2\n\
\n\
[kv]\n\
format = bf16\n\
repair-tau = 1.0\n\
\n\
[faults]\n\
plan = quiet\n";

    #[test]
    fn parses_a_full_manifest() {
        let m = TrialManifest::parse(GOOD).unwrap();
        assert_eq!(m.name, "demo");
        assert_eq!(m.seed, 42);
        assert_eq!(m.model.name, "nano");
        assert_eq!(m.weights_seed, 9);
        assert_eq!(m.policy_label, "balanced");
        let trace = m.trace.as_ref().expect("serving trial has a trace");
        assert_eq!(trace.kind, TraceKind::PrefixChat);
        assert_eq!(trace.requests, 9);
        assert_eq!(trace.seed, 42, "trace reuses the trial seed");
        assert!(m.figure.is_none());
        assert_eq!(m.workers, 2);
        assert_eq!(m.kv_format, Some(WeightFormat::Bf16));
        assert_eq!(m.repair_tau, Some(1.0));
        assert_eq!(m.fault_label, "quiet");
        assert!(m.faults.is_some());
    }

    #[test]
    fn defaults_are_sensible() {
        let m = TrialManifest::parse("name = d\n[workload]\ntrace = zipf-mix\n").unwrap();
        assert_eq!(m.seed, 1);
        assert_eq!(m.model.name, "nano");
        assert_eq!(m.policy_label, "balanced");
        assert_eq!(m.workers, 0);
        assert!(m.kv_format.is_none());
        assert!(m.faults.is_none());
        assert_eq!(m.fault_label, "none");
    }

    #[test]
    fn custom_policy_via_mu_tau_rule() {
        let text = "name = d\n[policy]\nmu = 4\ntau = 0.1\nrule = strict\n\
                    [workload]\ntrace = bursty\n";
        let m = TrialManifest::parse(text).unwrap();
        assert_eq!(m.policy, PrecisionPolicy::lamp(4, 0.1, Rule::Strict));
        assert!(m.policy_label.contains("mu=4"));
    }

    #[test]
    fn unknown_keys_and_sections_are_rejected() {
        let unknown_key = "name = d\n[workload]\ntrace = zipf-mix\nbogus = 1\n";
        let err = TrialManifest::parse(unknown_key).unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        let unknown_section = "name = d\n[nonsense]\nx = 1\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(unknown_section).is_err());
    }

    #[test]
    fn required_fields_and_conflicts_error() {
        assert!(TrialManifest::parse("[workload]\ntrace = zipf-mix\n").is_err(), "no name");
        assert!(TrialManifest::parse("name = d\n").is_err(), "no trace");
        let conflict = "name = d\n[policy]\ntier = high\nmu = 4\ntau = 0.1\n\
                        [workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(conflict).is_err());
        let tau_no_kv = "name = d\n[kv]\nrepair-tau = 1.0\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(tau_no_kv).is_err());
        let bad_value = "name = d\nseed = not-a-number\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(bad_value).is_err());
    }

    #[test]
    fn workload_knobs_flow_into_the_trace_spec() {
        let text = "name = d\nseed = 5\n[workload]\ntrace = poisson\nrequests = 20\n\
                    rate = 0.5\ntopk = 4\n";
        let m = TrialManifest::parse(text).unwrap();
        let trace = m.trace.expect("serving trial has a trace");
        assert_eq!(trace.kind, TraceKind::Poisson);
        assert_eq!(trace.requests, 20);
        assert_eq!(trace.rate, 0.5);
        assert_eq!(trace.topk, 4);
        // The resulting spec actually generates.
        assert_eq!(trace.generate().unwrap().len(), 20);
    }

    const FIGURE: &str = "\
name = fig-demo\n\
seed = 5\n\
\n\
[model]\n\
config = nano\n\
weights-seed = 3\n\
\n\
[figure]\n\
exp = fig1\n\
mu-grid = 2, 4, 7\n\
num-seqs = 2\n\
seq-len = 12\n\
domain = web\n\
tau = 0.1\n";

    #[test]
    fn figure_manifest_parses() {
        let m = TrialManifest::parse(FIGURE).unwrap();
        assert!(m.trace.is_none(), "figure trials carry no serving trace");
        let fig = m.figure.expect("figure spec");
        assert_eq!(fig.exp, "fig1");
        assert_eq!(fig.mu_grid, vec![2, 4, 7]);
        assert_eq!(fig.num_seqs, 2);
        assert_eq!(fig.seq_len, 12);
        assert_eq!(fig.domain, crate::data::Domain::Web);
        assert_eq!(fig.tau, 0.1);
        assert_eq!(m.weights_seed, 3);
    }

    #[test]
    fn figure_section_is_validated() {
        // [figure] and [workload] are mutually exclusive.
        let both = format!("{FIGURE}[workload]\ntrace = bursty\n");
        assert!(TrialManifest::parse(&both).is_err());
        // Unknown driver, missing grid, out-of-range mu, bad tau.
        assert!(TrialManifest::parse(&FIGURE.replace("fig1", "fig99")).is_err());
        assert!(TrialManifest::parse(&FIGURE.replace("mu-grid = 2, 4, 7\n", "")).is_err());
        assert!(TrialManifest::parse(&FIGURE.replace("2, 4, 7", "0, 4")).is_err());
        assert!(TrialManifest::parse(&FIGURE.replace("tau = 0.1", "tau = -1")).is_err());
        // Figure keys without `exp` are a typed error, not silently dropped.
        assert!(TrialManifest::parse(&FIGURE.replace("exp = fig1\n", "")).is_err());
        // Serving-only sections don't apply to figure trials.
        assert!(TrialManifest::parse(&format!("{FIGURE}[kv]\nformat = bf16\n")).is_err());
        assert!(TrialManifest::parse(&format!("{FIGURE}[faults]\nplan = quiet\n")).is_err());
        assert!(TrialManifest::parse(&format!("{FIGURE}[policy]\ntier = high\n")).is_err());
    }
}
