//! Declarative trial manifests.
//!
//! A trial is everything needed to reproduce one serving measurement:
//! model config, precision policy, workload trace, scheduler shape, and
//! an optional fault plan — plus the seed that makes the whole thing
//! deterministic. Manifests are a simple INI-style on-disk format:
//!
//! ```text
//! # Prefix-heavy chat over the shared paged KV cache.
//! name = prefix-chat-nano
//! seed = 42
//!
//! [model]
//! config = nano
//! weights-seed = 7
//!
//! [policy]
//! tier = balanced            # or mu/tau/rule for a custom plan
//!
//! [workload]
//! trace = prefix-chat
//! requests = 9
//! sessions = 3
//!
//! [scheduler]
//! max-sessions = 4
//! workers = 0                # 0 = step sessions sequentially
//!
//! [kv]
//! format = bf16              # paged KV pool with prefix sharing
//!
//! [faults]
//! plan = chaos               # quiet | chaos
//! ```
//!
//! `#`/`;` start comments; unknown sections or keys are typed errors, not
//! silently ignored — a manifest that parses runs exactly what it says.

use crate::coordinator::{FaultPlan, PrecisionPolicy, Rule, WeightFormat};
use crate::data::traces::{TraceKind, TraceSpec};
use crate::error::{Error, Result};
use crate::model::ModelConfig;

/// A fully resolved trial description.
#[derive(Debug, Clone)]
pub struct TrialManifest {
    pub name: String,
    /// Root seed: reused as the trace seed.
    pub seed: u64,
    pub model: ModelConfig,
    pub weights_seed: u64,
    pub policy: PrecisionPolicy,
    /// How the manifest spelled the policy (tier name or custom label).
    pub policy_label: String,
    pub trace: TraceSpec,
    pub max_sessions: usize,
    pub prefill_chunk: usize,
    /// Thread-pool size for session stepping; 0 = sequential.
    pub workers: usize,
    /// Paged-KV storage format; `None` runs without a shared pool.
    pub kv_format: Option<WeightFormat>,
    pub repair_tau: Option<f32>,
    /// Mixed-precision weight storage; `None` keeps f32.
    pub weight_format: Option<WeightFormat>,
    pub faults: Option<FaultPlan>,
    /// "none", "quiet", or "chaos" — for reports.
    pub fault_label: String,
}

/// Raw key-value state collected during the first parse pass.
#[derive(Default)]
struct Raw {
    name: Option<String>,
    seed: Option<u64>,
    model: Option<String>,
    weights_seed: Option<u64>,
    tier: Option<String>,
    mu: Option<u32>,
    tau: Option<f32>,
    rule: Option<String>,
    trace: Option<String>,
    requests: Option<usize>,
    sessions: Option<usize>,
    prefix_len: Option<usize>,
    turn_tokens: Option<usize>,
    new_tokens: Option<usize>,
    zipf_s: Option<f64>,
    burst: Option<usize>,
    gap_steps: Option<usize>,
    rate: Option<f64>,
    topk: Option<usize>,
    max_sessions: Option<usize>,
    prefill_chunk: Option<usize>,
    workers: Option<usize>,
    kv_format: Option<String>,
    repair_tau: Option<f32>,
    weight_format: Option<String>,
    fault_plan: Option<String>,
    fault_seed: Option<u64>,
}

fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T> {
    value
        .parse()
        .map_err(|_| Error::config(format!("trial manifest: bad value {value:?} for {key:?}")))
}

impl TrialManifest {
    /// Parse a manifest from its on-disk text.
    pub fn parse(text: &str) -> Result<TrialManifest> {
        let mut raw = Raw::default();
        let mut section = String::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = match line.find(['#', ';']) {
                Some(idx) => &line[..idx],
                None => line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or_else(|| {
                    Error::config(format!("trial manifest line {}: bad section", lineno + 1))
                })?;
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::config(format!(
                    "trial manifest line {}: expected `key = value`, got {line:?}",
                    lineno + 1
                ))
            })?;
            let key = key.trim();
            let value = value.trim();
            raw.set(&section, key, value)?;
        }
        raw.build()
    }
}

impl Raw {
    fn set(&mut self, section: &str, key: &str, value: &str) -> Result<()> {
        match (section, key) {
            ("", "name") => self.name = Some(value.to_string()),
            ("", "seed") => self.seed = Some(parse_num(key, value)?),
            ("model", "config") => self.model = Some(value.to_string()),
            ("model", "weights-seed") => self.weights_seed = Some(parse_num(key, value)?),
            ("policy", "tier") => self.tier = Some(value.to_string()),
            ("policy", "mu") => self.mu = Some(parse_num(key, value)?),
            ("policy", "tau") => self.tau = Some(parse_num(key, value)?),
            ("policy", "rule") => self.rule = Some(value.to_string()),
            ("workload", "trace") => self.trace = Some(value.to_string()),
            ("workload", "requests") => self.requests = Some(parse_num(key, value)?),
            ("workload", "sessions") => self.sessions = Some(parse_num(key, value)?),
            ("workload", "prefix-len") => self.prefix_len = Some(parse_num(key, value)?),
            ("workload", "turn-tokens") => self.turn_tokens = Some(parse_num(key, value)?),
            ("workload", "new-tokens") => self.new_tokens = Some(parse_num(key, value)?),
            ("workload", "zipf-s") => self.zipf_s = Some(parse_num(key, value)?),
            ("workload", "burst") => self.burst = Some(parse_num(key, value)?),
            ("workload", "gap-steps") => self.gap_steps = Some(parse_num(key, value)?),
            ("workload", "rate") => self.rate = Some(parse_num(key, value)?),
            ("workload", "topk") => self.topk = Some(parse_num(key, value)?),
            ("scheduler", "max-sessions") => self.max_sessions = Some(parse_num(key, value)?),
            ("scheduler", "prefill-chunk") => self.prefill_chunk = Some(parse_num(key, value)?),
            ("scheduler", "workers") => self.workers = Some(parse_num(key, value)?),
            ("kv", "format") => self.kv_format = Some(value.to_string()),
            ("kv", "repair-tau") => self.repair_tau = Some(parse_num(key, value)?),
            ("weights", "format") => self.weight_format = Some(value.to_string()),
            ("faults", "plan") => self.fault_plan = Some(value.to_string()),
            ("faults", "seed") => self.fault_seed = Some(parse_num(key, value)?),
            _ => {
                let place = if section.is_empty() {
                    "top level".to_string()
                } else {
                    format!("section [{section}]")
                };
                return Err(Error::config(format!(
                    "trial manifest: unknown key {key:?} in {place}"
                )));
            }
        }
        Ok(())
    }

    fn build(self) -> Result<TrialManifest> {
        let name = self
            .name
            .ok_or_else(|| Error::config("trial manifest: missing top-level `name`"))?;
        let seed = self.seed.unwrap_or(1);
        let model = ModelConfig::by_name(self.model.as_deref().unwrap_or("nano"))?;

        let (policy, policy_label) = match (&self.tier, self.mu) {
            (Some(tier), None) => (PrecisionPolicy::tier(tier)?, tier.clone()),
            (None, Some(mu)) => {
                let tau = self.tau.ok_or_else(|| {
                    Error::config("trial manifest: [policy] mu requires tau")
                })?;
                let rule = Rule::by_name(self.rule.as_deref().unwrap_or("relaxed"))?;
                let policy = PrecisionPolicy::lamp(mu, tau, rule);
                (policy, format!("lamp(mu={mu}, tau={tau}, rule={})", rule.name()))
            }
            (None, None) => (PrecisionPolicy::tier("balanced")?, "balanced".to_string()),
            (Some(_), Some(_)) => {
                return Err(Error::config(
                    "trial manifest: [policy] tier and mu/tau are mutually exclusive",
                ))
            }
        };

        let kind_name = self
            .trace
            .ok_or_else(|| Error::config("trial manifest: missing [workload] `trace`"))?;
        let kind = TraceKind::by_name(&kind_name)?;
        let mut trace = TraceSpec::new(kind, model.vocab, model.seq);
        trace.seed = seed;
        if let Some(v) = self.requests {
            trace.requests = v;
        }
        if let Some(v) = self.sessions {
            trace.sessions = v;
        }
        if let Some(v) = self.prefix_len {
            trace.prefix_len = v;
        }
        if let Some(v) = self.turn_tokens {
            trace.turn_tokens = v;
        }
        if let Some(v) = self.new_tokens {
            trace.new_tokens = v;
        }
        if let Some(v) = self.zipf_s {
            trace.zipf_s = v;
        }
        if let Some(v) = self.burst {
            trace.burst = v;
        }
        if let Some(v) = self.gap_steps {
            trace.gap_steps = v;
        }
        if let Some(v) = self.rate {
            trace.rate = v;
        }
        if let Some(v) = self.topk {
            trace.topk = v;
        }
        trace.validate()?;

        let kv_format = match &self.kv_format {
            Some(name) => Some(WeightFormat::by_name(name)?),
            None => None,
        };
        if self.repair_tau.is_some() && kv_format.is_none() {
            return Err(Error::config(
                "trial manifest: [kv] repair-tau requires [kv] format",
            ));
        }
        let weight_format = match &self.weight_format {
            Some(name) => Some(WeightFormat::by_name(name)?),
            None => None,
        };

        let fault_seed = self.fault_seed.unwrap_or(seed);
        let (faults, fault_label) = match self.fault_plan.as_deref() {
            None => (None, "none".to_string()),
            Some("quiet") => (Some(FaultPlan::quiet(fault_seed)), "quiet".to_string()),
            Some("chaos") => (Some(FaultPlan::chaos(fault_seed)), "chaos".to_string()),
            Some(other) => {
                return Err(Error::config(format!(
                    "trial manifest: unknown fault plan {other:?} (quiet|chaos)"
                )))
            }
        };

        Ok(TrialManifest {
            name,
            seed,
            model,
            weights_seed: self.weights_seed.unwrap_or(7),
            policy,
            policy_label,
            trace,
            max_sessions: self.max_sessions.unwrap_or(4),
            prefill_chunk: self.prefill_chunk.unwrap_or(8),
            workers: self.workers.unwrap_or(0),
            kv_format,
            repair_tau: self.repair_tau,
            weight_format,
            faults,
            fault_label,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# comment\n\
name = demo\n\
seed = 42\n\
\n\
[model]\n\
config = nano\n\
weights-seed = 9\n\
\n\
[policy]\n\
tier = balanced\n\
\n\
[workload]\n\
trace = prefix-chat   ; inline comment\n\
requests = 9\n\
sessions = 3\n\
prefix-len = 8\n\
turn-tokens = 3\n\
new-tokens = 4\n\
\n\
[scheduler]\n\
max-sessions = 4\n\
workers = 2\n\
\n\
[kv]\n\
format = bf16\n\
repair-tau = 1.0\n\
\n\
[faults]\n\
plan = quiet\n";

    #[test]
    fn parses_a_full_manifest() {
        let m = TrialManifest::parse(GOOD).unwrap();
        assert_eq!(m.name, "demo");
        assert_eq!(m.seed, 42);
        assert_eq!(m.model.name, "nano");
        assert_eq!(m.weights_seed, 9);
        assert_eq!(m.policy_label, "balanced");
        assert_eq!(m.trace.kind, TraceKind::PrefixChat);
        assert_eq!(m.trace.requests, 9);
        assert_eq!(m.trace.seed, 42, "trace reuses the trial seed");
        assert_eq!(m.workers, 2);
        assert_eq!(m.kv_format, Some(WeightFormat::Bf16));
        assert_eq!(m.repair_tau, Some(1.0));
        assert_eq!(m.fault_label, "quiet");
        assert!(m.faults.is_some());
    }

    #[test]
    fn defaults_are_sensible() {
        let m = TrialManifest::parse("name = d\n[workload]\ntrace = zipf-mix\n").unwrap();
        assert_eq!(m.seed, 1);
        assert_eq!(m.model.name, "nano");
        assert_eq!(m.policy_label, "balanced");
        assert_eq!(m.workers, 0);
        assert!(m.kv_format.is_none());
        assert!(m.faults.is_none());
        assert_eq!(m.fault_label, "none");
    }

    #[test]
    fn custom_policy_via_mu_tau_rule() {
        let text = "name = d\n[policy]\nmu = 4\ntau = 0.1\nrule = strict\n\
                    [workload]\ntrace = bursty\n";
        let m = TrialManifest::parse(text).unwrap();
        assert_eq!(m.policy, PrecisionPolicy::lamp(4, 0.1, Rule::Strict));
        assert!(m.policy_label.contains("mu=4"));
    }

    #[test]
    fn unknown_keys_and_sections_are_rejected() {
        let unknown_key = "name = d\n[workload]\ntrace = zipf-mix\nbogus = 1\n";
        let err = TrialManifest::parse(unknown_key).unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        let unknown_section = "name = d\n[nonsense]\nx = 1\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(unknown_section).is_err());
    }

    #[test]
    fn required_fields_and_conflicts_error() {
        assert!(TrialManifest::parse("[workload]\ntrace = zipf-mix\n").is_err(), "no name");
        assert!(TrialManifest::parse("name = d\n").is_err(), "no trace");
        let conflict = "name = d\n[policy]\ntier = high\nmu = 4\ntau = 0.1\n\
                        [workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(conflict).is_err());
        let tau_no_kv = "name = d\n[kv]\nrepair-tau = 1.0\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(tau_no_kv).is_err());
        let bad_value = "name = d\nseed = not-a-number\n[workload]\ntrace = zipf-mix\n";
        assert!(TrialManifest::parse(bad_value).is_err());
    }

    #[test]
    fn workload_knobs_flow_into_the_trace_spec() {
        let text = "name = d\nseed = 5\n[workload]\ntrace = poisson\nrequests = 20\n\
                    rate = 0.5\ntopk = 4\n";
        let m = TrialManifest::parse(text).unwrap();
        assert_eq!(m.trace.kind, TraceKind::Poisson);
        assert_eq!(m.trace.requests, 20);
        assert_eq!(m.trace.rate, 0.5);
        assert_eq!(m.trace.topk, 4);
        // The resulting spec actually generates.
        assert_eq!(m.trace.generate().unwrap().len(), 20);
    }
}
