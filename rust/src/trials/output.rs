//! Canonical trial output: the byte-exact artifact of a trial run.
//!
//! Two runs of the same manifest must produce *identical bytes* here, on
//! any machine and (for fault-free trials) at any worker count. That
//! dictates what the format may contain:
//!
//! * included — per-request token streams, LAMP repair counters (integer
//!   numerator/denominator, never floats), outcomes, and aggregates that
//!   are plain sums over per-request data, everything ordered by request
//!   id;
//! * excluded — anything wall-clock (TTFT/ITL percentiles, elapsed time)
//!   or schedule-dependent (iteration counts, occupancy, preemptions):
//!   those live in the human-readable display output instead.

use crate::coordinator::{GenerateResponse, ReplayReport};
use crate::data::traces::TraceRequest;

use super::manifest::TrialManifest;

/// FNV-1a over the little-endian bytes of a token stream — a compact
/// fingerprint so canonical output can reference prompts without
/// embedding every long prompt verbatim.
pub fn token_fingerprint(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

fn join_tokens(tokens: &[u32]) -> String {
    tokens.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
}

/// Render the canonical, deterministic output of a trial run.
pub fn canonical(
    manifest: &TrialManifest,
    trace: &[TraceRequest],
    report: &ReplayReport,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("trial = {}\n", manifest.name));
    out.push_str(&format!("seed = {}\n", manifest.seed));
    out.push_str(&format!("model = {}\n", manifest.model.name));
    out.push_str(&format!("policy = {}\n", manifest.policy_label));
    out.push_str(&format!(
        "workload = {} requests={}\n",
        manifest.trace.as_ref().map_or("none", |t| t.kind.name()),
        trace.len()
    ));
    out.push_str(&format!(
        "kv = {}\n",
        manifest.kv_format.map(|f| f.label()).unwrap_or_else(|| "off".to_string())
    ));
    out.push_str(&format!(
        "weights = {}\n",
        manifest.weight_format.map(|f| f.label()).unwrap_or_else(|| "f32".to_string())
    ));
    out.push_str(&format!("faults = {}\n", manifest.fault_label));

    // Aggregates as sums over per-request data (schedule-independent).
    let generated: usize = report.responses.iter().map(|r| r.tokens.len() - r.prompt_len).sum();
    let recomputed: usize = report.responses.iter().map(|r| r.stats.recomputed).sum();
    let causal: usize = report.responses.iter().map(|r| r.stats.causal_total).sum();
    out.push_str(&format!("completed = {}\n", report.responses.len()));
    out.push_str(&format!("failed = {}\n", report.failures.len()));
    out.push_str(&format!("generated_tokens = {generated}\n"));
    out.push_str(&format!("attention_recompute = {recomputed}/{causal}\n"));

    for resp in &report.responses {
        out.push_str(&render_response(trace, resp));
    }
    for (id, error) in &report.failures {
        out.push_str(&format!("[request {id}]\n"));
        push_trace_line(&mut out, trace, *id);
        out.push_str(&format!("outcome = failed: {error}\n"));
    }
    out
}

fn push_trace_line(out: &mut String, trace: &[TraceRequest], id: u64) {
    if let Some(r) = trace.get(id as usize) {
        out.push_str(&format!(
            "arrival = {} prompt_len = {} prompt_fnv = {:016x} seed = {}\n",
            r.arrival_step,
            r.prompt.len(),
            token_fingerprint(&r.prompt),
            r.seed
        ));
    }
}

fn render_response(trace: &[TraceRequest], resp: &GenerateResponse) -> String {
    let mut out = String::new();
    out.push_str(&format!("[request {}]\n", resp.id));
    push_trace_line(&mut out, trace, resp.id);
    out.push_str("outcome = completed\n");
    out.push_str(&format!("tokens = {}\n", join_tokens(&resp.tokens[resp.prompt_len..])));
    let s = &resp.stats;
    out.push_str(&format!(
        "attention = {}/{} mlp = {}/{} norm = {}/{} sampler = {}/{}\n",
        s.recomputed,
        s.causal_total,
        s.mlp.recomputed,
        s.mlp.total,
        s.norm.recomputed,
        s.norm.total,
        s.sampler.recomputed,
        s.sampler.total
    ));
    out
}

/// Compare two canonical outputs line by line; `None` means identical.
/// Otherwise returns a human-readable description of the first
/// divergence (1-indexed line number plus both lines).
pub fn first_divergence(a: &str, b: &str) -> Option<String> {
    let la: Vec<&str> = a.lines().collect();
    let lb: Vec<&str> = b.lines().collect();
    for (i, (x, y)) in la.iter().zip(&lb).enumerate() {
        if x != y {
            return Some(format!("line {}:\n  a: {x}\n  b: {y}", i + 1));
        }
    }
    if la.len() != lb.len() {
        return Some(format!(
            "line counts differ: {} vs {} (first {} lines identical)",
            la.len(),
            lb.len(),
            la.len().min(lb.len())
        ));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let a = token_fingerprint(&[1, 2, 3]);
        assert_eq!(a, token_fingerprint(&[1, 2, 3]), "pure function");
        assert_ne!(a, token_fingerprint(&[1, 2, 4]));
        assert_ne!(a, token_fingerprint(&[1, 2]));
        // Known FNV-1a property: hashing nothing gives the offset basis.
        assert_eq!(token_fingerprint(&[]), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn divergence_reporting() {
        assert!(first_divergence("a\nb\n", "a\nb\n").is_none());
        let d = first_divergence("a\nb\n", "a\nc\n").unwrap();
        assert!(d.contains("line 2"), "{d}");
        let d = first_divergence("a\n", "a\nb\n").unwrap();
        assert!(d.contains("line counts differ"), "{d}");
    }
}
