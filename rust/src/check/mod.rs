//! Minimal property-based testing framework (proptest is unavailable
//! offline).
//!
//! Provides seeded generators over common domains and a runner that, on
//! failure, performs greedy shrinking of the failing case before reporting.
//!
//! ```no_run
//! use lamp::check::{Config, Gen, forall};
//! forall(Config::default().cases(200), Gen::f32_range(-10.0, 10.0), |x| {
//!     x.abs() >= 0.0
//! });
//! ```

use crate::util::Rng;

/// Runner configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE, max_shrink_steps: 512 }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A generator: produces values and knows how to shrink them.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Rng) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Gen { gen: Box::new(gen), shrink: Box::new(shrink) }
    }

    /// Generator without shrinking.
    pub fn no_shrink(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen { gen: Box::new(gen), shrink: Box::new(|_| Vec::new()) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking beyond the source).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + Clone + 'static) -> Gen<U> {
        let g = self.gen;
        let s = self.shrink;
        let f2 = f.clone();
        Gen {
            gen: Box::new(move |rng| f(g(rng))),
            shrink: Box::new(move |_u| {
                // We cannot invert f; shrink by regenerating small candidates
                // is unsound, so no shrinking through map.
                let _ = &s;
                let _ = &f2;
                Vec::new()
            }),
        }
    }
}

impl Gen<f32> {
    /// Uniform f32 in [lo, hi) with shrinking toward 0 and midpoints.
    pub fn f32_range(lo: f32, hi: f32) -> Gen<f32> {
        assert!(hi > lo);
        Gen::new(
            move |rng| lo + rng.f32() * (hi - lo),
            |&x| {
                let mut out = Vec::new();
                if x != 0.0 {
                    out.push(0.0);
                    out.push(x / 2.0);
                    out.push(x.trunc());
                }
                out.retain(|&c| c != x);
                out
            },
        )
    }
}

impl Gen<u32> {
    /// Uniform u32 in [lo, hi] with shrinking toward lo.
    pub fn u32_range(lo: u32, hi: u32) -> Gen<u32> {
        assert!(hi >= lo);
        Gen::new(
            move |rng| lo + rng.below((hi - lo + 1) as u64) as u32,
            move |&x| {
                let mut out = Vec::new();
                if x > lo {
                    out.push(lo);
                    out.push(lo + (x - lo) / 2);
                    out.push(x - 1);
                }
                out.retain(|&c| c != x && c >= lo);
                out.dedup();
                out
            },
        )
    }
}

impl Gen<usize> {
    /// Uniform usize in [lo, hi] with shrinking toward lo.
    pub fn usize_range(lo: usize, hi: usize) -> Gen<usize> {
        assert!(hi >= lo);
        Gen::new(
            move |rng| lo + rng.below((hi - lo + 1) as u64) as usize,
            move |&x| {
                let mut out = Vec::new();
                if x > lo {
                    out.push(lo);
                    out.push(lo + (x - lo) / 2);
                    out.push(x - 1);
                }
                out.retain(|&c| c != x && c >= lo);
                out.dedup();
                out
            },
        )
    }
}

impl Gen<Vec<f32>> {
    /// Vector of uniform f32 with length in [min_len, max_len]; shrinks by
    /// halving length and zeroing elements.
    pub fn f32_vec(min_len: usize, max_len: usize, lo: f32, hi: f32) -> Gen<Vec<f32>> {
        assert!(max_len >= min_len && hi > lo);
        Gen::new(
            move |rng| {
                let n = rng.range(min_len, max_len + 1);
                (0..n).map(|_| lo + rng.f32() * (hi - lo)).collect()
            },
            move |v: &Vec<f32>| {
                let mut out = Vec::new();
                if v.len() > min_len {
                    out.push(v[..v.len() / 2.max(min_len)].to_vec());
                    let mut shorter = v.clone();
                    shorter.pop();
                    out.push(shorter);
                }
                if v.iter().any(|&x| x != 0.0) {
                    out.push(v.iter().map(|_| 0.0).collect());
                    let mut halved = v.clone();
                    for x in &mut halved {
                        *x /= 2.0;
                    }
                    out.push(halved);
                }
                out.retain(|c| c.len() >= min_len && c != v);
                out
            },
        )
    }
}

/// Combine two independent generators.
pub fn pair<A: Clone + 'static, B: Clone + 'static>(ga: Gen<A>, gb: Gen<B>) -> Gen<(A, B)> {
    let (gena, shra) = (ga.gen, ga.shrink);
    let (genb, shrb) = (gb.gen, gb.shrink);
    Gen {
        gen: Box::new(move |rng| (gena(rng), genb(rng))),
        shrink: Box::new(move |(a, b)| {
            let mut out: Vec<(A, B)> = Vec::new();
            for sa in shra(a) {
                out.push((sa, b.clone()));
            }
            for sb in shrb(b) {
                out.push((a.clone(), sb));
            }
            out
        }),
    }
}

/// The result of a failed property run.
#[derive(Debug)]
pub struct Failure<T> {
    pub original: T,
    pub shrunk: T,
    pub case_index: usize,
}

/// Run the property over generated cases; returns the shrunk failure if any.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    config: &Config,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) -> Option<Failure<T>> {
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let value = gen.sample(&mut rng);
        if !prop(&value) {
            // Greedy shrink.
            let mut current = value.clone();
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for cand in gen.shrinks(&current) {
                    steps += 1;
                    if !prop(&cand) {
                        current = cand;
                        continue 'outer;
                    }
                    if steps >= config.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            return Some(Failure { original: value, shrunk: current, case_index: case });
        }
    }
    None
}

/// Assert a property holds; panics with the shrunk counterexample otherwise.
pub fn forall<T: Clone + std::fmt::Debug + 'static>(
    config: Config,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    if let Some(fail) = check(&config, &gen, |v| prop(v)) {
        panic!(
            "property falsified at case {}:\n  original: {:?}\n  shrunk:   {:?}",
            fail.case_index, fail.original, fail.shrunk
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(Config::default().cases(100), Gen::f32_range(-5.0, 5.0), |x| {
            x.abs() <= 5.0
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let fail = check(
            &Config::default().cases(500),
            &Gen::u32_range(0, 1000),
            |&x| x < 100,
        )
        .expect("must fail");
        // Shrinking should find a value close to the boundary.
        assert!(fail.shrunk >= 100 && fail.shrunk <= fail.original);
        assert!(fail.shrunk <= 200, "shrunk={}", fail.shrunk);
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = Gen::f32_vec(2, 10, -1.0, 1.0);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = g.sample(&mut rng);
            assert!((2..=10).contains(&v.len()));
            assert!(v.iter().all(|&x| (-1.0..1.0).contains(&x)));
        }
    }

    #[test]
    fn pair_shrinks_both_sides() {
        let g = pair(Gen::u32_range(0, 100), Gen::u32_range(0, 100));
        let shrinks = g.shrinks(&(50, 50));
        assert!(shrinks.iter().any(|&(a, b)| a < 50 && b == 50));
        assert!(shrinks.iter().any(|&(a, b)| a == 50 && b < 50));
    }

    #[test]
    fn deterministic_given_seed() {
        let c = Config::default().seed(9).cases(50);
        let g = Gen::f32_range(0.0, 1.0);
        let mut rng1 = Rng::new(c.seed);
        let mut rng2 = Rng::new(c.seed);
        for _ in 0..50 {
            assert_eq!(g.sample(&mut rng1).to_bits(), g.sample(&mut rng2).to_bits());
        }
    }
}
