//! Runtime-dispatched SIMD kernels with a bit-exact scalar replay.
//!
//! Every kernel here exists in (up to) three bodies — AVX2+FMA, NEON, and
//! a scalar replay — that execute the **same accumulation-chain shape**:
//! the lane count and partial-sum tree are fixed by the format definition,
//! not by the instruction set, so all bodies produce bitwise-identical
//! results (DESIGN.md §SIMD & tiled precision). Dispatch is resolved once
//! per process from runtime CPU feature detection and the `LAMP_SIMD`
//! environment variable (`LAMP_SIMD=0` forces the scalar replay — the CI
//! `test-scalar` job runs the whole suite that way).
//!
//! Chain contracts:
//! * [`dot_block`] — the pinned FP32 reference-dot chain: 4 interleaved
//!   8-lane vector accumulators (32 independent partial sums over 32-wide
//!   blocks), reduced accumulator-pairwise then through a fixed 8-lane
//!   tree, with a sequential-FMA tail. This chain *replaced* the old
//!   4-way-unrolled `dot_unrolled4` pins in PR 8.
//! * [`score_row_ps_simd`] / the PS matvec kernels — vectorization only
//!   interleaves *independent* per-output `round(fma(..))` chains (8 per
//!   vector), each internally identical to the sequential
//!   [`crate::softfloat::dot::dot_ps`] chain, so no pin changed there.
//! * The FP32 matvec kernels vectorize across output columns with
//!   elementwise mul+add — bit-transparent at any width.
//!
//! IEEE-754 gives the equivalences for free: `_mm256_fmadd_ps` /
//! `vfmaq_f32` and scalar [`f32::mul_add`] are all correctly-rounded fused
//! multiply-adds, and vector add/mul are the scalar operations applied
//! lanewise (MXCSR/FPCR defaults: round-to-nearest-even, no FTZ/DAZ).

use super::tensor::bf16_to_f32;
use std::sync::atomic::{AtomicU8, Ordering};

/// Lanes per vector accumulator in the pinned [`dot_block`] chain.
pub const DOT_LANES: usize = 8;
/// Interleaved vector accumulators in the pinned [`dot_block`] chain.
pub const DOT_ACCS: usize = 4;
/// Elements consumed per main-loop iteration of [`dot_block`].
pub const DOT_BLOCK: usize = DOT_LANES * DOT_ACCS;

const MODE_UNINIT: u8 = 0;
const MODE_SCALAR: u8 = 1;
const MODE_SIMD: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

/// True iff this build/CPU has a vector backend at all.
fn detect() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return MODE_SIMD;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return MODE_SIMD;
        }
    }
    MODE_SCALAR
}

fn resolve() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m != MODE_UNINIT {
        return m;
    }
    // LAMP_SIMD: unset/1/true/yes/on → use the vector backend when the CPU
    // has one; 0/false/no/off → force the scalar replay.
    let enabled = match std::env::var("LAMP_SIMD") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "0" | "false" | "no" | "off"
        ),
        Err(_) => true,
    };
    let m = if enabled { detect() } else { MODE_SCALAR };
    MODE.store(m, Ordering::Relaxed);
    m
}

/// Whether the vector backend is active (false ⇒ every kernel runs its
/// scalar replay, which is bitwise identical by construction).
#[inline]
pub fn simd_enabled() -> bool {
    resolve() == MODE_SIMD
}

/// Force the dispatch mode (benches/tests). Returns the mode that actually
/// took effect: requesting SIMD on a CPU without a backend stays scalar.
/// Process-global; racing toggles are benign for correctness because both
/// modes produce identical bits, but perf measurements should serialize.
pub fn set_simd_enabled(on: bool) -> bool {
    let m = if on { detect() } else { MODE_SCALAR };
    MODE.store(m, Ordering::Relaxed);
    m == MODE_SIMD
}

/// Human-readable label of the active backend (bench records, `lamp info`).
pub fn simd_backend() -> &'static str {
    if simd_enabled() {
        #[cfg(target_arch = "x86_64")]
        {
            return "avx2+fma";
        }
        #[cfg(target_arch = "aarch64")]
        {
            return "neon";
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            return "scalar";
        }
    }
    "scalar"
}

// --------------------------------------------------------------------------
// dot_block — the pinned FP32 reference-dot chain
// --------------------------------------------------------------------------

/// Fixed 8-lane reduction tree of the [`dot_block`] chain:
/// `t_m = w[m] + w[m+4]` then `(t0 + t2) + (t1 + t3)` — exactly the
/// extract/movehl/shuffle add sequence of the AVX2 body.
#[inline]
fn reduce8(w: &[f32; DOT_LANES]) -> f32 {
    let t0 = w[0] + w[4];
    let t1 = w[1] + w[5];
    let t2 = w[2] + w[6];
    let t3 = w[3] + w[7];
    (t0 + t2) + (t1 + t3)
}

/// Scalar replay of the pinned [`dot_block`] chain. Public so parity tests
/// can compare it against the dispatched kernel explicitly.
pub fn dot_block_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let mut s = [[0.0f32; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                let i = p + u * DOT_LANES + l;
                *sl = a[i].mul_add(b[i], *sl);
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [0.0f32; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce8(&w);
    while p < k {
        r = a[p].mul_add(b[p], r);
        p += 1;
    }
    r
}

/// bf16 twin of [`dot_block_scalar`] — the identical chain on in-register
/// widened weights.
pub fn dot_block_bf16_scalar(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let mut s = [[0.0f32; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                let i = p + u * DOT_LANES + l;
                *sl = a[i].mul_add(bf16_to_f32(b[i]), *sl);
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [0.0f32; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce8(&w);
    while p < k {
        r = a[p].mul_add(bf16_to_f32(b[p]), r);
        p += 1;
    }
    r
}

/// The pinned FP32 reference dot product (see module docs), dispatched to
/// the active backend. Always bitwise equal to [`dot_block_scalar`].
#[inline]
pub fn dot_block(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::dot_block(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after neon detection.
        return unsafe { neon::dot_block(a, b) };
    }
    dot_block_scalar(a, b)
}

/// bf16 twin of [`dot_block`].
#[inline]
pub fn dot_block_bf16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::dot_block_bf16(a, b) };
    }
    dot_block_bf16_scalar(a, b)
}

// --------------------------------------------------------------------------
// Vectorized per-row kernels (dispatchers return false ⇒ caller runs its
// scalar body, which is the defining chain)
// --------------------------------------------------------------------------

/// Fused causal score row with 8 interleaved independent PS(μ) chains per
/// vector. Returns false when no vector backend is active (the caller's
/// scalar body is the reference chain and produces identical bits).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn score_row_ps_simd(
    q: &[f32],
    keys: &[f32],
    stride: usize,
    n: usize,
    mu: u32,
    scale: f32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::score_row_ps(q, keys, stride, n, mu, scale, out) };
        return true;
    }
    let _ = (q, keys, stride, n, mu, scale, out);
    false
}

/// Vectorized `out[j] += x_p · w[p][j]` matvec body (mul+add, elementwise —
/// bit-transparent at any lane width). Returns false when scalar.
#[inline]
pub fn matvec_f32_simd(x_row: &[f32], wdata: &[f32], n: usize, bias: &[f32], out: &mut [f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_f32(x_row, wdata, n, bias, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, out);
    false
}

/// bf16 twin of [`matvec_f32_simd`].
#[inline]
pub fn matvec_bf16_simd(x_row: &[f32], wdata: &[u16], n: usize, bias: &[f32], out: &mut [f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_bf16(x_row, wdata, n, bias, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, out);
    false
}

/// Vectorized PS(μ) matvec body: per output column the per-step
/// `round(fma(..))` chain over p, 8 independent columns per vector.
/// Returns false when scalar.
#[inline]
pub fn matvec_ps_simd(
    x_row: &[f32],
    wdata: &[f32],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_ps(x_row, wdata, n, bias, mu, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, mu, out);
    false
}

/// bf16 twin of [`matvec_ps_simd`].
#[inline]
pub fn matvec_ps_bf16_simd(
    x_row: &[f32],
    wdata: &[u16],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_ps_bf16(x_row, wdata, n, bias, mu, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, mu, out);
    false
}

/// Register-blocked 4-row FP32 micro-kernel: four x rows against one
/// streamed weight panel, 8 output columns per vector, each output's
/// p-ascending mul+add order identical to the single-row matvec (so the
/// blocked matmul stays bitwise equal to per-row kernels). Returns false
/// when scalar — the caller then runs per-row matvecs.
#[inline]
pub fn matvec4_f32_simd(
    xs: [&[f32]; 4],
    wdata: &[f32],
    n: usize,
    bias: &[f32],
    outs: [&mut [f32]; 4],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec4_f32(xs, wdata, n, bias, outs) };
        return true;
    }
    let _ = (xs, wdata, n, bias, outs);
    false
}

/// bf16 twin of [`matvec4_f32_simd`].
#[inline]
pub fn matvec4_bf16_simd(
    xs: [&[f32]; 4],
    wdata: &[u16],
    n: usize,
    bias: &[f32],
    outs: [&mut [f32]; 4],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec4_bf16(xs, wdata, n, bias, outs) };
        return true;
    }
    let _ = (xs, wdata, n, bias, outs);
    false
}

// --------------------------------------------------------------------------
// AVX2 + FMA backend
// --------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{DOT_BLOCK, DOT_LANES};
    use crate::softfloat::dot::dot_ps;
    use std::arch::x86_64::*;

    /// Key-tile transposition chunk of the score-row kernel (in f32s per
    /// column): sized so the 8-column scratch tile (8·64·4 B = 2 KiB) stays
    /// resident in L1 while the chains advance through it.
    const PCHUNK: usize = 64;

    /// 8-lane horizontal sum implementing exactly the [`super::reduce8`]
    /// tree: `t_m = w[m] + w[m+4]`, then `(t0 + t2) + (t1 + t3)`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum8(w: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(w);
        let hi = _mm256_extractf128_ps::<1>(w);
        let t = _mm_add_ps(lo, hi); // (t0, t1, t2, t3)
        let pair = _mm_add_ps(t, _mm_movehl_ps(t, t)); // (t0+t2, t1+t3, ..)
        let one = _mm_add_ss(pair, _mm_shuffle_ps::<0b01>(pair, pair));
        _mm_cvtss_f32(one)
    }

    /// Widen 8 bf16 values (stored as u16) to f32 lanes: zero-extend to
    /// 32 bits and shift into the high half — the vector form of
    /// [`crate::linalg::tensor::bf16_to_f32`].
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen_bf16(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    /// Vector round-to-μ-mantissa-bits (RNE), lanewise identical to
    /// [`crate::softfloat::round::round_to_mantissa`]: the same integer
    /// bias-add-truncate on finite lanes, with NaN/±inf lanes passed
    /// through unchanged via the finite blend (without it, the bias add
    /// could carry a NaN payload into the sign bit).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn round8(x: __m256, shift: i32, cnt: __m128i, half: __m256i) -> __m256 {
        debug_assert!((1..=22).contains(&shift));
        let u = _mm256_castps_si256(x);
        let lsb = _mm256_and_si256(_mm256_srl_epi32(u, cnt), _mm256_set1_epi32(1));
        let bias = _mm256_add_epi32(half, lsb);
        let r = _mm256_sll_epi32(_mm256_srl_epi32(_mm256_add_epi32(u, bias), cnt), cnt);
        let rounded = _mm256_castsi256_ps(r);
        let abs = _mm256_and_ps(x, _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF)));
        let finite = _mm256_cmp_ps::<_CMP_LT_OQ>(abs, _mm256_set1_ps(f32::INFINITY));
        _mm256_blendv_ps(x, rounded, finite)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_block(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(p)), _mm256_loadu_ps(bp.add(p)), s0);
            s1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + DOT_LANES)),
                s1,
            );
            s2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 2 * DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + 2 * DOT_LANES)),
                s2,
            );
            s3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 3 * DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + 3 * DOT_LANES)),
                s3,
            );
            p += DOT_BLOCK;
        }
        let w = _mm256_add_ps(_mm256_add_ps(s0, s1), _mm256_add_ps(s2, s3));
        let mut r = hsum8(w);
        while p < k {
            r = a[p].mul_add(b[p], r);
            p += 1;
        }
        r
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_block_bf16(a: &[f32], b: &[u16]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(p)), widen_bf16(bp.add(p)), s0);
            s1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + DOT_LANES)),
                widen_bf16(bp.add(p + DOT_LANES)),
                s1,
            );
            s2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 2 * DOT_LANES)),
                widen_bf16(bp.add(p + 2 * DOT_LANES)),
                s2,
            );
            s3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 3 * DOT_LANES)),
                widen_bf16(bp.add(p + 3 * DOT_LANES)),
                s3,
            );
            p += DOT_BLOCK;
        }
        let w = _mm256_add_ps(_mm256_add_ps(s0, s1), _mm256_add_ps(s2, s3));
        let mut r = hsum8(w);
        while p < k {
            r = a[p].mul_add(super::bf16_to_f32(b[p]), r);
            p += 1;
        }
        r
    }

    /// 8 interleaved independent PS(μ) score chains. The key columns are
    /// strided in the KV buffer, so each 8-column group is first
    /// transposed into a stack tile (PCHUNK × 8) and the chains then read
    /// it with contiguous vector loads — the cache-blocking that makes
    /// the gather-free inner loop possible on both AVX2 and NEON layouts.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn score_row_ps(
        q: &[f32],
        keys: &[f32],
        stride: usize,
        n: usize,
        mu: u32,
        scale: f32,
        out: &mut [f32],
    ) {
        let hd = q.len();
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let scale_v = _mm256_set1_ps(scale);
        let mut tbuf = [0.0f32; PCHUNK * 8];
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_ps();
            let mut p0 = 0;
            while p0 < hd {
                let pc = (hd - p0).min(PCHUNK);
                for l in 0..8 {
                    let col = &keys[(j + l) * stride + p0..(j + l) * stride + p0 + pc];
                    for (pp, &kv) in col.iter().enumerate() {
                        tbuf[pp * 8 + l] = kv;
                    }
                }
                if mu == 23 {
                    for (pp, &qp) in q[p0..p0 + pc].iter().enumerate() {
                        let kv = _mm256_loadu_ps(tbuf.as_ptr().add(pp * 8));
                        acc = _mm256_fmadd_ps(_mm256_set1_ps(qp), kv, acc);
                    }
                } else {
                    for (pp, &qp) in q[p0..p0 + pc].iter().enumerate() {
                        let kv = _mm256_loadu_ps(tbuf.as_ptr().add(pp * 8));
                        acc = round8(_mm256_fmadd_ps(_mm256_set1_ps(qp), kv, acc), shift, cnt, half);
                    }
                }
                p0 += pc;
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_mul_ps(acc, scale_v));
            j += 8;
        }
        while j < n {
            out[j] = dot_ps(q, &keys[j * stride..j * stride + hd], mu) * scale;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_f32(x_row: &[f32], wdata: &[f32], n: usize, bias: &[f32], out: &mut [f32]) {
        init_out(bias, out);
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = _mm256_loadu_ps(wrow.add(j));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(o, _mm256_mul_ps(xb, w)));
                j += 8;
            }
            while j < n {
                *op.add(j) += xv * *wrow.add(j);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_bf16(x_row: &[f32], wdata: &[u16], n: usize, bias: &[f32], out: &mut [f32]) {
        init_out(bias, out);
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = widen_bf16(wrow.add(j));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(o, _mm256_mul_ps(xb, w)));
                j += 8;
            }
            while j < n {
                *op.add(j) += xv * super::bf16_to_f32(*wrow.add(j));
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_ps(
        x_row: &[f32],
        wdata: &[f32],
        n: usize,
        bias: &[f32],
        mu: u32,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = _mm256_loadu_ps(wrow.add(j));
                let f = _mm256_fmadd_ps(xb, w, o);
                let r = if mu == 23 { f } else { round8(f, shift, cnt, half) };
                _mm256_storeu_ps(op.add(j), r);
                j += 8;
            }
            while j < n {
                let f = xv.mul_add(*wrow.add(j), *op.add(j));
                *op.add(j) = crate::softfloat::round::round_to_mantissa(f, mu);
                j += 1;
            }
        }
        if !bias.is_empty() {
            for (o, &b) in out.iter_mut().zip(bias) {
                *o += b;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_ps_bf16(
        x_row: &[f32],
        wdata: &[u16],
        n: usize,
        bias: &[f32],
        mu: u32,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = widen_bf16(wrow.add(j));
                let f = _mm256_fmadd_ps(xb, w, o);
                let r = if mu == 23 { f } else { round8(f, shift, cnt, half) };
                _mm256_storeu_ps(op.add(j), r);
                j += 8;
            }
            while j < n {
                let f = xv.mul_add(super::bf16_to_f32(*wrow.add(j)), *op.add(j));
                *op.add(j) = crate::softfloat::round::round_to_mantissa(f, mu);
                j += 1;
            }
        }
        if !bias.is_empty() {
            for (o, &b) in out.iter_mut().zip(bias) {
                *o += b;
            }
        }
    }

    #[inline]
    unsafe fn init_out(bias: &[f32], out: &mut [f32]) {
        if bias.is_empty() {
            out.fill(0.0);
        } else {
            out.copy_from_slice(bias);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec4_f32(
        xs: [&[f32]; 4],
        wdata: &[f32],
        n: usize,
        bias: &[f32],
        mut outs: [&mut [f32]; 4],
    ) {
        let k = xs[0].len();
        for o in outs.iter_mut() {
            init_out(bias, o);
        }
        let ops = [
            outs[0].as_mut_ptr(),
            outs[1].as_mut_ptr(),
            outs[2].as_mut_ptr(),
            outs[3].as_mut_ptr(),
        ];
        let mut j = 0;
        // 8-column panel held in 4 register accumulators across all of p;
        // W is streamed once per 4 output rows (the register blocking).
        while j + 8 <= n {
            let mut a0 = _mm256_loadu_ps(ops[0].add(j));
            let mut a1 = _mm256_loadu_ps(ops[1].add(j));
            let mut a2 = _mm256_loadu_ps(ops[2].add(j));
            let mut a3 = _mm256_loadu_ps(ops[3].add(j));
            for p in 0..k {
                let w = _mm256_loadu_ps(wdata.as_ptr().add(p * n + j));
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_set1_ps(xs[0][p]), w));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_set1_ps(xs[1][p]), w));
                a2 = _mm256_add_ps(a2, _mm256_mul_ps(_mm256_set1_ps(xs[2][p]), w));
                a3 = _mm256_add_ps(a3, _mm256_mul_ps(_mm256_set1_ps(xs[3][p]), w));
            }
            _mm256_storeu_ps(ops[0].add(j), a0);
            _mm256_storeu_ps(ops[1].add(j), a1);
            _mm256_storeu_ps(ops[2].add(j), a2);
            _mm256_storeu_ps(ops[3].add(j), a3);
            j += 8;
        }
        while j < n {
            for (u, &op) in ops.iter().enumerate() {
                let mut o = *op.add(j);
                for p in 0..k {
                    o += xs[u][p] * wdata[p * n + j];
                }
                *op.add(j) = o;
            }
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec4_bf16(
        xs: [&[f32]; 4],
        wdata: &[u16],
        n: usize,
        bias: &[f32],
        mut outs: [&mut [f32]; 4],
    ) {
        let k = xs[0].len();
        for o in outs.iter_mut() {
            init_out(bias, o);
        }
        let ops = [
            outs[0].as_mut_ptr(),
            outs[1].as_mut_ptr(),
            outs[2].as_mut_ptr(),
            outs[3].as_mut_ptr(),
        ];
        let mut j = 0;
        while j + 8 <= n {
            let mut a0 = _mm256_loadu_ps(ops[0].add(j));
            let mut a1 = _mm256_loadu_ps(ops[1].add(j));
            let mut a2 = _mm256_loadu_ps(ops[2].add(j));
            let mut a3 = _mm256_loadu_ps(ops[3].add(j));
            for p in 0..k {
                let w = widen_bf16(wdata.as_ptr().add(p * n + j));
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_set1_ps(xs[0][p]), w));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_set1_ps(xs[1][p]), w));
                a2 = _mm256_add_ps(a2, _mm256_mul_ps(_mm256_set1_ps(xs[2][p]), w));
                a3 = _mm256_add_ps(a3, _mm256_mul_ps(_mm256_set1_ps(xs[3][p]), w));
            }
            _mm256_storeu_ps(ops[0].add(j), a0);
            _mm256_storeu_ps(ops[1].add(j), a1);
            _mm256_storeu_ps(ops[2].add(j), a2);
            _mm256_storeu_ps(ops[3].add(j), a3);
            j += 8;
        }
        while j < n {
            for (u, &op) in ops.iter().enumerate() {
                let mut o = *op.add(j);
                for p in 0..k {
                    o += xs[u][p] * super::bf16_to_f32(wdata[p * n + j]);
                }
                *op.add(j) = o;
            }
            j += 1;
        }
    }
}

// --------------------------------------------------------------------------
// NEON backend (minimal: the pinned reference-dot chain; every other kernel
// falls back to the scalar replay, which is bitwise identical)
// --------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::DOT_BLOCK;
    use std::arch::aarch64::*;

    /// The pinned [`super::dot_block`] chain on NEON: the 8-lane vector
    /// accumulators are register pairs (low/high float32x4), reduced with
    /// the same fixed tree — `t_m = w[m] + w[m+4]` is `vaddq(w_lo, w_hi)`,
    /// then `(t0 + t2) + (t1 + t3)` via the 64-bit halves.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_block(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut lo = [vdupq_n_f32(0.0); 4];
        let mut hi = [vdupq_n_f32(0.0); 4];
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            for u in 0..4 {
                let base = p + u * 8;
                lo[u] = vfmaq_f32(lo[u], vld1q_f32(ap.add(base)), vld1q_f32(bp.add(base)));
                hi[u] = vfmaq_f32(hi[u], vld1q_f32(ap.add(base + 4)), vld1q_f32(bp.add(base + 4)));
            }
            p += DOT_BLOCK;
        }
        let w_lo = vaddq_f32(vaddq_f32(lo[0], lo[1]), vaddq_f32(lo[2], lo[3]));
        let w_hi = vaddq_f32(vaddq_f32(hi[0], hi[1]), vaddq_f32(hi[2], hi[3]));
        let t = vaddq_f32(w_lo, w_hi); // (t0, t1, t2, t3)
        let pair = vadd_f32(vget_low_f32(t), vget_high_f32(t)); // (t0+t2, t1+t3)
        let mut r = vget_lane_f32::<0>(pair) + vget_lane_f32::<1>(pair);
        while p < k {
            r = a[p].mul_add(b[p], r);
            p += 1;
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::Mutex;

    /// Serializes tests that toggle the process-global dispatch mode.
    /// (Tests that don't take this lock are mode-agnostic: both modes
    /// produce identical bits.)
    pub(crate) static MODE_LOCK: Mutex<()> = Mutex::new(());

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect()
    }

    #[test]
    fn backend_label_consistent_with_mode() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        assert!(!set_simd_enabled(false));
        assert_eq!(simd_backend(), "scalar");
        let took = set_simd_enabled(true);
        if took {
            assert_ne!(simd_backend(), "scalar");
        } else {
            assert_eq!(simd_backend(), "scalar");
        }
        set_simd_enabled(had);
    }

    #[test]
    fn dot_block_simd_matches_scalar_replay_all_tails() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0x51AD);
        // Every tail class around the 32-wide block and 8-wide lane edges.
        for k in [0usize, 1, 7, 8, 9, 31, 32, 33, 63, 64, 65, 96, 257] {
            let a = randvec(&mut rng, k);
            let b = randvec(&mut rng, k);
            let bq: Vec<u16> = b.iter().map(|&x| crate::linalg::tensor::f32_to_bf16(x)).collect();
            set_simd_enabled(true);
            let fast = dot_block(&a, &b);
            let fast_bf = dot_block_bf16(&a, &bq);
            set_simd_enabled(false);
            let slow = dot_block(&a, &b);
            let slow_bf = dot_block_bf16(&a, &bq);
            assert_eq!(fast.to_bits(), slow.to_bits(), "k={k}");
            assert_eq!(slow.to_bits(), dot_block_scalar(&a, &b).to_bits(), "k={k}");
            assert_eq!(fast_bf.to_bits(), slow_bf.to_bits(), "bf16 k={k}");
            assert_eq!(
                slow_bf.to_bits(),
                dot_block_bf16_scalar(&a, &bq).to_bits(),
                "bf16 k={k}"
            );
        }
        set_simd_enabled(had);
    }

    #[test]
    fn dot_block_close_to_f64_reference() {
        let mut rng = Rng::new(0xACC);
        for _ in 0..50 {
            let k = rng.range(1, 300);
            let a = randvec(&mut rng, k);
            let b = randvec(&mut rng, k);
            let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot_block(&a, &b) as f64;
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            assert!((got - exact).abs() <= 1e-4 * mag.max(1.0), "k={k}");
        }
    }

    #[test]
    fn score_row_simd_matches_scalar_chain_including_specials() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0x5C0E);
        for _ in 0..30 {
            let hd = rng.range(1, 80); // crosses the PCHUNK=64 boundary via accumulation
            let n = rng.range(1, 21); // crosses the 8-wide column block boundary
            let stride = hd + rng.range(0, 5);
            let q = randvec(&mut rng, hd);
            let mut keys = randvec(&mut rng, n * stride);
            // Poison a lane with an overflow-prone magnitude so the rounded
            // chain can hit ±inf and exercise the passthrough blend.
            if n > 2 && hd > 1 {
                keys[stride + 1] = 3.0e38;
            }
            for mu in [1u32, 4, 11, 23] {
                let scale = 1.0 / (hd as f32).sqrt();
                let mut fast = vec![0.0f32; n];
                let mut slow = vec![0.0f32; n];
                if set_simd_enabled(true) {
                    assert!(score_row_ps_simd(&q, &keys, stride, n, mu, scale, &mut fast));
                } else {
                    // Host without a backend: nothing to cross-check.
                    set_simd_enabled(had);
                    return;
                }
                set_simd_enabled(false);
                assert!(!score_row_ps_simd(&q, &keys, stride, n, mu, scale, &mut slow));
                crate::softfloat::dot::score_row_ps(&q, &keys, stride, n, mu, scale, &mut slow);
                for j in 0..n {
                    assert_eq!(fast[j].to_bits(), slow[j].to_bits(), "j={j} mu={mu} hd={hd}");
                }
            }
        }
        set_simd_enabled(had);
    }

    #[test]
    fn scalar_replay_reduction_tree_shape() {
        // Pin the chain shape itself: a 32-element block must reduce as
        // lanewise accumulator pairs then the fixed 8-lane tree — i.e. the
        // scalar replay is NOT a sequential sum. Constructed so the two
        // orders differ in f32.
        let mut a = vec![0.0f32; 32];
        let b = vec![1.0f32; 32];
        a[0] = 1.0e8;
        a[1] = 1.0;
        a[8] = -1.0e8;
        let got = dot_block_scalar(&a, &b);
        // Chain: w[0] = (1e8 + (-1e8)) + 0 = 0, w[1] = 1 → tree sums to 1.
        assert_eq!(got, 1.0);
        // A sequential left-to-right sum would have absorbed the 1.0:
        let seq: f32 = a.iter().zip(&b).fold(0.0, |c, (&x, &y)| x.mul_add(y, c));
        assert_eq!(seq, 0.0);
    }
}
