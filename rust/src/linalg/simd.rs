//! Runtime-dispatched SIMD kernels with a bit-exact scalar replay.
//!
//! Every kernel here exists in (up to) three bodies — AVX2+FMA, NEON, and
//! a scalar replay — that execute the **same accumulation-chain shape**:
//! the lane count and partial-sum tree are fixed by the format definition,
//! not by the instruction set, so all bodies produce bitwise-identical
//! results (DESIGN.md §SIMD & tiled precision). Dispatch is resolved once
//! per process from runtime CPU feature detection and the `LAMP_SIMD`
//! environment variable (`LAMP_SIMD=0` forces the scalar replay — the CI
//! `test-scalar` job runs the whole suite that way).
//!
//! Chain contracts:
//! * [`dot_block`] — the pinned FP32 reference-dot chain: 4 interleaved
//!   8-lane vector accumulators (32 independent partial sums over 32-wide
//!   blocks), reduced accumulator-pairwise then through a fixed 8-lane
//!   tree, with a sequential-FMA tail. This chain *replaced* the old
//!   4-way-unrolled `dot_unrolled4` pins in PR 8.
//! * [`score_row_ps_simd`] / the PS matvec kernels — vectorization only
//!   interleaves *independent* per-output `round(fma(..))` chains (8 per
//!   vector), each internally identical to the sequential
//!   [`crate::softfloat::dot::dot_ps`] chain, so no pin changed there.
//! * The FP32 matvec kernels vectorize across output columns with
//!   elementwise mul+add — bit-transparent at any width.
//! * [`row_max`] / [`row_sum`] — the pinned softmax row chains (PR 9):
//!   the same 4×8 accumulator block shape as [`dot_block`] with lanewise
//!   max / add in place of FMA, reduced through the same fixed trees. The
//!   lanewise max is the AVX `max` (`if a > b { a } else { b }` — second
//!   operand on ties/NaN), spelled out in the replay.
//! * [`row_sum_f64`] / [`row_sumsq_dev`] — the pinned layernorm moment
//!   chains: 4 interleaved 4-lane f64 vector accumulators over 16-wide
//!   blocks (f32 inputs widened exactly), reduced accumulator-pairwise
//!   then through a fixed 4-lane tree.
//! * The elementwise row kernels ([`div_row_simd`], [`norm_finish_simd`],
//!   [`round_row_simd`]) apply lanewise scalar operations —
//!   bit-transparent at any width.
//!
//! IEEE-754 gives the equivalences for free: `_mm256_fmadd_ps` /
//! `vfmaq_f32` and scalar [`f32::mul_add`] are all correctly-rounded fused
//! multiply-adds, and vector add/mul are the scalar operations applied
//! lanewise (MXCSR/FPCR defaults: round-to-nearest-even, no FTZ/DAZ).

use super::tensor::bf16_to_f32;
use std::sync::atomic::{AtomicU8, Ordering};

/// Lanes per vector accumulator in the pinned [`dot_block`] chain.
pub const DOT_LANES: usize = 8;
/// Interleaved vector accumulators in the pinned [`dot_block`] chain.
pub const DOT_ACCS: usize = 4;
/// Elements consumed per main-loop iteration of [`dot_block`].
pub const DOT_BLOCK: usize = DOT_LANES * DOT_ACCS;

/// Lanes per f64 vector accumulator in the pinned moment chains
/// ([`row_sum_f64`], [`row_sumsq_dev`]).
pub const SUM64_LANES: usize = 4;
/// Interleaved f64 vector accumulators in the pinned moment chains.
pub const SUM64_ACCS: usize = 4;
/// Elements consumed per main-loop iteration of the f64 moment chains.
pub const SUM64_BLOCK: usize = SUM64_LANES * SUM64_ACCS;

const MODE_UNINIT: u8 = 0;
const MODE_SCALAR: u8 = 1;
const MODE_SIMD: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

/// True iff this build/CPU has a vector backend at all.
fn detect() -> u8 {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return MODE_SIMD;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return MODE_SIMD;
        }
    }
    MODE_SCALAR
}

fn resolve() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m != MODE_UNINIT {
        return m;
    }
    // LAMP_SIMD: unset/1/true/yes/on → use the vector backend when the CPU
    // has one; 0/false/no/off → force the scalar replay.
    let enabled = match std::env::var("LAMP_SIMD") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "0" | "false" | "no" | "off"
        ),
        Err(_) => true,
    };
    let m = if enabled { detect() } else { MODE_SCALAR };
    MODE.store(m, Ordering::Relaxed);
    m
}

/// Whether the vector backend is active (false ⇒ every kernel runs its
/// scalar replay, which is bitwise identical by construction).
#[inline]
pub fn simd_enabled() -> bool {
    resolve() == MODE_SIMD
}

/// Force the dispatch mode (benches/tests). Returns the mode that actually
/// took effect: requesting SIMD on a CPU without a backend stays scalar.
/// Process-global; racing toggles are benign for correctness because both
/// modes produce identical bits, but perf measurements should serialize.
pub fn set_simd_enabled(on: bool) -> bool {
    let m = if on { detect() } else { MODE_SCALAR };
    MODE.store(m, Ordering::Relaxed);
    m == MODE_SIMD
}

/// Human-readable label of the active backend (bench records, `lamp info`).
pub fn simd_backend() -> &'static str {
    if simd_enabled() {
        #[cfg(target_arch = "x86_64")]
        {
            return "avx2+fma";
        }
        #[cfg(target_arch = "aarch64")]
        {
            return "neon";
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            return "scalar";
        }
    }
    "scalar"
}

// --------------------------------------------------------------------------
// dot_block — the pinned FP32 reference-dot chain
// --------------------------------------------------------------------------

/// Fixed 8-lane reduction tree of the [`dot_block`] chain:
/// `t_m = w[m] + w[m+4]` then `(t0 + t2) + (t1 + t3)` — exactly the
/// extract/movehl/shuffle add sequence of the AVX2 body.
#[inline]
fn reduce8(w: &[f32; DOT_LANES]) -> f32 {
    let t0 = w[0] + w[4];
    let t1 = w[1] + w[5];
    let t2 = w[2] + w[6];
    let t3 = w[3] + w[7];
    (t0 + t2) + (t1 + t3)
}

/// Scalar replay of the pinned [`dot_block`] chain. Public so parity tests
/// can compare it against the dispatched kernel explicitly.
pub fn dot_block_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let mut s = [[0.0f32; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                let i = p + u * DOT_LANES + l;
                *sl = a[i].mul_add(b[i], *sl);
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [0.0f32; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce8(&w);
    while p < k {
        r = a[p].mul_add(b[p], r);
        p += 1;
    }
    r
}

/// bf16 twin of [`dot_block_scalar`] — the identical chain on in-register
/// widened weights.
pub fn dot_block_bf16_scalar(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let mut s = [[0.0f32; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                let i = p + u * DOT_LANES + l;
                *sl = a[i].mul_add(bf16_to_f32(b[i]), *sl);
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [0.0f32; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce8(&w);
    while p < k {
        r = a[p].mul_add(bf16_to_f32(b[p]), r);
        p += 1;
    }
    r
}

/// The pinned FP32 reference dot product (see module docs), dispatched to
/// the active backend. Always bitwise equal to [`dot_block_scalar`].
#[inline]
pub fn dot_block(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::dot_block(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after neon detection.
        return unsafe { neon::dot_block(a, b) };
    }
    dot_block_scalar(a, b)
}

/// bf16 twin of [`dot_block`].
#[inline]
pub fn dot_block_bf16(a: &[f32], b: &[u16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::dot_block_bf16(a, b) };
    }
    dot_block_bf16_scalar(a, b)
}

// --------------------------------------------------------------------------
// Pinned row-reduction chains (softmax & layernorm)
// --------------------------------------------------------------------------

/// The lanewise max of the pinned [`row_max`] chain: AVX `max` semantics —
/// `if a > b { a } else { b }`, second operand on ties and NaN — which
/// differ from [`f32::max`], so the replay spells them out.
#[inline]
fn vmax(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

/// Fixed 4-lane reduction tree of the f64 moment chains:
/// `(w[0] + w[2]) + (w[1] + w[3])` — exactly the extract/unpackhi add
/// sequence of the AVX2 body.
#[inline]
fn reduce4(w: &[f64; SUM64_LANES]) -> f64 {
    (w[0] + w[2]) + (w[1] + w[3])
}

/// Scalar replay of the pinned row-max chain: the [`dot_block`] block shape
/// with [`vmax`] in place of FMA. Returns −∞ on an empty row.
pub fn row_max_scalar(y: &[f32]) -> f32 {
    let k = y.len();
    let mut s = [[f32::NEG_INFINITY; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                *sl = vmax(*sl, y[p + u * DOT_LANES + l]);
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [f32::NEG_INFINITY; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = vmax(vmax(s[0][l], s[1][l]), vmax(s[2][l], s[3][l]));
    }
    let t0 = vmax(w[0], w[4]);
    let t1 = vmax(w[1], w[5]);
    let t2 = vmax(w[2], w[6]);
    let t3 = vmax(w[3], w[7]);
    let mut r = vmax(vmax(t0, t2), vmax(t1, t3));
    while p < k {
        r = vmax(r, y[p]);
        p += 1;
    }
    r
}

/// Scalar replay of the pinned row-sum chain: the [`dot_block`] block shape
/// with lanewise add in place of FMA.
pub fn row_sum_scalar(y: &[f32]) -> f32 {
    let k = y.len();
    let mut s = [[0.0f32; DOT_LANES]; DOT_ACCS];
    let mut p = 0;
    while p + DOT_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                *sl += y[p + u * DOT_LANES + l];
            }
        }
        p += DOT_BLOCK;
    }
    let mut w = [0.0f32; DOT_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce8(&w);
    while p < k {
        r += y[p];
        p += 1;
    }
    r
}

/// Scalar replay of the pinned f64 sum chain (layernorm mean): 4×4 f64
/// accumulators over 16-wide blocks, each f32 widened exactly.
pub fn row_sum_f64_scalar(x: &[f32]) -> f64 {
    let k = x.len();
    let mut s = [[0.0f64; SUM64_LANES]; SUM64_ACCS];
    let mut p = 0;
    while p + SUM64_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                *sl += x[p + u * SUM64_LANES + l] as f64;
            }
        }
        p += SUM64_BLOCK;
    }
    let mut w = [0.0f64; SUM64_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce4(&w);
    while p < k {
        r += x[p] as f64;
        p += 1;
    }
    r
}

/// Scalar replay of the pinned f64 squared-deviation chain (layernorm
/// variance): per element `d = x − mean` then `fma(d, d, acc)`, same block
/// shape as [`row_sum_f64_scalar`].
pub fn row_sumsq_dev_scalar(x: &[f32], mean: f64) -> f64 {
    let k = x.len();
    let mut s = [[0.0f64; SUM64_LANES]; SUM64_ACCS];
    let mut p = 0;
    while p + SUM64_BLOCK <= k {
        for (u, acc) in s.iter_mut().enumerate() {
            for (l, sl) in acc.iter_mut().enumerate() {
                let d = x[p + u * SUM64_LANES + l] as f64 - mean;
                *sl = d.mul_add(d, *sl);
            }
        }
        p += SUM64_BLOCK;
    }
    let mut w = [0.0f64; SUM64_LANES];
    for (l, wl) in w.iter_mut().enumerate() {
        *wl = (s[0][l] + s[1][l]) + (s[2][l] + s[3][l]);
    }
    let mut r = reduce4(&w);
    while p < k {
        let d = x[p] as f64 - mean;
        r = d.mul_add(d, r);
        p += 1;
    }
    r
}

/// The pinned softmax row-max chain, dispatched to the active backend.
/// Always bitwise equal to [`row_max_scalar`].
#[inline]
pub fn row_max(y: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::row_max(y) };
    }
    row_max_scalar(y)
}

/// The pinned softmax row-sum chain, dispatched to the active backend.
/// Always bitwise equal to [`row_sum_scalar`].
#[inline]
pub fn row_sum(y: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::row_sum(y) };
    }
    row_sum_scalar(y)
}

/// The pinned f64 sum chain, dispatched. Always bitwise equal to
/// [`row_sum_f64_scalar`].
#[inline]
pub fn row_sum_f64(x: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::row_sum_f64(x) };
    }
    row_sum_f64_scalar(x)
}

/// The pinned f64 squared-deviation chain, dispatched. Always bitwise equal
/// to [`row_sumsq_dev_scalar`].
#[inline]
pub fn row_sumsq_dev(x: &[f32], mean: f64) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        return unsafe { avx2::row_sumsq_dev(x, mean) };
    }
    row_sumsq_dev_scalar(x, mean)
}

/// Vectorized in-place `y[i] /= d` (lanewise IEEE divide — bit-transparent
/// at any width). Returns false when scalar.
#[inline]
pub fn div_row_simd(y: &mut [f32], d: f32) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::div_row(y, d) };
        return true;
    }
    let _ = (y, d);
    false
}

/// Vectorized layernorm finish: `x[i] = ((x[i] − mean)·inv as f32)·g[i] +
/// b[i]` with the subtract/multiply in f64 — lanewise identical to the
/// scalar expression (cvtpd→ps is the `as f32` rounding). Returns false
/// when scalar.
#[inline]
pub fn norm_finish_simd(x: &mut [f32], mean: f64, inv: f64, g: &[f32], b: &[f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::norm_finish(x, mean, inv, g, b) };
        return true;
    }
    let _ = (x, mean, inv, g, b);
    false
}

/// Vectorized elementwise `out[i] = round_to_mantissa(x[i], mu)` (lanewise
/// RNE bias-add-truncate with NaN/±inf passthrough — bit-transparent).
/// Returns false when scalar or when μ is outside the vector kernel's
/// 1..=22 shift range (μ = 0 or 23: the caller's scalar body handles it).
#[inline]
pub fn round_row_simd(x: &[f32], mu: u32, out: &mut [f32]) -> bool {
    debug_assert_eq!(x.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if (1..=22).contains(&mu) && simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::round_row(x, mu, out) };
        return true;
    }
    let _ = (x, mu, out);
    false
}

// --------------------------------------------------------------------------
// Vectorized per-row kernels (dispatchers return false ⇒ caller runs its
// scalar body, which is the defining chain)
// --------------------------------------------------------------------------

/// Fused causal score row with 8 interleaved independent PS(μ) chains per
/// vector. Returns false when no vector backend is active (the caller's
/// scalar body is the reference chain and produces identical bits).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn score_row_ps_simd(
    q: &[f32],
    keys: &[f32],
    stride: usize,
    n: usize,
    mu: u32,
    scale: f32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::score_row_ps(q, keys, stride, n, mu, scale, out) };
        return true;
    }
    let _ = (q, keys, stride, n, mu, scale, out);
    false
}

/// Vectorized `out[j] += x_p · w[p][j]` matvec body (mul+add, elementwise —
/// bit-transparent at any lane width). Returns false when scalar.
#[inline]
pub fn matvec_f32_simd(x_row: &[f32], wdata: &[f32], n: usize, bias: &[f32], out: &mut [f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_f32(x_row, wdata, n, bias, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, out);
    false
}

/// bf16 twin of [`matvec_f32_simd`].
#[inline]
pub fn matvec_bf16_simd(x_row: &[f32], wdata: &[u16], n: usize, bias: &[f32], out: &mut [f32]) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_bf16(x_row, wdata, n, bias, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, out);
    false
}

/// Vectorized PS(μ) matvec body: per output column the per-step
/// `round(fma(..))` chain over p, 8 independent columns per vector.
/// Returns false when scalar.
#[inline]
pub fn matvec_ps_simd(
    x_row: &[f32],
    wdata: &[f32],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_ps(x_row, wdata, n, bias, mu, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, mu, out);
    false
}

/// bf16 twin of [`matvec_ps_simd`].
#[inline]
pub fn matvec_ps_bf16_simd(
    x_row: &[f32],
    wdata: &[u16],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec_ps_bf16(x_row, wdata, n, bias, mu, out) };
        return true;
    }
    let _ = (x_row, wdata, n, bias, mu, out);
    false
}

/// Register-blocked 4-row FP32 micro-kernel: four x rows against one
/// streamed weight panel, 8 output columns per vector, each output's
/// p-ascending mul+add order identical to the single-row matvec (so the
/// blocked matmul stays bitwise equal to per-row kernels). Returns false
/// when scalar — the caller then runs per-row matvecs.
#[inline]
pub fn matvec4_f32_simd(
    xs: [&[f32]; 4],
    wdata: &[f32],
    n: usize,
    bias: &[f32],
    outs: [&mut [f32]; 4],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec4_f32(xs, wdata, n, bias, outs) };
        return true;
    }
    let _ = (xs, wdata, n, bias, outs);
    false
}

/// bf16 twin of [`matvec4_f32_simd`].
#[inline]
pub fn matvec4_bf16_simd(
    xs: [&[f32]; 4],
    wdata: &[u16],
    n: usize,
    bias: &[f32],
    outs: [&mut [f32]; 4],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    if simd_enabled() {
        // SAFETY: MODE_SIMD is only set after avx2+fma detection.
        unsafe { avx2::matvec4_bf16(xs, wdata, n, bias, outs) };
        return true;
    }
    let _ = (xs, wdata, n, bias, outs);
    false
}

// --------------------------------------------------------------------------
// AVX2 + FMA backend
// --------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{DOT_BLOCK, DOT_LANES, SUM64_BLOCK, SUM64_LANES};
    use crate::softfloat::dot::dot_ps;
    use std::arch::x86_64::*;

    /// Key-tile transposition chunk of the score-row kernel (in f32s per
    /// column): sized so the 8-column scratch tile (8·64·4 B = 2 KiB) stays
    /// resident in L1 while the chains advance through it.
    const PCHUNK: usize = 64;

    /// 8-lane horizontal sum implementing exactly the [`super::reduce8`]
    /// tree: `t_m = w[m] + w[m+4]`, then `(t0 + t2) + (t1 + t3)`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum8(w: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(w);
        let hi = _mm256_extractf128_ps::<1>(w);
        let t = _mm_add_ps(lo, hi); // (t0, t1, t2, t3)
        let pair = _mm_add_ps(t, _mm_movehl_ps(t, t)); // (t0+t2, t1+t3, ..)
        let one = _mm_add_ss(pair, _mm_shuffle_ps::<0b01>(pair, pair));
        _mm_cvtss_f32(one)
    }

    /// Widen 8 bf16 values (stored as u16) to f32 lanes: zero-extend to
    /// 32 bits and shift into the high half — the vector form of
    /// [`crate::linalg::tensor::bf16_to_f32`].
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn widen_bf16(p: *const u16) -> __m256 {
        let h = _mm_loadu_si128(p as *const __m128i);
        _mm256_castsi256_ps(_mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h)))
    }

    /// Vector round-to-μ-mantissa-bits (RNE), lanewise identical to
    /// [`crate::softfloat::round::round_to_mantissa`]: the same integer
    /// bias-add-truncate on finite lanes, with NaN/±inf lanes passed
    /// through unchanged via the finite blend (without it, the bias add
    /// could carry a NaN payload into the sign bit).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn round8(x: __m256, shift: i32, cnt: __m128i, half: __m256i) -> __m256 {
        debug_assert!((1..=22).contains(&shift));
        let u = _mm256_castps_si256(x);
        let lsb = _mm256_and_si256(_mm256_srl_epi32(u, cnt), _mm256_set1_epi32(1));
        let bias = _mm256_add_epi32(half, lsb);
        let r = _mm256_sll_epi32(_mm256_srl_epi32(_mm256_add_epi32(u, bias), cnt), cnt);
        let rounded = _mm256_castsi256_ps(r);
        let abs = _mm256_and_ps(x, _mm256_castsi256_ps(_mm256_set1_epi32(0x7FFF_FFFF)));
        let finite = _mm256_cmp_ps::<_CMP_LT_OQ>(abs, _mm256_set1_ps(f32::INFINITY));
        _mm256_blendv_ps(x, rounded, finite)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_block(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(p)), _mm256_loadu_ps(bp.add(p)), s0);
            s1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + DOT_LANES)),
                s1,
            );
            s2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 2 * DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + 2 * DOT_LANES)),
                s2,
            );
            s3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 3 * DOT_LANES)),
                _mm256_loadu_ps(bp.add(p + 3 * DOT_LANES)),
                s3,
            );
            p += DOT_BLOCK;
        }
        let w = _mm256_add_ps(_mm256_add_ps(s0, s1), _mm256_add_ps(s2, s3));
        let mut r = hsum8(w);
        while p < k {
            r = a[p].mul_add(b[p], r);
            p += 1;
        }
        r
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot_block_bf16(a: &[f32], b: &[u16]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_fmadd_ps(_mm256_loadu_ps(ap.add(p)), widen_bf16(bp.add(p)), s0);
            s1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + DOT_LANES)),
                widen_bf16(bp.add(p + DOT_LANES)),
                s1,
            );
            s2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 2 * DOT_LANES)),
                widen_bf16(bp.add(p + 2 * DOT_LANES)),
                s2,
            );
            s3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(ap.add(p + 3 * DOT_LANES)),
                widen_bf16(bp.add(p + 3 * DOT_LANES)),
                s3,
            );
            p += DOT_BLOCK;
        }
        let w = _mm256_add_ps(_mm256_add_ps(s0, s1), _mm256_add_ps(s2, s3));
        let mut r = hsum8(w);
        while p < k {
            r = a[p].mul_add(super::bf16_to_f32(b[p]), r);
            p += 1;
        }
        r
    }

    /// 8-lane horizontal max implementing exactly the scalar replay's tree
    /// in [`super::row_max_scalar`]: `t_m = vmax(w[m], w[m+4])`, then
    /// `vmax(vmax(t0, t2), vmax(t1, t3))`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hmax8(w: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(w);
        let hi = _mm256_extractf128_ps::<1>(w);
        let t = _mm_max_ps(lo, hi); // (t0, t1, t2, t3)
        let pair = _mm_max_ps(t, _mm_movehl_ps(t, t)); // (vmax(t0,t2), vmax(t1,t3), ..)
        let one = _mm_max_ss(pair, _mm_shuffle_ps::<0b01>(pair, pair));
        _mm_cvtss_f32(one)
    }

    /// 4-lane f64 horizontal sum implementing exactly the [`super::reduce4`]
    /// tree: `(w[0] + w[2]) + (w[1] + w[3])`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum4(w: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(w);
        let hi = _mm256_extractf128_pd::<1>(w);
        let t = _mm_add_pd(lo, hi); // (w0+w2, w1+w3)
        _mm_cvtsd_f64(_mm_add_sd(t, _mm_unpackhi_pd(t, t)))
    }

    /// The pinned softmax row-max chain (see [`super::row_max_scalar`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_max(y: &[f32]) -> f32 {
        let k = y.len();
        let yp = y.as_ptr();
        let mut s0 = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut s1 = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut s2 = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut s3 = _mm256_set1_ps(f32::NEG_INFINITY);
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_max_ps(s0, _mm256_loadu_ps(yp.add(p)));
            s1 = _mm256_max_ps(s1, _mm256_loadu_ps(yp.add(p + DOT_LANES)));
            s2 = _mm256_max_ps(s2, _mm256_loadu_ps(yp.add(p + 2 * DOT_LANES)));
            s3 = _mm256_max_ps(s3, _mm256_loadu_ps(yp.add(p + 3 * DOT_LANES)));
            p += DOT_BLOCK;
        }
        let w = _mm256_max_ps(_mm256_max_ps(s0, s1), _mm256_max_ps(s2, s3));
        let mut r = hmax8(w);
        while p < k {
            r = super::vmax(r, y[p]);
            p += 1;
        }
        r
    }

    /// The pinned softmax row-sum chain (see [`super::row_sum_scalar`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_sum(y: &[f32]) -> f32 {
        let k = y.len();
        let yp = y.as_ptr();
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            s0 = _mm256_add_ps(s0, _mm256_loadu_ps(yp.add(p)));
            s1 = _mm256_add_ps(s1, _mm256_loadu_ps(yp.add(p + DOT_LANES)));
            s2 = _mm256_add_ps(s2, _mm256_loadu_ps(yp.add(p + 2 * DOT_LANES)));
            s3 = _mm256_add_ps(s3, _mm256_loadu_ps(yp.add(p + 3 * DOT_LANES)));
            p += DOT_BLOCK;
        }
        let w = _mm256_add_ps(_mm256_add_ps(s0, s1), _mm256_add_ps(s2, s3));
        let mut r = hsum8(w);
        while p < k {
            r += y[p];
            p += 1;
        }
        r
    }

    /// The pinned f64 sum chain (see [`super::row_sum_f64_scalar`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_sum_f64(x: &[f32]) -> f64 {
        let k = x.len();
        let xp = x.as_ptr();
        let mut s0 = _mm256_setzero_pd();
        let mut s1 = _mm256_setzero_pd();
        let mut s2 = _mm256_setzero_pd();
        let mut s3 = _mm256_setzero_pd();
        let mut p = 0;
        while p + SUM64_BLOCK <= k {
            s0 = _mm256_add_pd(s0, _mm256_cvtps_pd(_mm_loadu_ps(xp.add(p))));
            s1 = _mm256_add_pd(s1, _mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + SUM64_LANES))));
            s2 = _mm256_add_pd(s2, _mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + 2 * SUM64_LANES))));
            s3 = _mm256_add_pd(s3, _mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + 3 * SUM64_LANES))));
            p += SUM64_BLOCK;
        }
        let w = _mm256_add_pd(_mm256_add_pd(s0, s1), _mm256_add_pd(s2, s3));
        let mut r = hsum4(w);
        while p < k {
            r += x[p] as f64;
            p += 1;
        }
        r
    }

    /// The pinned f64 squared-deviation chain (see
    /// [`super::row_sumsq_dev_scalar`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_sumsq_dev(x: &[f32], mean: f64) -> f64 {
        let k = x.len();
        let xp = x.as_ptr();
        let m = _mm256_set1_pd(mean);
        let mut s0 = _mm256_setzero_pd();
        let mut s1 = _mm256_setzero_pd();
        let mut s2 = _mm256_setzero_pd();
        let mut s3 = _mm256_setzero_pd();
        let mut p = 0;
        while p + SUM64_BLOCK <= k {
            let d0 = _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(xp.add(p))), m);
            let d1 = _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + SUM64_LANES))), m);
            let d2 = _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + 2 * SUM64_LANES))), m);
            let d3 = _mm256_sub_pd(_mm256_cvtps_pd(_mm_loadu_ps(xp.add(p + 3 * SUM64_LANES))), m);
            s0 = _mm256_fmadd_pd(d0, d0, s0);
            s1 = _mm256_fmadd_pd(d1, d1, s1);
            s2 = _mm256_fmadd_pd(d2, d2, s2);
            s3 = _mm256_fmadd_pd(d3, d3, s3);
            p += SUM64_BLOCK;
        }
        let w = _mm256_add_pd(_mm256_add_pd(s0, s1), _mm256_add_pd(s2, s3));
        let mut r = hsum4(w);
        while p < k {
            let d = x[p] as f64 - mean;
            r = d.mul_add(d, r);
            p += 1;
        }
        r
    }

    /// Lanewise in-place divide (bit-transparent).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn div_row(y: &mut [f32], d: f32) {
        let n = y.len();
        let yp = y.as_mut_ptr();
        let dv = _mm256_set1_ps(d);
        let mut j = 0;
        while j + 8 <= n {
            _mm256_storeu_ps(yp.add(j), _mm256_div_ps(_mm256_loadu_ps(yp.add(j)), dv));
            j += 8;
        }
        while j < n {
            *yp.add(j) /= d;
            j += 1;
        }
    }

    /// Lanewise layernorm finish (bit-transparent): the f64 sub/mul and the
    /// cvtpd→ps narrowing round exactly as the scalar
    /// `((x as f64 − mean) · inv) as f32`, then f32 mul+add with g, b.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn norm_finish(x: &mut [f32], mean: f64, inv: f64, g: &[f32], b: &[f32]) {
        let n = x.len();
        let xp = x.as_mut_ptr();
        let mv = _mm256_set1_pd(mean);
        let iv = _mm256_set1_pd(inv);
        let mut j = 0;
        while j + 4 <= n {
            let xv = _mm256_cvtps_pd(_mm_loadu_ps(xp.add(j)));
            let t = _mm256_cvtpd_ps(_mm256_mul_pd(_mm256_sub_pd(xv, mv), iv));
            let r = _mm_add_ps(
                _mm_mul_ps(t, _mm_loadu_ps(g.as_ptr().add(j))),
                _mm_loadu_ps(b.as_ptr().add(j)),
            );
            _mm_storeu_ps(xp.add(j), r);
            j += 4;
        }
        while j < n {
            *xp.add(j) = (((*xp.add(j) as f64 - mean) * inv) as f32) * g[j] + b[j];
            j += 1;
        }
    }

    /// Lanewise elementwise round-to-μ-mantissa-bits (bit-transparent; μ in
    /// 1..=22 — the dispatcher gates the rest to the scalar body).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn round_row(x: &[f32], mu: u32, out: &mut [f32]) {
        let n = x.len();
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32((1i32 << (shift - 1)) - 1);
        let mut j = 0;
        while j + 8 <= n {
            let v = _mm256_loadu_ps(x.as_ptr().add(j));
            _mm256_storeu_ps(out.as_mut_ptr().add(j), round8(v, shift, cnt, half));
            j += 8;
        }
        while j < n {
            out[j] = crate::softfloat::round::round_to_mantissa(x[j], mu);
            j += 1;
        }
    }

    /// 8 interleaved independent PS(μ) score chains. The key columns are
    /// strided in the KV buffer, so each 8-column group is first
    /// transposed into a stack tile (PCHUNK × 8) and the chains then read
    /// it with contiguous vector loads — the cache-blocking that makes
    /// the gather-free inner loop possible on both AVX2 and NEON layouts.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn score_row_ps(
        q: &[f32],
        keys: &[f32],
        stride: usize,
        n: usize,
        mu: u32,
        scale: f32,
        out: &mut [f32],
    ) {
        let hd = q.len();
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let scale_v = _mm256_set1_ps(scale);
        let mut tbuf = [0.0f32; PCHUNK * 8];
        let mut j = 0;
        while j + 8 <= n {
            let mut acc = _mm256_setzero_ps();
            let mut p0 = 0;
            while p0 < hd {
                let pc = (hd - p0).min(PCHUNK);
                for l in 0..8 {
                    let col = &keys[(j + l) * stride + p0..(j + l) * stride + p0 + pc];
                    for (pp, &kv) in col.iter().enumerate() {
                        tbuf[pp * 8 + l] = kv;
                    }
                }
                if mu == 23 {
                    for (pp, &qp) in q[p0..p0 + pc].iter().enumerate() {
                        let kv = _mm256_loadu_ps(tbuf.as_ptr().add(pp * 8));
                        acc = _mm256_fmadd_ps(_mm256_set1_ps(qp), kv, acc);
                    }
                } else {
                    for (pp, &qp) in q[p0..p0 + pc].iter().enumerate() {
                        let kv = _mm256_loadu_ps(tbuf.as_ptr().add(pp * 8));
                        acc = round8(_mm256_fmadd_ps(_mm256_set1_ps(qp), kv, acc), shift, cnt, half);
                    }
                }
                p0 += pc;
            }
            _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_mul_ps(acc, scale_v));
            j += 8;
        }
        while j < n {
            out[j] = dot_ps(q, &keys[j * stride..j * stride + hd], mu) * scale;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_f32(x_row: &[f32], wdata: &[f32], n: usize, bias: &[f32], out: &mut [f32]) {
        init_out(bias, out);
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = _mm256_loadu_ps(wrow.add(j));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(o, _mm256_mul_ps(xb, w)));
                j += 8;
            }
            while j < n {
                *op.add(j) += xv * *wrow.add(j);
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_bf16(x_row: &[f32], wdata: &[u16], n: usize, bias: &[f32], out: &mut [f32]) {
        init_out(bias, out);
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = widen_bf16(wrow.add(j));
                _mm256_storeu_ps(op.add(j), _mm256_add_ps(o, _mm256_mul_ps(xb, w)));
                j += 8;
            }
            while j < n {
                *op.add(j) += xv * super::bf16_to_f32(*wrow.add(j));
                j += 1;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_ps(
        x_row: &[f32],
        wdata: &[f32],
        n: usize,
        bias: &[f32],
        mu: u32,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = _mm256_loadu_ps(wrow.add(j));
                let f = _mm256_fmadd_ps(xb, w, o);
                let r = if mu == 23 { f } else { round8(f, shift, cnt, half) };
                _mm256_storeu_ps(op.add(j), r);
                j += 8;
            }
            while j < n {
                let f = xv.mul_add(*wrow.add(j), *op.add(j));
                *op.add(j) = crate::softfloat::round::round_to_mantissa(f, mu);
                j += 1;
            }
        }
        if !bias.is_empty() {
            for (o, &b) in out.iter_mut().zip(bias) {
                *o += b;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec_ps_bf16(
        x_row: &[f32],
        wdata: &[u16],
        n: usize,
        bias: &[f32],
        mu: u32,
        out: &mut [f32],
    ) {
        out.fill(0.0);
        let shift = (23 - mu) as i32;
        let cnt = _mm_cvtsi32_si128(shift);
        let half = _mm256_set1_epi32(if mu == 23 { 0 } else { (1i32 << (shift - 1)) - 1 });
        let op = out.as_mut_ptr();
        for (p, &xv) in x_row.iter().enumerate() {
            let wrow = wdata[p * n..(p + 1) * n].as_ptr();
            let xb = _mm256_set1_ps(xv);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(op.add(j));
                let w = widen_bf16(wrow.add(j));
                let f = _mm256_fmadd_ps(xb, w, o);
                let r = if mu == 23 { f } else { round8(f, shift, cnt, half) };
                _mm256_storeu_ps(op.add(j), r);
                j += 8;
            }
            while j < n {
                let f = xv.mul_add(super::bf16_to_f32(*wrow.add(j)), *op.add(j));
                *op.add(j) = crate::softfloat::round::round_to_mantissa(f, mu);
                j += 1;
            }
        }
        if !bias.is_empty() {
            for (o, &b) in out.iter_mut().zip(bias) {
                *o += b;
            }
        }
    }

    #[inline]
    unsafe fn init_out(bias: &[f32], out: &mut [f32]) {
        if bias.is_empty() {
            out.fill(0.0);
        } else {
            out.copy_from_slice(bias);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec4_f32(
        xs: [&[f32]; 4],
        wdata: &[f32],
        n: usize,
        bias: &[f32],
        mut outs: [&mut [f32]; 4],
    ) {
        let k = xs[0].len();
        for o in outs.iter_mut() {
            init_out(bias, o);
        }
        let ops = [
            outs[0].as_mut_ptr(),
            outs[1].as_mut_ptr(),
            outs[2].as_mut_ptr(),
            outs[3].as_mut_ptr(),
        ];
        let mut j = 0;
        // 8-column panel held in 4 register accumulators across all of p;
        // W is streamed once per 4 output rows (the register blocking).
        while j + 8 <= n {
            let mut a0 = _mm256_loadu_ps(ops[0].add(j));
            let mut a1 = _mm256_loadu_ps(ops[1].add(j));
            let mut a2 = _mm256_loadu_ps(ops[2].add(j));
            let mut a3 = _mm256_loadu_ps(ops[3].add(j));
            for p in 0..k {
                let w = _mm256_loadu_ps(wdata.as_ptr().add(p * n + j));
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_set1_ps(xs[0][p]), w));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_set1_ps(xs[1][p]), w));
                a2 = _mm256_add_ps(a2, _mm256_mul_ps(_mm256_set1_ps(xs[2][p]), w));
                a3 = _mm256_add_ps(a3, _mm256_mul_ps(_mm256_set1_ps(xs[3][p]), w));
            }
            _mm256_storeu_ps(ops[0].add(j), a0);
            _mm256_storeu_ps(ops[1].add(j), a1);
            _mm256_storeu_ps(ops[2].add(j), a2);
            _mm256_storeu_ps(ops[3].add(j), a3);
            j += 8;
        }
        while j < n {
            for (u, &op) in ops.iter().enumerate() {
                let mut o = *op.add(j);
                for p in 0..k {
                    o += xs[u][p] * wdata[p * n + j];
                }
                *op.add(j) = o;
            }
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matvec4_bf16(
        xs: [&[f32]; 4],
        wdata: &[u16],
        n: usize,
        bias: &[f32],
        mut outs: [&mut [f32]; 4],
    ) {
        let k = xs[0].len();
        for o in outs.iter_mut() {
            init_out(bias, o);
        }
        let ops = [
            outs[0].as_mut_ptr(),
            outs[1].as_mut_ptr(),
            outs[2].as_mut_ptr(),
            outs[3].as_mut_ptr(),
        ];
        let mut j = 0;
        while j + 8 <= n {
            let mut a0 = _mm256_loadu_ps(ops[0].add(j));
            let mut a1 = _mm256_loadu_ps(ops[1].add(j));
            let mut a2 = _mm256_loadu_ps(ops[2].add(j));
            let mut a3 = _mm256_loadu_ps(ops[3].add(j));
            for p in 0..k {
                let w = widen_bf16(wdata.as_ptr().add(p * n + j));
                a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_set1_ps(xs[0][p]), w));
                a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_set1_ps(xs[1][p]), w));
                a2 = _mm256_add_ps(a2, _mm256_mul_ps(_mm256_set1_ps(xs[2][p]), w));
                a3 = _mm256_add_ps(a3, _mm256_mul_ps(_mm256_set1_ps(xs[3][p]), w));
            }
            _mm256_storeu_ps(ops[0].add(j), a0);
            _mm256_storeu_ps(ops[1].add(j), a1);
            _mm256_storeu_ps(ops[2].add(j), a2);
            _mm256_storeu_ps(ops[3].add(j), a3);
            j += 8;
        }
        while j < n {
            for (u, &op) in ops.iter().enumerate() {
                let mut o = *op.add(j);
                for p in 0..k {
                    o += xs[u][p] * super::bf16_to_f32(wdata[p * n + j]);
                }
                *op.add(j) = o;
            }
            j += 1;
        }
    }
}

// --------------------------------------------------------------------------
// NEON backend (minimal: the pinned reference-dot chain; every other kernel
// falls back to the scalar replay, which is bitwise identical)
// --------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::DOT_BLOCK;
    use std::arch::aarch64::*;

    /// The pinned [`super::dot_block`] chain on NEON: the 8-lane vector
    /// accumulators are register pairs (low/high float32x4), reduced with
    /// the same fixed tree — `t_m = w[m] + w[m+4]` is `vaddq(w_lo, w_hi)`,
    /// then `(t0 + t2) + (t1 + t3)` via the 64-bit halves.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_block(a: &[f32], b: &[f32]) -> f32 {
        let k = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut lo = [vdupq_n_f32(0.0); 4];
        let mut hi = [vdupq_n_f32(0.0); 4];
        let mut p = 0;
        while p + DOT_BLOCK <= k {
            for u in 0..4 {
                let base = p + u * 8;
                lo[u] = vfmaq_f32(lo[u], vld1q_f32(ap.add(base)), vld1q_f32(bp.add(base)));
                hi[u] = vfmaq_f32(hi[u], vld1q_f32(ap.add(base + 4)), vld1q_f32(bp.add(base + 4)));
            }
            p += DOT_BLOCK;
        }
        let w_lo = vaddq_f32(vaddq_f32(lo[0], lo[1]), vaddq_f32(lo[2], lo[3]));
        let w_hi = vaddq_f32(vaddq_f32(hi[0], hi[1]), vaddq_f32(hi[2], hi[3]));
        let t = vaddq_f32(w_lo, w_hi); // (t0, t1, t2, t3)
        let pair = vadd_f32(vget_low_f32(t), vget_high_f32(t)); // (t0+t2, t1+t3)
        let mut r = vget_lane_f32::<0>(pair) + vget_lane_f32::<1>(pair);
        while p < k {
            r = a[p].mul_add(b[p], r);
            p += 1;
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::Mutex;

    /// Serializes tests that toggle the process-global dispatch mode.
    /// (Tests that don't take this lock are mode-agnostic: both modes
    /// produce identical bits.)
    pub(crate) static MODE_LOCK: Mutex<()> = Mutex::new(());

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect()
    }

    #[test]
    fn backend_label_consistent_with_mode() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        assert!(!set_simd_enabled(false));
        assert_eq!(simd_backend(), "scalar");
        let took = set_simd_enabled(true);
        if took {
            assert_ne!(simd_backend(), "scalar");
        } else {
            assert_eq!(simd_backend(), "scalar");
        }
        set_simd_enabled(had);
    }

    #[test]
    fn dot_block_simd_matches_scalar_replay_all_tails() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0x51AD);
        // Every tail class around the 32-wide block and 8-wide lane edges.
        for k in [0usize, 1, 7, 8, 9, 31, 32, 33, 63, 64, 65, 96, 257] {
            let a = randvec(&mut rng, k);
            let b = randvec(&mut rng, k);
            let bq: Vec<u16> = b.iter().map(|&x| crate::linalg::tensor::f32_to_bf16(x)).collect();
            set_simd_enabled(true);
            let fast = dot_block(&a, &b);
            let fast_bf = dot_block_bf16(&a, &bq);
            set_simd_enabled(false);
            let slow = dot_block(&a, &b);
            let slow_bf = dot_block_bf16(&a, &bq);
            assert_eq!(fast.to_bits(), slow.to_bits(), "k={k}");
            assert_eq!(slow.to_bits(), dot_block_scalar(&a, &b).to_bits(), "k={k}");
            assert_eq!(fast_bf.to_bits(), slow_bf.to_bits(), "bf16 k={k}");
            assert_eq!(
                slow_bf.to_bits(),
                dot_block_bf16_scalar(&a, &bq).to_bits(),
                "bf16 k={k}"
            );
        }
        set_simd_enabled(had);
    }

    #[test]
    fn dot_block_close_to_f64_reference() {
        let mut rng = Rng::new(0xACC);
        for _ in 0..50 {
            let k = rng.range(1, 300);
            let a = randvec(&mut rng, k);
            let b = randvec(&mut rng, k);
            let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot_block(&a, &b) as f64;
            let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            assert!((got - exact).abs() <= 1e-4 * mag.max(1.0), "k={k}");
        }
    }

    #[test]
    fn score_row_simd_matches_scalar_chain_including_specials() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0x5C0E);
        for _ in 0..30 {
            let hd = rng.range(1, 80); // crosses the PCHUNK=64 boundary via accumulation
            let n = rng.range(1, 21); // crosses the 8-wide column block boundary
            let stride = hd + rng.range(0, 5);
            let q = randvec(&mut rng, hd);
            let mut keys = randvec(&mut rng, n * stride);
            // Poison a lane with an overflow-prone magnitude so the rounded
            // chain can hit ±inf and exercise the passthrough blend.
            if n > 2 && hd > 1 {
                keys[stride + 1] = 3.0e38;
            }
            for mu in [1u32, 4, 11, 23] {
                let scale = 1.0 / (hd as f32).sqrt();
                let mut fast = vec![0.0f32; n];
                let mut slow = vec![0.0f32; n];
                if set_simd_enabled(true) {
                    assert!(score_row_ps_simd(&q, &keys, stride, n, mu, scale, &mut fast));
                } else {
                    // Host without a backend: nothing to cross-check.
                    set_simd_enabled(had);
                    return;
                }
                set_simd_enabled(false);
                assert!(!score_row_ps_simd(&q, &keys, stride, n, mu, scale, &mut slow));
                crate::softfloat::dot::score_row_ps(&q, &keys, stride, n, mu, scale, &mut slow);
                for j in 0..n {
                    assert_eq!(fast[j].to_bits(), slow[j].to_bits(), "j={j} mu={mu} hd={hd}");
                }
            }
        }
        set_simd_enabled(had);
    }

    #[test]
    fn row_reduction_chains_match_scalar_replays_all_tails() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0x50F7);
        // Tail classes around the 32/16-wide block and 8/4-wide lane edges.
        for k in [0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 257] {
            let y = randvec(&mut rng, k);
            set_simd_enabled(true);
            let fmax = row_max(&y);
            let fsum = row_sum(&y);
            let fs64 = row_sum_f64(&y);
            let mean = if k == 0 { 0.0 } else { fs64 / k as f64 };
            let fdev = row_sumsq_dev(&y, mean);
            set_simd_enabled(false);
            assert_eq!(row_max(&y).to_bits(), fmax.to_bits(), "max k={k}");
            assert_eq!(row_sum(&y).to_bits(), fsum.to_bits(), "sum k={k}");
            assert_eq!(row_sum_f64(&y).to_bits(), fs64.to_bits(), "sum64 k={k}");
            assert_eq!(row_sumsq_dev(&y, mean).to_bits(), fdev.to_bits(), "dev k={k}");
            assert_eq!(row_max_scalar(&y).to_bits(), fmax.to_bits(), "max replay k={k}");
            assert_eq!(row_sum_scalar(&y).to_bits(), fsum.to_bits(), "sum replay k={k}");
            assert_eq!(
                row_sum_f64_scalar(&y).to_bits(),
                fs64.to_bits(),
                "sum64 replay k={k}"
            );
            assert_eq!(
                row_sumsq_dev_scalar(&y, mean).to_bits(),
                fdev.to_bits(),
                "dev replay k={k}"
            );
        }
        set_simd_enabled(had);
    }

    #[test]
    fn elementwise_row_kernels_are_bit_transparent() {
        let _g = MODE_LOCK.lock().unwrap();
        let had = simd_enabled();
        let mut rng = Rng::new(0xE1E);
        for k in [0usize, 1, 3, 4, 5, 7, 8, 9, 31, 32, 33, 100] {
            let base = randvec(&mut rng, k);
            let g = randvec(&mut rng, k);
            let b = randvec(&mut rng, k);
            let d = 0.37 + rng.f32();
            let mean = 0.123f64;
            let inv = 2.5f64;

            let mut fast = base.clone();
            let mut slow = base.clone();
            set_simd_enabled(true);
            if !div_row_simd(&mut fast, d) {
                for v in fast.iter_mut() {
                    *v /= d;
                }
            }
            set_simd_enabled(false);
            assert!(!div_row_simd(&mut slow, d));
            for v in slow.iter_mut() {
                *v /= d;
            }
            for j in 0..k {
                assert_eq!(fast[j].to_bits(), slow[j].to_bits(), "div j={j} k={k}");
            }

            let mut fast = base.clone();
            let mut slow = base.clone();
            set_simd_enabled(true);
            if !norm_finish_simd(&mut fast, mean, inv, &g, &b) {
                for j in 0..k {
                    fast[j] = (((fast[j] as f64 - mean) * inv) as f32) * g[j] + b[j];
                }
            }
            set_simd_enabled(false);
            assert!(!norm_finish_simd(&mut slow, mean, inv, &g, &b));
            for j in 0..k {
                slow[j] = (((slow[j] as f64 - mean) * inv) as f32) * g[j] + b[j];
            }
            for j in 0..k {
                assert_eq!(fast[j].to_bits(), slow[j].to_bits(), "norm j={j} k={k}");
            }

            // Round with specials poked in so the passthrough blend runs.
            let mut src = base.clone();
            if k > 2 {
                src[1] = f32::INFINITY;
                src[2] = f32::NAN;
            }
            let scalar_round = |s: &[f32], mu: u32, o: &mut [f32]| {
                for (oj, &v) in o.iter_mut().zip(s) {
                    *oj = crate::softfloat::round::round_to_mantissa(v, mu);
                }
            };
            for mu in [1u32, 4, 11, 22, 23] {
                let mut fast = vec![0.0f32; k];
                let mut slow = vec![0.0f32; k];
                set_simd_enabled(true);
                if !round_row_simd(&src, mu, &mut fast) {
                    scalar_round(&src, mu, &mut fast);
                }
                set_simd_enabled(false);
                assert!(!round_row_simd(&src, mu, &mut slow));
                scalar_round(&src, mu, &mut slow);
                for j in 0..k {
                    assert_eq!(
                        fast[j].to_bits(),
                        slow[j].to_bits(),
                        "round j={j} k={k} mu={mu}"
                    );
                }
            }
        }
        set_simd_enabled(had);
    }

    #[test]
    fn row_sum_scalar_replay_is_blocked_not_sequential() {
        // Pin the chain shape: a 32-element block reduces as lanewise
        // accumulator pairs then the fixed 8-lane tree, not left-to-right.
        let mut y = vec![0.0f32; 32];
        y[0] = 1.0e8;
        y[1] = 1.0;
        y[8] = -1.0e8;
        // Chain: w[0] = (1e8 + (-1e8)) + 0 = 0, w[1] = 1 → tree sums to 1.
        assert_eq!(row_sum_scalar(&y), 1.0);
        // A sequential left-to-right sum would have absorbed the 1.0:
        let seq: f32 = y.iter().sum();
        assert_eq!(seq, 0.0);
    }

    #[test]
    fn row_max_replay_uses_avx_tie_semantics() {
        // vmax picks the second operand on ties — including −0.0 vs +0.0 —
        // and on NaN (so a NaN is *replaced* by the next element, unlike
        // f32::max which keeps the numeric operand).
        let y = [-0.0f32, 0.0, -1.0];
        assert_eq!(row_max_scalar(&y).to_bits(), 0.0f32.to_bits());
        let poisoned = [1.0f32, f32::NAN, 3.0];
        assert_eq!(row_max_scalar(&poisoned), 3.0);
    }

    #[test]
    fn scalar_replay_reduction_tree_shape() {
        // Pin the chain shape itself: a 32-element block must reduce as
        // lanewise accumulator pairs then the fixed 8-lane tree — i.e. the
        // scalar replay is NOT a sequential sum. Constructed so the two
        // orders differ in f32.
        let mut a = vec![0.0f32; 32];
        let b = vec![1.0f32; 32];
        a[0] = 1.0e8;
        a[1] = 1.0;
        a[8] = -1.0e8;
        let got = dot_block_scalar(&a, &b);
        // Chain: w[0] = (1e8 + (-1e8)) + 0 = 0, w[1] = 1 → tree sums to 1.
        assert_eq!(got, 1.0);
        // A sequential left-to-right sum would have absorbed the 1.0:
        let seq: f32 = a.iter().zip(&b).fold(0.0, |c, (&x, &y)| x.mul_add(y, c));
        assert_eq!(seq, 0.0);
    }
}
