//! Dense linear algebra over row-major f32 matrices, with mixed-precision
//! accumulation hooks.
//!
//! * [`tensor`] — the [`tensor::Matrix`] type (row-major, shape-checked).
//! * [`matmul`] — FP32 matmul, PS(μ)-accumulated matmul, and masked
//!   recomputation (the building block of LAMP attention).

pub mod matmul;
pub mod tensor;

pub use matmul::{matmul_f32, matmul_ps, recompute_masked};
pub use tensor::Matrix;
