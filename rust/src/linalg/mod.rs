//! Dense linear algebra over row-major f32 matrices, with mixed-precision
//! accumulation hooks and mixed-precision weight storage.
//!
//! * [`tensor`] — the [`tensor::Matrix`] activation type (row-major,
//!   shape-checked, always f32) and the [`tensor::WeightTensor`] parameter
//!   store (f32 / bf16 / PS(μ)-rounded storage; every stored value is an
//!   exact f32, so dequantization is error-free).
//! * [`matmul`] — FP32 matmul, PS(μ)-accumulated matmul, masked
//!   recomputation (the building block of LAMP attention), and the fused
//!   dequant-on-the-fly `*_wt` kernels that read [`WeightTensor`] storage
//!   directly (bf16 decode reads half the bytes).

pub mod matmul;
pub mod tensor;

pub use matmul::{matmul_f32, matmul_ps, recompute_masked};
pub use tensor::{Matrix, WeightFormat, WeightStore, WeightTensor};
