//! Dense linear algebra over row-major f32 matrices, with mixed-precision
//! accumulation hooks and mixed-precision weight storage.
//!
//! * [`tensor`] — the [`tensor::Matrix`] activation type (row-major,
//!   shape-checked, always f32) and the [`tensor::WeightTensor`] parameter
//!   store (f32 / bf16 / PS(μ)-rounded storage; every stored value is an
//!   exact f32, so dequantization is error-free).
//! * [`matmul`] — FP32 matmul, PS(μ)-accumulated matmul, masked
//!   recomputation (the building block of LAMP attention), and the fused
//!   dequant-on-the-fly `*_wt` kernels that read [`WeightTensor`] storage
//!   directly (bf16 decode reads half the bytes).
//! * [`simd`] — runtime-dispatched AVX2/NEON kernel bodies with bit-exact
//!   scalar replays of the same accumulation-chain shape (`LAMP_SIMD=0`
//!   forces the replay everywhere).

pub mod matmul;
pub mod simd;
pub mod tensor;

pub use matmul::{matmul_f32, matmul_ps, recompute_masked};
pub use simd::{set_simd_enabled, simd_backend, simd_enabled};
pub use tensor::{Matrix, WeightFormat, WeightStore, WeightTensor};
