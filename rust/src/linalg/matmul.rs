//! Matrix multiplication under FP32 and PS(μ) accumulation, plus masked
//! FP32 recomputation — the LAMP primitive: recompute only the inner
//! products flagged by the selection rule.

use super::tensor::Matrix;
use crate::error::{Error, Result};
use crate::softfloat::dot::{dot_f32, dot_ps};
use crate::softfloat::round::round_to_mantissa;

fn check(a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::shape(format!(
            "matmul: {:?} x {:?}",
            a.shape(),
            b.shape()
        )));
    }
    Ok(())
}

/// C = A·B with FP32 accumulation (sequential order, matching `matmul_ps`
/// at μ=23 bit-for-bit).
pub fn matmul_f32(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_f32(arow, bt.row(j)));
        }
    }
    Ok(c)
}

/// C = A·B with per-step PS(μ) rounding of the accumulator (paper §4.1).
pub fn matmul_ps(a: &Matrix, b: &Matrix, mu: u32) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_ps(arow, bt.row(j), mu));
        }
    }
    Ok(c)
}

/// Recompute in FP32 the entries of `c` flagged by `mask` (true = recompute)
/// and return the number of recomputed entries.
///
/// This is the mixed-precision accumulation step of LAMP: the matrix is
/// split into the low-precision block and the flagged block, each computed
/// with its own accumulation algorithm (paper §3, matrix-product property).
pub fn recompute_masked(
    c: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
    mask: &[bool],
) -> Result<usize> {
    check(a, b)?;
    if c.shape() != (a.rows(), b.cols()) || mask.len() != a.rows() * b.cols() {
        return Err(Error::shape("recompute_masked: output/mask shape".to_string()));
    }
    let bt = b.transpose();
    let mut n = 0;
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            if mask[i * b.cols() + j] {
                c.set(i, j, dot_f32(a.row(i), bt.row(j)));
                n += 1;
            }
        }
    }
    Ok(n)
}

/// One row of the fast-path matmul: `out = x_row·W + bias` with W
/// row-major [k, n], p–j loop order so the inner loop vectorizes across
/// output columns. Shared by the batched [`matmul_bias_into`] and the
/// KV-cache decode step, which runs the *same* FP32 op sequence on a
/// single row — that shared kernel is what makes incremental decode
/// bit-identical to the full forward pass (DESIGN.md §Bit-exactness).
#[inline]
pub fn matvec_bias_into(x_row: &[f32], w: &Matrix, bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    if bias.is_empty() {
        for o in out.iter_mut() {
            *o = 0.0;
        }
    } else {
        out.copy_from_slice(bias);
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = w.row(p);
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
}

/// One row of the PS(μ) matmul: `out[j] = dot_ps(x_row, W[:, j], μ)` with
/// the bias added once in FP32 afterwards. Each output column keeps its own
/// accumulator advanced with the paper's per-step `round(fma(..))` chain in
/// input order (p ascending) — **bit-identical to [`dot_ps`] on the
/// explicit column** — while the p-major loop walks the row-major weight
/// matrix cache-friendly, interleaving the independent chains exactly like
/// the fused attention score kernel. This is the whole-model-LAMP
/// counterpart of [`matvec_bias_into`]: shared by the batched PS matmul of
/// `model::mlp` and the KV-cache decode row, so incremental decode stays
/// bit-identical to the full pass under every plan.
pub fn matvec_ps_bias_into(
    x_row: &[f32],
    w: &Matrix,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = w.row(p);
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o = round_to_mantissa(xv.mul_add(wv, *o), mu);
        }
    }
    if !bias.is_empty() {
        for (o, &b) in out.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// FP32 recomputation of one output column of `x_row·W + bias`: the
/// sequential-FMA chain of [`dot_f32`] run down column `j` of the
/// row-major weight matrix, plus the FP32 bias add. The LAMP repair
/// kernel paired with [`matvec_ps_bias_into`].
#[inline]
pub fn matvec_col_f32(x_row: &[f32], w: &Matrix, bias: &[f32], j: usize) -> f32 {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert!(j < w.cols());
    let mut c = 0.0f32;
    for (p, &xv) in x_row.iter().enumerate() {
        c = xv.mul_add(w.row(p)[j], c);
    }
    if bias.is_empty() {
        c
    } else {
        c + bias[j]
    }
}

/// Four-way-unrolled FP32 dot product (independent partial sums break the
/// FP add latency chain and let the compiler vectorize). Shared by
/// [`matmul_transposed_into`] and the KV-cache unembedding row so both
/// produce bit-identical logits.
#[inline]
pub fn dot_unrolled4(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let k = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut p = 0;
    while p + 4 <= k {
        s0 += a[p] * b[p];
        s1 += a[p + 1] * b[p + 1];
        s2 += a[p + 2] * b[p + 2];
        s3 += a[p + 3] * b[p + 3];
        p += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while p < k {
        s += a[p] * b[p];
        p += 1;
    }
    s
}

fn check_bias_shapes(x: &Matrix, w: &Matrix, bias: &[f32]) -> Result<()> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "matmul_bias_fast: {:?} x {:?}",
            x.shape(),
            w.shape()
        )));
    }
    if !bias.is_empty() && bias.len() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_bias_fast: bias {} != n {}",
            bias.len(),
            w.cols()
        )));
    }
    Ok(())
}

/// Throughput-oriented FP32 matmul into a reusable output: `C = X·W + b`
/// with X: [m, k] and W *already row-major [k, n]* (no transpose needed).
/// `out` is resized (allocation-free once warm) and fully overwritten.
///
/// Used on the FP32 parts of the model (QKV/proj/MLP/logits) where exact
/// accumulation order is not part of the simulated-arithmetic contract —
/// the PS(μ) score path stays on the sequential-FMA [`crate::softfloat::dot::dot_ps`].
/// ~an order of magnitude faster than per-dot sequential FMA chains
/// (latency-bound) at these sizes; see DESIGN.md §Perf.
pub fn matmul_bias_into(
    x: &Matrix,
    w: &Matrix,
    bias: &[f32],
    out: &mut Matrix,
) -> Result<()> {
    check_bias_shapes(x, w, bias)?;
    let m = x.rows();
    let n = w.cols();
    out.resize(m, n);
    for i in 0..m {
        matvec_bias_into(x.row(i), w, bias, out.row_mut(i));
    }
    Ok(())
}

/// Allocating wrapper around [`matmul_bias_into`].
pub fn matmul_bias_fast(x: &Matrix, w: &Matrix, bias: &[f32]) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_bias_into(x, w, bias, &mut c)?;
    Ok(c)
}

/// `C = X·Wᵀ` for W stored [n, k] (each output is a row dot) into a
/// reusable output: the fast path for the tied unembedding where `wte` is
/// [vocab, d].
pub fn matmul_transposed_into(x: &Matrix, w: &Matrix, out: &mut Matrix) -> Result<()> {
    if x.cols() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_transposed_fast: {:?} x {:?}T",
            x.shape(),
            w.shape()
        )));
    }
    let m = x.rows();
    let n = w.rows();
    out.resize(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ci = out.row_mut(i);
        for j in 0..n {
            ci[j] = dot_unrolled4(xi, w.row(j));
        }
    }
    Ok(())
}

/// Allocating wrapper around [`matmul_transposed_into`].
pub fn matmul_transposed_fast(x: &Matrix, w: &Matrix) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_transposed_into(x, w, &mut c)?;
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let c = matmul_f32(&a, &eye).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(matmul_f32(&a, &b).is_err());
        assert!(matmul_ps(&a, &b, 7).is_err());
    }

    #[test]
    fn ps23_equals_f32() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        let b = Matrix::randn(9, 3, 1.0, &mut rng);
        let c23 = matmul_ps(&a, &b, 23).unwrap();
        let cf = matmul_f32(&a, &b).unwrap();
        assert_eq!(c23, cf);
    }

    #[test]
    fn lower_mu_more_error() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 64, 1.0, &mut rng);
        let b = Matrix::randn(64, 8, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let e4 = matmul_ps(&a, &b, 4).unwrap().max_abs_diff(&cf).unwrap();
        let e10 = matmul_ps(&a, &b, 10).unwrap().max_abs_diff(&cf).unwrap();
        assert!(e4 > e10, "e4={e4} e10={e10}");
    }

    #[test]
    fn recompute_masked_restores_flagged() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(6, 32, 1.0, &mut rng);
        let b = Matrix::randn(32, 6, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let mut c = matmul_ps(&a, &b, 3).unwrap();
        // Flag every other entry.
        let mask: Vec<bool> = (0..36).map(|k| k % 2 == 0).collect();
        let n = recompute_masked(&mut c, &a, &b, &mask).unwrap();
        assert_eq!(n, 18);
        for i in 0..6 {
            for j in 0..6 {
                if mask[i * 6 + j] {
                    assert_eq!(c.get(i, j), cf.get(i, j));
                }
            }
        }
    }

    #[test]
    fn fast_matmul_matches_reference_within_tolerance() {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(9, 33, 1.0, &mut rng);
        let w = Matrix::randn(33, 17, 1.0, &mut rng);
        let bias: Vec<f32> = (0..17).map(|_| rng.normal_f32()).collect();
        let fast = matmul_bias_fast(&x, &w, &bias).unwrap();
        let mut slow = matmul_f32(&x, &w).unwrap();
        for i in 0..9 {
            for j in 0..17 {
                slow.set(i, j, slow.get(i, j) + bias[j]);
            }
        }
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
        // No bias variant.
        let fast0 = matmul_bias_fast(&x, &w, &[]).unwrap();
        let slow0 = matmul_f32(&x, &w).unwrap();
        assert!(fast0.max_abs_diff(&slow0).unwrap() < 1e-4);
    }

    #[test]
    fn transposed_fast_matches_reference() {
        let mut rng = Rng::new(8);
        let x = Matrix::randn(5, 29, 1.0, &mut rng);
        let w = Matrix::randn(13, 29, 1.0, &mut rng); // [n, k]
        let fast = matmul_transposed_fast(&x, &w).unwrap();
        let slow = matmul_f32(&x, &w.transpose()).unwrap();
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
    }

    #[test]
    fn fast_matmul_shape_checks() {
        let x = Matrix::zeros(2, 3);
        let w = Matrix::zeros(4, 2);
        assert!(matmul_bias_fast(&x, &w, &[]).is_err());
        assert!(matmul_bias_fast(&x, &Matrix::zeros(3, 4), &[0.0; 3]).is_err());
        assert!(matmul_transposed_fast(&x, &Matrix::zeros(4, 5)).is_err());
    }

    #[test]
    fn matvec_ps_matches_per_column_dot_ps_bitwise() {
        // The PS row-matvec's contract: each output column equals dot_ps
        // over the explicit (strided) column, bit for bit, for every μ.
        let mut rng = Rng::new(9);
        for _ in 0..30 {
            let k = rng.range(1, 24);
            let n = rng.range(1, 17);
            let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            let w = Matrix::randn(k, n, 1.0, &mut rng);
            let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for mu in [1u32, 4, 11, 23] {
                let mut out = vec![0.0f32; n];
                matvec_ps_bias_into(&x, &w, &bias, mu, &mut out);
                for j in 0..n {
                    let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
                    let want = dot_ps(&x, &col, mu) + bias[j];
                    assert_eq!(out[j].to_bits(), want.to_bits(), "j={j} mu={mu}");
                }
                // No-bias variant.
                let mut out0 = vec![0.0f32; n];
                matvec_ps_bias_into(&x, &w, &[], mu, &mut out0);
                for j in 0..n {
                    let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
                    assert_eq!(out0[j].to_bits(), dot_ps(&x, &col, mu).to_bits());
                }
            }
        }
    }

    #[test]
    fn matvec_col_f32_matches_sequential_fma() {
        let mut rng = Rng::new(10);
        let k = 19;
        let n = 7;
        let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
        let w = Matrix::randn(k, n, 1.0, &mut rng);
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        for j in 0..n {
            let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
            let want = dot_f32(&x, &col) + bias[j];
            assert_eq!(matvec_col_f32(&x, &w, &bias, j).to_bits(), want.to_bits());
            assert_eq!(
                matvec_col_f32(&x, &w, &[], j).to_bits(),
                dot_f32(&x, &col).to_bits()
            );
        }
    }

    #[test]
    fn matvec_ps_mu23_is_fma_chain_not_vectorized_path() {
        // μ=23 PS accumulation equals the sequential FMA chain (dot_f32),
        // which is deliberately NOT the vectorized matvec_bias_into order —
        // the reference short-circuit, not μ=23, reproduces the fast path.
        let mut rng = Rng::new(11);
        let k = 33;
        let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
        let w = Matrix::randn(k, 5, 1.0, &mut rng);
        let mut ps = vec![0.0f32; 5];
        matvec_ps_bias_into(&x, &w, &[], 23, &mut ps);
        for j in 0..5 {
            let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
            assert_eq!(ps[j].to_bits(), dot_f32(&x, &col).to_bits());
        }
    }

    #[test]
    fn recompute_mask_len_checked() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 2);
        let mut c = Matrix::zeros(2, 2);
        assert!(recompute_masked(&mut c, &a, &b, &[true; 3]).is_err());
    }
}
