//! Matrix multiplication under FP32 and PS(μ) accumulation, plus masked
//! FP32 recomputation — the LAMP primitive: recompute only the inner
//! products flagged by the selection rule.

use super::tensor::Matrix;
use crate::error::{Error, Result};
use crate::softfloat::dot::{dot_f32, dot_ps};

fn check(a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::shape(format!(
            "matmul: {:?} x {:?}",
            a.shape(),
            b.shape()
        )));
    }
    Ok(())
}

/// C = A·B with FP32 accumulation (sequential order, matching `matmul_ps`
/// at μ=23 bit-for-bit).
pub fn matmul_f32(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_f32(arow, bt.row(j)));
        }
    }
    Ok(c)
}

/// C = A·B with per-step PS(μ) rounding of the accumulator (paper §4.1).
pub fn matmul_ps(a: &Matrix, b: &Matrix, mu: u32) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_ps(arow, bt.row(j), mu));
        }
    }
    Ok(c)
}

/// Recompute in FP32 the entries of `c` flagged by `mask` (true = recompute)
/// and return the number of recomputed entries.
///
/// This is the mixed-precision accumulation step of LAMP: the matrix is
/// split into the low-precision block and the flagged block, each computed
/// with its own accumulation algorithm (paper §3, matrix-product property).
pub fn recompute_masked(
    c: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
    mask: &[bool],
) -> Result<usize> {
    check(a, b)?;
    if c.shape() != (a.rows(), b.cols()) || mask.len() != a.rows() * b.cols() {
        return Err(Error::shape("recompute_masked: output/mask shape".to_string()));
    }
    let bt = b.transpose();
    let mut n = 0;
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            if mask[i * b.cols() + j] {
                c.set(i, j, dot_f32(a.row(i), bt.row(j)));
                n += 1;
            }
        }
    }
    Ok(n)
}

/// Throughput-oriented FP32 matmul: `C = X·W + b` with X: [m, k] and W
/// *already row-major [k, n]* (no transpose needed), i–k–j loop order so
/// the inner loop vectorizes across output columns.
///
/// Used on the FP32 parts of the model (QKV/proj/MLP/logits) where exact
/// accumulation order is not part of the simulated-arithmetic contract —
/// the PS(μ) score path stays on the sequential-FMA [`crate::softfloat::dot::dot_ps`].
/// ~an order of magnitude faster than per-dot sequential FMA chains
/// (latency-bound) at these sizes; see EXPERIMENTS.md §Perf.
pub fn matmul_bias_fast(x: &Matrix, w: &Matrix, bias: &[f32]) -> Result<Matrix> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "matmul_bias_fast: {:?} x {:?}",
            x.shape(),
            w.shape()
        )));
    }
    let (m, k) = x.shape();
    let n = w.cols();
    if !bias.is_empty() && bias.len() != n {
        return Err(Error::shape(format!(
            "matmul_bias_fast: bias {} != n {n}",
            bias.len()
        )));
    }
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ci = c.row_mut(i);
        if !bias.is_empty() {
            ci.copy_from_slice(bias);
        }
        for (p, &xv) in xi.iter().enumerate().take(k) {
            let wrow = w.row(p);
            for j in 0..n {
                ci[j] += xv * wrow[j];
            }
        }
    }
    Ok(c)
}

/// `C = X·Wᵀ` for W stored [n, k] (each output is a row dot): the fast
/// path for the tied unembedding where `wte` is [vocab, d].
pub fn matmul_transposed_fast(x: &Matrix, w: &Matrix) -> Result<Matrix> {
    if x.cols() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_transposed_fast: {:?} x {:?}T",
            x.shape(),
            w.shape()
        )));
    }
    let (m, k) = x.shape();
    let n = w.rows();
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ci = c.row_mut(i);
        for j in 0..n {
            let wj = w.row(j);
            // Four independent partial sums: breaks the FP add latency
            // chain and lets the compiler vectorize.
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut p = 0;
            while p + 4 <= k {
                s0 += xi[p] * wj[p];
                s1 += xi[p + 1] * wj[p + 1];
                s2 += xi[p + 2] * wj[p + 2];
                s3 += xi[p + 3] * wj[p + 3];
                p += 4;
            }
            let mut s = (s0 + s1) + (s2 + s3);
            while p < k {
                s += xi[p] * wj[p];
                p += 1;
            }
            ci[j] = s;
        }
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let c = matmul_f32(&a, &eye).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(matmul_f32(&a, &b).is_err());
        assert!(matmul_ps(&a, &b, 7).is_err());
    }

    #[test]
    fn ps23_equals_f32() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        let b = Matrix::randn(9, 3, 1.0, &mut rng);
        let c23 = matmul_ps(&a, &b, 23).unwrap();
        let cf = matmul_f32(&a, &b).unwrap();
        assert_eq!(c23, cf);
    }

    #[test]
    fn lower_mu_more_error() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 64, 1.0, &mut rng);
        let b = Matrix::randn(64, 8, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let e4 = matmul_ps(&a, &b, 4).unwrap().max_abs_diff(&cf).unwrap();
        let e10 = matmul_ps(&a, &b, 10).unwrap().max_abs_diff(&cf).unwrap();
        assert!(e4 > e10, "e4={e4} e10={e10}");
    }

    #[test]
    fn recompute_masked_restores_flagged() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(6, 32, 1.0, &mut rng);
        let b = Matrix::randn(32, 6, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let mut c = matmul_ps(&a, &b, 3).unwrap();
        // Flag every other entry.
        let mask: Vec<bool> = (0..36).map(|k| k % 2 == 0).collect();
        let n = recompute_masked(&mut c, &a, &b, &mask).unwrap();
        assert_eq!(n, 18);
        for i in 0..6 {
            for j in 0..6 {
                if mask[i * 6 + j] {
                    assert_eq!(c.get(i, j), cf.get(i, j));
                }
            }
        }
    }

    #[test]
    fn fast_matmul_matches_reference_within_tolerance() {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(9, 33, 1.0, &mut rng);
        let w = Matrix::randn(33, 17, 1.0, &mut rng);
        let bias: Vec<f32> = (0..17).map(|_| rng.normal_f32()).collect();
        let fast = matmul_bias_fast(&x, &w, &bias).unwrap();
        let mut slow = matmul_f32(&x, &w).unwrap();
        for i in 0..9 {
            for j in 0..17 {
                slow.set(i, j, slow.get(i, j) + bias[j]);
            }
        }
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
        // No bias variant.
        let fast0 = matmul_bias_fast(&x, &w, &[]).unwrap();
        let slow0 = matmul_f32(&x, &w).unwrap();
        assert!(fast0.max_abs_diff(&slow0).unwrap() < 1e-4);
    }

    #[test]
    fn transposed_fast_matches_reference() {
        let mut rng = Rng::new(8);
        let x = Matrix::randn(5, 29, 1.0, &mut rng);
        let w = Matrix::randn(13, 29, 1.0, &mut rng); // [n, k]
        let fast = matmul_transposed_fast(&x, &w).unwrap();
        let slow = matmul_f32(&x, &w.transpose()).unwrap();
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
    }

    #[test]
    fn fast_matmul_shape_checks() {
        let x = Matrix::zeros(2, 3);
        let w = Matrix::zeros(4, 2);
        assert!(matmul_bias_fast(&x, &w, &[]).is_err());
        assert!(matmul_bias_fast(&x, &Matrix::zeros(3, 4), &[0.0; 3]).is_err());
        assert!(matmul_transposed_fast(&x, &Matrix::zeros(4, 5)).is_err());
    }

    #[test]
    fn recompute_mask_len_checked() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 2);
        let mut c = Matrix::zeros(2, 2);
        assert!(recompute_masked(&mut c, &a, &b, &[true; 3]).is_err());
    }
}
