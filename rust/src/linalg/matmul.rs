//! Matrix multiplication under FP32 and PS(μ) accumulation, plus masked
//! FP32 recomputation — the LAMP primitive: recompute only the inner
//! products flagged by the selection rule.
//!
//! The `*_wt` variants read [`WeightTensor`] storage directly with
//! dequantization fused into the inner loop: f32-backed storage (F32 and
//! PS-rounded formats) runs the *identical* slice kernels as the `Matrix`
//! versions, and bf16 storage widens each weight in-register
//! ([`super::tensor::bf16_to_f32`], a 16-bit shift) inside the same loop
//! structure — so a fused call is **bitwise identical** to dequantizing
//! the weights first and calling the f32 kernel, while streaming half the
//! weight bytes.

use super::simd;
pub use super::simd::{dot_block, dot_block_bf16};
use super::tensor::{bf16_to_f32, Matrix, WeightStore, WeightTensor};
use crate::error::{Error, Result};
use crate::softfloat::dot::{dot_f32, dot_ps};
use crate::softfloat::round::round_to_mantissa;

fn check(a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::shape(format!(
            "matmul: {:?} x {:?}",
            a.shape(),
            b.shape()
        )));
    }
    Ok(())
}

/// C = A·B with FP32 accumulation (sequential order, matching `matmul_ps`
/// at μ=23 bit-for-bit).
pub fn matmul_f32(a: &Matrix, b: &Matrix) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_f32(arow, bt.row(j)));
        }
    }
    Ok(c)
}

/// C = A·B with per-step PS(μ) rounding of the accumulator (paper §4.1).
pub fn matmul_ps(a: &Matrix, b: &Matrix, mu: u32) -> Result<Matrix> {
    check(a, b)?;
    let bt = b.transpose();
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..b.cols() {
            c.set(i, j, dot_ps(arow, bt.row(j), mu));
        }
    }
    Ok(c)
}

/// Recompute in FP32 the entries of `c` flagged by `mask` (true = recompute)
/// and return the number of recomputed entries.
///
/// This is the mixed-precision accumulation step of LAMP: the matrix is
/// split into the low-precision block and the flagged block, each computed
/// with its own accumulation algorithm (paper §3, matrix-product property).
pub fn recompute_masked(
    c: &mut Matrix,
    a: &Matrix,
    b: &Matrix,
    mask: &[bool],
) -> Result<usize> {
    check(a, b)?;
    if c.shape() != (a.rows(), b.cols()) || mask.len() != a.rows() * b.cols() {
        return Err(Error::shape("recompute_masked: output/mask shape".to_string()));
    }
    // Strided column dots instead of materializing `b.transpose()`: the
    // ascending-p FMA chain down column j is exactly [`dot_f32`] on the
    // explicit column, so this stays bitwise identical to the old
    // transpose-then-row-dot body while allocating nothing (repair calls
    // sit on the decode hot path).
    let bc = b.cols();
    let bd = b.data();
    let mut n = 0;
    for i in 0..a.rows() {
        let arow = a.row(i);
        for j in 0..bc {
            if mask[i * bc + j] {
                let mut cij = 0.0f32;
                for (p, &av) in arow.iter().enumerate() {
                    cij = av.mul_add(bd[p * bc + j], cij);
                }
                c.set(i, j, cij);
                n += 1;
            }
        }
    }
    Ok(n)
}

/// One row of the fast-path matmul: `out = x_row·W + bias` with W
/// row-major [k, n], p–j loop order so the inner loop vectorizes across
/// output columns. Shared by the batched [`matmul_bias_into`] and the
/// KV-cache decode step, which runs the *same* FP32 op sequence on a
/// single row — that shared kernel is what makes incremental decode
/// bit-identical to the full forward pass (DESIGN.md §Bit-exactness).
#[inline]
pub fn matvec_bias_into(x_row: &[f32], w: &Matrix, bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    matvec_bias_flat(x_row, w.data(), w.cols(), bias, out);
}

/// Slice-level body of [`matvec_bias_into`] over a flat row-major [k, n]
/// f32 buffer — shared with the f32-backed arm of [`matvec_bias_into_wt`]
/// so the two are bit-identical by construction.
#[inline]
fn matvec_bias_flat(x_row: &[f32], wdata: &[f32], n: usize, bias: &[f32], out: &mut [f32]) {
    // Mul+add is elementwise per (p, j): the vector body computes the same
    // FP32 ops on the same values in the same order, so SIMD and scalar are
    // bitwise identical here (no chain pin involved).
    if simd::matvec_f32_simd(x_row, wdata, n, bias, out) {
        return;
    }
    if bias.is_empty() {
        for o in out.iter_mut() {
            *o = 0.0;
        }
    } else {
        out.copy_from_slice(bias);
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = &wdata[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
}

/// bf16 twin of [`matvec_bias_flat`]: the same p–j loop with each weight
/// widened in-register. Identical f32 arithmetic on identical values in
/// identical order ⇒ bitwise equal to dequantize-then-`matvec_bias_into`.
#[inline]
fn matvec_bias_flat_bf16(x_row: &[f32], wdata: &[u16], n: usize, bias: &[f32], out: &mut [f32]) {
    if simd::matvec_bf16_simd(x_row, wdata, n, bias, out) {
        return;
    }
    if bias.is_empty() {
        for o in out.iter_mut() {
            *o = 0.0;
        }
    } else {
        out.copy_from_slice(bias);
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = &wdata[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xv * bf16_to_f32(wv);
        }
    }
}

/// [`matvec_bias_into`] over mixed-precision weight storage with fused
/// dequantization — the decode hot path reads the stored bytes directly.
#[inline]
pub fn matvec_bias_into_wt(x_row: &[f32], w: &WeightTensor, bias: &[f32], out: &mut [f32]) {
    let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Matvec);
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            matvec_bias_flat(x_row, d, w.cols(), bias, out)
        }
        WeightStore::Bf16(d) => matvec_bias_flat_bf16(x_row, d, w.cols(), bias, out),
    }
}

/// One row of the PS(μ) matmul: `out[j] = dot_ps(x_row, W[:, j], μ)` with
/// the bias added once in FP32 afterwards. Each output column keeps its own
/// accumulator advanced with the paper's per-step `round(fma(..))` chain in
/// input order (p ascending) — **bit-identical to [`dot_ps`] on the
/// explicit column** — while the p-major loop walks the row-major weight
/// matrix cache-friendly, interleaving the independent chains exactly like
/// the fused attention score kernel. This is the whole-model-LAMP
/// counterpart of [`matvec_bias_into`]: shared by the batched PS matmul of
/// `model::mlp` and the KV-cache decode row, so incremental decode stays
/// bit-identical to the full pass under every plan.
pub fn matvec_ps_bias_into(
    x_row: &[f32],
    w: &Matrix,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    matvec_ps_bias_flat(x_row, w.data(), w.cols(), bias, mu, out);
}

/// Slice-level body of [`matvec_ps_bias_into`] (shared with the f32-backed
/// arm of [`matvec_ps_bias_into_wt`]).
#[inline]
fn matvec_ps_bias_flat(
    x_row: &[f32],
    wdata: &[f32],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) {
    // Each output column is an independent per-step round(fma(..)) chain in
    // ascending p; the vector body advances 8 such chains side by side with
    // a lanewise-identical rounding primitive, so the per-column chain —
    // and therefore every bit — is unchanged.
    if simd::matvec_ps_simd(x_row, wdata, n, bias, mu, out) {
        return;
    }
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = &wdata[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o = round_to_mantissa(xv.mul_add(wv, *o), mu);
        }
    }
    if !bias.is_empty() {
        for (o, &b) in out.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// bf16 twin of [`matvec_ps_bias_flat`] — same `round(fma(..))` chain on
/// the widened weights.
#[inline]
fn matvec_ps_bias_flat_bf16(
    x_row: &[f32],
    wdata: &[u16],
    n: usize,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) {
    if simd::matvec_ps_bf16_simd(x_row, wdata, n, bias, mu, out) {
        return;
    }
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (p, &xv) in x_row.iter().enumerate() {
        let wrow = &wdata[p * n..(p + 1) * n];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o = round_to_mantissa(xv.mul_add(bf16_to_f32(wv), *o), mu);
        }
    }
    if !bias.is_empty() {
        for (o, &b) in out.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// [`matvec_ps_bias_into`] over mixed-precision weight storage with fused
/// dequantization.
pub fn matvec_ps_bias_into_wt(
    x_row: &[f32],
    w: &WeightTensor,
    bias: &[f32],
    mu: u32,
    out: &mut [f32],
) {
    let _t = crate::obs::timers::scoped(crate::obs::timers::Site::Matvec);
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert_eq!(out.len(), w.cols());
    debug_assert!(bias.is_empty() || bias.len() == w.cols());
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            matvec_ps_bias_flat(x_row, d, w.cols(), bias, mu, out)
        }
        WeightStore::Bf16(d) => matvec_ps_bias_flat_bf16(x_row, d, w.cols(), bias, mu, out),
    }
}

/// FP32 recomputation of one output column of `x_row·W + bias`: the
/// sequential-FMA chain of [`dot_f32`] run down column `j` of the
/// row-major weight matrix, plus the FP32 bias add. The LAMP repair
/// kernel paired with [`matvec_ps_bias_into`].
#[inline]
pub fn matvec_col_f32(x_row: &[f32], w: &Matrix, bias: &[f32], j: usize) -> f32 {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert!(j < w.cols());
    let mut c = 0.0f32;
    for (p, &xv) in x_row.iter().enumerate() {
        c = xv.mul_add(w.row(p)[j], c);
    }
    if bias.is_empty() {
        c
    } else {
        c + bias[j]
    }
}

/// [`matvec_col_f32`] over mixed-precision weight storage: the same
/// sequential-FMA chain down the stored column, dequantizing on the fly.
#[inline]
pub fn matvec_col_f32_wt(x_row: &[f32], w: &WeightTensor, bias: &[f32], j: usize) -> f32 {
    debug_assert_eq!(x_row.len(), w.rows());
    debug_assert!(j < w.cols());
    let n = w.cols();
    let mut c = 0.0f32;
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            for (p, &xv) in x_row.iter().enumerate() {
                c = xv.mul_add(d[p * n + j], c);
            }
        }
        WeightStore::Bf16(d) => {
            for (p, &xv) in x_row.iter().enumerate() {
                c = xv.mul_add(bf16_to_f32(d[p * n + j]), c);
            }
        }
    }
    if bias.is_empty() {
        c
    } else {
        c + bias[j]
    }
}

/// Contiguous row `r` of a [n, k] weight tensor dotted with `x` via the
/// pinned block-dot chain ([`dot_block`]), dequantizing on the fly — the
/// reference unembedding row over mixed-precision `wte` storage.
///
/// PR 8 replaced the old 4-way-unrolled scalar chain (`dot_unrolled4`)
/// with the SIMD-shaped 32-wide block chain as the defined reference; the
/// old golden pins were regenerated in the same commit (DESIGN.md §SIMD &
/// tiled precision).
#[inline]
pub fn wt_row_dot_block(x: &[f32], w: &WeightTensor, r: usize) -> f32 {
    let k = w.cols();
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            dot_block(x, &d[r * k..(r + 1) * k])
        }
        WeightStore::Bf16(d) => dot_block_bf16(x, &d[r * k..(r + 1) * k]),
    }
}

/// Contiguous row `r` of a [n, k] weight tensor dotted with `x` under the
/// per-step PS(μ) chain of [`dot_ps`], dequantizing on the fly — the
/// sampler-site low-precision logit dot over mixed-precision storage.
#[inline]
pub fn wt_row_dot_ps(x: &[f32], w: &WeightTensor, r: usize, mu: u32) -> f32 {
    let k = w.cols();
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            dot_ps(x, &d[r * k..(r + 1) * k], mu)
        }
        WeightStore::Bf16(d) => {
            let row = &d[r * k..(r + 1) * k];
            let mut c = 0.0f32;
            for i in 0..x.len() {
                c = round_to_mantissa(x[i].mul_add(bf16_to_f32(row[i]), c), mu);
            }
            c
        }
    }
}

/// Contiguous row `r` of a [n, k] weight tensor dotted with `x` via the
/// sequential-FMA FP32 chain of [`dot_f32`], dequantizing on the fly —
/// the sampler-site repair kernel over mixed-precision storage.
#[inline]
pub fn wt_row_dot_f32(x: &[f32], w: &WeightTensor, r: usize) -> f32 {
    let k = w.cols();
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            dot_f32(x, &d[r * k..(r + 1) * k])
        }
        WeightStore::Bf16(d) => {
            let row = &d[r * k..(r + 1) * k];
            let mut c = 0.0f32;
            for i in 0..x.len() {
                c = x[i].mul_add(bf16_to_f32(row[i]), c);
            }
            c
        }
    }
}

fn check_bias_shapes(x: &Matrix, w: &Matrix, bias: &[f32]) -> Result<()> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "matmul_bias_fast: {:?} x {:?}",
            x.shape(),
            w.shape()
        )));
    }
    if !bias.is_empty() && bias.len() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_bias_fast: bias {} != n {}",
            bias.len(),
            w.cols()
        )));
    }
    Ok(())
}

/// Throughput-oriented FP32 matmul into a reusable output: `C = X·W + b`
/// with X: [m, k] and W *already row-major [k, n]* (no transpose needed).
/// `out` is resized (allocation-free once warm) and fully overwritten.
///
/// Used on the FP32 parts of the model (QKV/proj/MLP/logits) where exact
/// accumulation order is not part of the simulated-arithmetic contract —
/// the PS(μ) score path stays on the sequential-FMA [`crate::softfloat::dot::dot_ps`].
/// ~an order of magnitude faster than per-dot sequential FMA chains
/// (latency-bound) at these sizes; see DESIGN.md §Perf.
pub fn matmul_bias_into(
    x: &Matrix,
    w: &Matrix,
    bias: &[f32],
    out: &mut Matrix,
) -> Result<()> {
    check_bias_shapes(x, w, bias)?;
    let m = x.rows();
    let n = w.cols();
    out.resize(m, n);
    matmul_rows_f32(x, w.data(), n, bias, out);
    Ok(())
}

/// 4-row register-blocked body shared by [`matmul_bias_into`] and the
/// f32-backed arm of [`matmul_bias_into_wt`]: each streamed weight panel
/// feeds four output rows at once (4× less W traffic), while every output
/// keeps the ascending-p mul+add order of the single-row matvec — so the
/// blocked batched call stays bitwise identical to per-row kernels (and to
/// the KV-cache decode row). Remainder rows run the row kernel directly.
fn matmul_rows_f32(x: &Matrix, wdata: &[f32], n: usize, bias: &[f32], out: &mut Matrix) {
    let m = x.rows();
    if m == 0 || n == 0 {
        return;
    }
    let mut rows = out.data_mut().chunks_exact_mut(n);
    let mut i = 0;
    while i + 4 <= m {
        let r0 = rows.next().unwrap();
        let r1 = rows.next().unwrap();
        let r2 = rows.next().unwrap();
        let r3 = rows.next().unwrap();
        let xs = [x.row(i), x.row(i + 1), x.row(i + 2), x.row(i + 3)];
        if !simd::matvec4_f32_simd(xs, wdata, n, bias, [&mut *r0, &mut *r1, &mut *r2, &mut *r3]) {
            matvec_bias_flat(xs[0], wdata, n, bias, r0);
            matvec_bias_flat(xs[1], wdata, n, bias, r1);
            matvec_bias_flat(xs[2], wdata, n, bias, r2);
            matvec_bias_flat(xs[3], wdata, n, bias, r3);
        }
        i += 4;
    }
    for r in rows {
        matvec_bias_flat(x.row(i), wdata, n, bias, r);
        i += 1;
    }
}

/// bf16 twin of [`matmul_rows_f32`].
fn matmul_rows_bf16(x: &Matrix, wdata: &[u16], n: usize, bias: &[f32], out: &mut Matrix) {
    let m = x.rows();
    if m == 0 || n == 0 {
        return;
    }
    let mut rows = out.data_mut().chunks_exact_mut(n);
    let mut i = 0;
    while i + 4 <= m {
        let r0 = rows.next().unwrap();
        let r1 = rows.next().unwrap();
        let r2 = rows.next().unwrap();
        let r3 = rows.next().unwrap();
        let xs = [x.row(i), x.row(i + 1), x.row(i + 2), x.row(i + 3)];
        if !simd::matvec4_bf16_simd(xs, wdata, n, bias, [&mut *r0, &mut *r1, &mut *r2, &mut *r3]) {
            matvec_bias_flat_bf16(xs[0], wdata, n, bias, r0);
            matvec_bias_flat_bf16(xs[1], wdata, n, bias, r1);
            matvec_bias_flat_bf16(xs[2], wdata, n, bias, r2);
            matvec_bias_flat_bf16(xs[3], wdata, n, bias, r3);
        }
        i += 4;
    }
    for r in rows {
        matvec_bias_flat_bf16(x.row(i), wdata, n, bias, r);
        i += 1;
    }
}

/// Allocating wrapper around [`matmul_bias_into`].
pub fn matmul_bias_fast(x: &Matrix, w: &Matrix, bias: &[f32]) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_bias_into(x, w, bias, &mut c)?;
    Ok(c)
}

fn check_bias_shapes_wt(x: &Matrix, w: &WeightTensor, bias: &[f32]) -> Result<()> {
    if x.cols() != w.rows() {
        return Err(Error::shape(format!(
            "matmul_bias_into_wt: {:?} x {:?}",
            x.shape(),
            w.shape()
        )));
    }
    if !bias.is_empty() && bias.len() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_bias_into_wt: bias {} != n {}",
            bias.len(),
            w.cols()
        )));
    }
    Ok(())
}

/// [`matmul_bias_into`] over mixed-precision weight storage: the same
/// 4-row register-blocked body with dequantization fused into the panel
/// stream (so the batched call and the KV-cache decode row stay
/// bit-identical per storage format).
pub fn matmul_bias_into_wt(
    x: &Matrix,
    w: &WeightTensor,
    bias: &[f32],
    out: &mut Matrix,
) -> Result<()> {
    check_bias_shapes_wt(x, w, bias)?;
    let m = x.rows();
    let n = w.cols();
    out.resize(m, n);
    match w.store() {
        WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
            matmul_rows_f32(x, d, n, bias, out)
        }
        WeightStore::Bf16(d) => matmul_rows_bf16(x, d, n, bias, out),
    }
    Ok(())
}

/// Allocating wrapper around [`matmul_bias_into_wt`].
pub fn matmul_bias_fast_wt(x: &Matrix, w: &WeightTensor, bias: &[f32]) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_bias_into_wt(x, w, bias, &mut c)?;
    Ok(c)
}

/// `C = X·Wᵀ` for W stored [n, k] (each output is a row dot) into a
/// reusable output: the fast path for the tied unembedding where `wte` is
/// [vocab, d].
pub fn matmul_transposed_into(x: &Matrix, w: &Matrix, out: &mut Matrix) -> Result<()> {
    if x.cols() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_transposed_fast: {:?} x {:?}T",
            x.shape(),
            w.shape()
        )));
    }
    let m = x.rows();
    let n = w.rows();
    out.resize(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ci = out.row_mut(i);
        for j in 0..n {
            ci[j] = dot_block(xi, w.row(j));
        }
    }
    Ok(())
}

/// Allocating wrapper around [`matmul_transposed_into`].
pub fn matmul_transposed_fast(x: &Matrix, w: &Matrix) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_transposed_into(x, w, &mut c)?;
    Ok(c)
}

/// [`matmul_transposed_into`] over mixed-precision weight storage — the
/// tied-unembedding fast path reading `wte` in its stored format (each
/// output is a fused-dequant [`wt_row_dot_block`] row dot).
pub fn matmul_transposed_into_wt(
    x: &Matrix,
    w: &WeightTensor,
    out: &mut Matrix,
) -> Result<()> {
    if x.cols() != w.cols() {
        return Err(Error::shape(format!(
            "matmul_transposed_into_wt: {:?} x {:?}T",
            x.shape(),
            w.shape()
        )));
    }
    let m = x.rows();
    let n = w.rows();
    out.resize(m, n);
    for i in 0..m {
        let xi = x.row(i);
        let ci = out.row_mut(i);
        for (j, c) in ci.iter_mut().enumerate() {
            *c = wt_row_dot_block(xi, w, j);
        }
    }
    Ok(())
}

/// Allocating wrapper around [`matmul_transposed_into_wt`].
pub fn matmul_transposed_fast_wt(x: &Matrix, w: &WeightTensor) -> Result<Matrix> {
    let mut c = Matrix::zeros(0, 0);
    matmul_transposed_into_wt(x, w, &mut c)?;
    Ok(c)
}

#[cfg(test)]
mod alloc_counter {
    //! Thread-local allocation counter for no-alloc assertions: a counting
    //! wrapper around the system allocator, installed for the unit-test
    //! binary only. The counter is a const-initialized thread-local `Cell`
    //! (no lazy TLS init, so counting inside `alloc` cannot recurse) and
    //! per-thread, so parallel tests don't perturb each other's counts.
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    thread_local! {
        static ALLOCS: Cell<usize> = const { Cell::new(0) };
    }

    struct CountingAlloc;

    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.with(|c| c.set(c.get() + 1));
            System.alloc(layout)
        }
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    #[global_allocator]
    static COUNTING: CountingAlloc = CountingAlloc;

    /// Heap allocations performed by the current thread so far.
    pub fn allocation_count() -> usize {
        ALLOCS.with(|c| c.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut eye = Matrix::zeros(4, 4);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let c = matmul_f32(&a, &eye).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(matmul_f32(&a, &b).is_err());
        assert!(matmul_ps(&a, &b, 7).is_err());
    }

    #[test]
    fn ps23_equals_f32() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(5, 9, 1.0, &mut rng);
        let b = Matrix::randn(9, 3, 1.0, &mut rng);
        let c23 = matmul_ps(&a, &b, 23).unwrap();
        let cf = matmul_f32(&a, &b).unwrap();
        assert_eq!(c23, cf);
    }

    #[test]
    fn lower_mu_more_error() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(8, 64, 1.0, &mut rng);
        let b = Matrix::randn(64, 8, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let e4 = matmul_ps(&a, &b, 4).unwrap().max_abs_diff(&cf).unwrap();
        let e10 = matmul_ps(&a, &b, 10).unwrap().max_abs_diff(&cf).unwrap();
        assert!(e4 > e10, "e4={e4} e10={e10}");
    }

    #[test]
    fn recompute_masked_restores_flagged() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(6, 32, 1.0, &mut rng);
        let b = Matrix::randn(32, 6, 1.0, &mut rng);
        let cf = matmul_f32(&a, &b).unwrap();
        let mut c = matmul_ps(&a, &b, 3).unwrap();
        // Flag every other entry.
        let mask: Vec<bool> = (0..36).map(|k| k % 2 == 0).collect();
        let before = super::alloc_counter::allocation_count();
        let n = recompute_masked(&mut c, &a, &b, &mask).unwrap();
        assert_eq!(
            super::alloc_counter::allocation_count(),
            before,
            "recompute_masked must not allocate on the repair path"
        );
        assert_eq!(n, 18);
        for i in 0..6 {
            for j in 0..6 {
                if mask[i * 6 + j] {
                    assert_eq!(c.get(i, j), cf.get(i, j));
                }
            }
        }
    }

    #[test]
    fn fast_matmul_matches_reference_within_tolerance() {
        let mut rng = Rng::new(7);
        let x = Matrix::randn(9, 33, 1.0, &mut rng);
        let w = Matrix::randn(33, 17, 1.0, &mut rng);
        let bias: Vec<f32> = (0..17).map(|_| rng.normal_f32()).collect();
        let fast = matmul_bias_fast(&x, &w, &bias).unwrap();
        let mut slow = matmul_f32(&x, &w).unwrap();
        for i in 0..9 {
            for j in 0..17 {
                slow.set(i, j, slow.get(i, j) + bias[j]);
            }
        }
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
        // No bias variant.
        let fast0 = matmul_bias_fast(&x, &w, &[]).unwrap();
        let slow0 = matmul_f32(&x, &w).unwrap();
        assert!(fast0.max_abs_diff(&slow0).unwrap() < 1e-4);
    }

    #[test]
    fn transposed_fast_matches_reference() {
        let mut rng = Rng::new(8);
        let x = Matrix::randn(5, 29, 1.0, &mut rng);
        let w = Matrix::randn(13, 29, 1.0, &mut rng); // [n, k]
        let fast = matmul_transposed_fast(&x, &w).unwrap();
        let slow = matmul_f32(&x, &w.transpose()).unwrap();
        assert!(fast.max_abs_diff(&slow).unwrap() < 1e-4);
    }

    #[test]
    fn fast_matmul_shape_checks() {
        let x = Matrix::zeros(2, 3);
        let w = Matrix::zeros(4, 2);
        assert!(matmul_bias_fast(&x, &w, &[]).is_err());
        assert!(matmul_bias_fast(&x, &Matrix::zeros(3, 4), &[0.0; 3]).is_err());
        assert!(matmul_transposed_fast(&x, &Matrix::zeros(4, 5)).is_err());
    }

    #[test]
    fn matvec_ps_matches_per_column_dot_ps_bitwise() {
        // The PS row-matvec's contract: each output column equals dot_ps
        // over the explicit (strided) column, bit for bit, for every μ.
        let mut rng = Rng::new(9);
        for _ in 0..30 {
            let k = rng.range(1, 24);
            let n = rng.range(1, 17);
            let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            let w = Matrix::randn(k, n, 1.0, &mut rng);
            let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for mu in [1u32, 4, 11, 23] {
                let mut out = vec![0.0f32; n];
                matvec_ps_bias_into(&x, &w, &bias, mu, &mut out);
                for j in 0..n {
                    let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
                    let want = dot_ps(&x, &col, mu) + bias[j];
                    assert_eq!(out[j].to_bits(), want.to_bits(), "j={j} mu={mu}");
                }
                // No-bias variant.
                let mut out0 = vec![0.0f32; n];
                matvec_ps_bias_into(&x, &w, &[], mu, &mut out0);
                for j in 0..n {
                    let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
                    assert_eq!(out0[j].to_bits(), dot_ps(&x, &col, mu).to_bits());
                }
            }
        }
    }

    #[test]
    fn matvec_col_f32_matches_sequential_fma() {
        let mut rng = Rng::new(10);
        let k = 19;
        let n = 7;
        let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
        let w = Matrix::randn(k, n, 1.0, &mut rng);
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        for j in 0..n {
            let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
            let want = dot_f32(&x, &col) + bias[j];
            assert_eq!(matvec_col_f32(&x, &w, &bias, j).to_bits(), want.to_bits());
            assert_eq!(
                matvec_col_f32(&x, &w, &[], j).to_bits(),
                dot_f32(&x, &col).to_bits()
            );
        }
    }

    #[test]
    fn matvec_ps_mu23_is_fma_chain_not_vectorized_path() {
        // μ=23 PS accumulation equals the sequential FMA chain (dot_f32),
        // which is deliberately NOT the vectorized matvec_bias_into order —
        // the reference short-circuit, not μ=23, reproduces the fast path.
        let mut rng = Rng::new(11);
        let k = 33;
        let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
        let w = Matrix::randn(k, 5, 1.0, &mut rng);
        let mut ps = vec![0.0f32; 5];
        matvec_ps_bias_into(&x, &w, &[], 23, &mut ps);
        for j in 0..5 {
            let col: Vec<f32> = (0..k).map(|p| w.get(p, j)).collect();
            assert_eq!(ps[j].to_bits(), dot_f32(&x, &col).to_bits());
        }
    }

    #[test]
    fn recompute_mask_len_checked() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 2);
        let mut c = Matrix::zeros(2, 2);
        assert!(recompute_masked(&mut c, &a, &b, &[true; 3]).is_err());
    }

    use super::super::tensor::WeightFormat;

    fn storage_formats() -> [WeightFormat; 3] {
        [
            WeightFormat::F32,
            WeightFormat::Bf16,
            WeightFormat::PsRounded { mu: 6 },
        ]
    }

    #[test]
    fn fused_dequant_kernels_match_dequantize_then_f32_bitwise() {
        // The fused-dequant contract: for every storage format, every `_wt`
        // kernel is bit-identical to dequantizing the weights into an f32
        // Matrix first and calling the corresponding f32 kernel.
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let k = rng.range(1, 24);
            let n = rng.range(1, 17);
            let x: Vec<f32> = (0..k).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            let wm = Matrix::randn(k, n, 1.0, &mut rng);
            let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            for fmt in storage_formats() {
                let wt = super::super::tensor::WeightTensor::from_matrix(&wm, fmt).unwrap();
                let deq = wt.to_matrix();
                // FP32 matvec.
                let mut fused = vec![0.0f32; n];
                let mut plain = vec![0.0f32; n];
                matvec_bias_into_wt(&x, &wt, &bias, &mut fused);
                matvec_bias_into(&x, &deq, &bias, &mut plain);
                for j in 0..n {
                    assert_eq!(fused[j].to_bits(), plain[j].to_bits(), "{fmt:?} matvec j={j}");
                }
                // PS(μ) matvec.
                for mu in [2u32, 7, 23] {
                    matvec_ps_bias_into_wt(&x, &wt, &bias, mu, &mut fused);
                    matvec_ps_bias_into(&x, &deq, &bias, mu, &mut plain);
                    for j in 0..n {
                        assert_eq!(
                            fused[j].to_bits(),
                            plain[j].to_bits(),
                            "{fmt:?} ps matvec mu={mu} j={j}"
                        );
                    }
                }
                // FP32 column repair.
                for j in 0..n {
                    assert_eq!(
                        matvec_col_f32_wt(&x, &wt, &bias, j).to_bits(),
                        matvec_col_f32(&x, &deq, &bias, j).to_bits(),
                        "{fmt:?} col j={j}"
                    );
                    assert_eq!(
                        matvec_col_f32_wt(&x, &wt, &[], j).to_bits(),
                        matvec_col_f32(&x, &deq, &[], j).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn fused_row_dots_match_dequantized_bitwise() {
        // The [vocab, d]-layout kernels of the sampler site / unembedding.
        let mut rng = Rng::new(22);
        for _ in 0..20 {
            let d = rng.range(1, 40);
            let v = rng.range(1, 12);
            let x: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            let wm = Matrix::randn(v, d, 1.0, &mut rng);
            for fmt in storage_formats() {
                let wt = super::super::tensor::WeightTensor::from_matrix(&wm, fmt).unwrap();
                let deq = wt.to_matrix();
                for r in 0..v {
                    assert_eq!(
                        wt_row_dot_block(&x, &wt, r).to_bits(),
                        dot_block(&x, deq.row(r)).to_bits(),
                        "{fmt:?} block r={r}"
                    );
                    assert_eq!(
                        wt_row_dot_f32(&x, &wt, r).to_bits(),
                        dot_f32(&x, deq.row(r)).to_bits(),
                        "{fmt:?} f32 r={r}"
                    );
                    for mu in [2u32, 11, 23] {
                        assert_eq!(
                            wt_row_dot_ps(&x, &wt, r, mu).to_bits(),
                            dot_ps(&x, deq.row(r), mu).to_bits(),
                            "{fmt:?} ps r={r} mu={mu}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batched_wt_matmuls_match_dequantized_and_shape_check() {
        let mut rng = Rng::new(23);
        let x = Matrix::randn(5, 19, 1.0, &mut rng);
        let wm = Matrix::randn(19, 9, 1.0, &mut rng);
        let bias: Vec<f32> = (0..9).map(|_| rng.normal_f32()).collect();
        let un = Matrix::randn(13, 19, 1.0, &mut rng); // [n, k] unembedding
        for fmt in storage_formats() {
            let wt = super::super::tensor::WeightTensor::from_matrix(&wm, fmt).unwrap();
            let fused = matmul_bias_fast_wt(&x, &wt, &bias).unwrap();
            let plain = matmul_bias_fast(&x, &wt.to_matrix(), &bias).unwrap();
            assert_eq!(fused, plain, "{fmt:?} batched matmul");
            let ut = super::super::tensor::WeightTensor::from_matrix(&un, fmt).unwrap();
            let fused_t = matmul_transposed_fast_wt(&x, &ut).unwrap();
            let plain_t = matmul_transposed_fast(&x, &ut.to_matrix()).unwrap();
            assert_eq!(fused_t, plain_t, "{fmt:?} transposed matmul");
        }
        let bad = super::super::tensor::WeightTensor::from_matrix(
            &Matrix::zeros(4, 2),
            WeightFormat::Bf16,
        )
        .unwrap();
        assert!(matmul_bias_fast_wt(&x, &bad, &[]).is_err());
        let good =
            super::super::tensor::WeightTensor::from_matrix(&wm, WeightFormat::F32).unwrap();
        assert!(matmul_bias_fast_wt(&x, &good, &[0.0; 3]).is_err());
        assert!(matmul_transposed_fast_wt(&x, &bad).is_err());
    }
}
