//! Row-major dense matrix with shape checking, and the mixed-precision
//! [`WeightTensor`] weight store (f32 / bf16 / PS(μ)-rounded storage with
//! exact-f32 dequantization).

use crate::error::{Error, Result};
use crate::softfloat::round::round_to_mantissa;
use crate::util::Rng;
use std::fmt;

/// A dense row-major f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Default for Matrix {
    /// An empty 0×0 matrix — the scratch-buffer starting point.
    fn default() -> Self {
        Matrix::zeros(0, 0)
    }
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Matrix::from_vec: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Random N(0, scale²) entries.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32() * scale).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Flat mutable data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place to `rows × cols`, reusing the existing buffer.
    ///
    /// Grows the backing `Vec` only when the new element count exceeds its
    /// capacity — the scratch-reuse primitive of the zero-realloc engine
    /// (`ForwardScratch`, `DecodeSession`): once a scratch matrix has seen
    /// its largest shape, later resizes are free. Newly exposed elements
    /// are zero; retained elements keep their (stale) values, so callers
    /// must fully overwrite the matrix before reading it.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        for x in &mut self.data {
            *x = v;
        }
    }

    /// Copy `other` into `self`, resizing to match. No allocation once
    /// capacity suffices.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.resize(other.data.len(), 0.0);
        self.data.copy_from_slice(&other.data);
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Sub-view copy of rows [r0, r1).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<Matrix> {
        if r0 > r1 || r1 > self.rows {
            return Err(Error::shape(format!(
                "slice_rows: [{r0},{r1}) out of 0..{}",
                self.rows
            )));
        }
        Ok(Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        })
    }

    /// Max |a - b| over all entries; error on shape mismatch.
    pub fn max_abs_diff(&self, other: &Matrix) -> Result<f32> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "max_abs_diff: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

/// Convert an f32 to bf16 bits with round-to-nearest-ties-to-even — the
/// top 16 bits of the f32 pattern after RNE on the discarded low half.
/// NaNs are quieted so the round trip stays a NaN.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// Widen bf16 bits to the f32 they exactly represent (every bf16 value is
/// an exact f32 — dequantization introduces no error).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Storage format of a [`WeightTensor`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFormat {
    /// Full-precision f32 — 4 bytes/element, bit-identical to the
    /// historical `Vec<f32>` storage.
    F32,
    /// bfloat16 (8 exponent bits, 7 mantissa bits) — 2 bytes/element,
    /// halving resident parameter bytes and decode weight traffic.
    Bf16,
    /// f32 values pre-rounded to μ mantissa bits (the paper's PS(μ)
    /// format as a *storage* simulation) — still 4 bytes/element
    /// resident, used to study storage-induced error, not memory wins.
    PsRounded { mu: u32 },
}

impl WeightFormat {
    /// Parse a CLI-facing name: `f32`, `bf16`, or `ps<mu>` (e.g. `ps8`).
    pub fn by_name(name: &str) -> Result<Self> {
        let fmt = match name {
            "f32" => WeightFormat::F32,
            "bf16" => WeightFormat::Bf16,
            _ => match name.strip_prefix("ps").and_then(|m| m.parse::<u32>().ok()) {
                Some(mu) => WeightFormat::PsRounded { mu },
                None => {
                    return Err(Error::config(format!(
                        "unknown weight format {name:?} (f32|bf16|ps<mu>)"
                    )))
                }
            },
        };
        fmt.validate()?;
        Ok(fmt)
    }

    /// Canonical name (the inverse of [`Self::by_name`]); used as the
    /// serving-metrics key for per-format attribution.
    pub fn label(&self) -> String {
        match self {
            WeightFormat::F32 => "f32".to_string(),
            WeightFormat::Bf16 => "bf16".to_string(),
            WeightFormat::PsRounded { mu } => format!("ps{mu}"),
        }
    }

    /// Range-check the format (μ ∈ 1..=23 for PS storage).
    pub fn validate(&self) -> Result<()> {
        if let WeightFormat::PsRounded { mu } = self {
            if !(1..=23).contains(mu) {
                return Err(Error::config(format!(
                    "weight format ps{mu}: mu out of 1..=23"
                )));
            }
        }
        Ok(())
    }

    /// Resident bytes per stored element.
    pub fn bytes_per_element(&self) -> usize {
        match self {
            WeightFormat::Bf16 => 2,
            WeightFormat::F32 | WeightFormat::PsRounded { .. } => 4,
        }
    }
}

/// The enum backing a [`WeightTensor`]: one flat row-major payload per
/// storage format.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightStore {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    PsRounded { mu: u32, data: Vec<f32> },
}

/// A shape-checked row-major 2-D weight store.
///
/// Unlike the activation [`Matrix`] (always f32, mutable, resizable), a
/// `WeightTensor` is an immutable parameter payload in one of the
/// [`WeightFormat`]s. Every stored value — bf16 or PS(μ)-rounded — is an
/// *exact* f32, so dequantization is error-free and everything downstream
/// (LAMP selection, FP32 column repair, KV-cache decode parity) operates
/// on exact f32 values regardless of storage: quantization error enters
/// once, at [`Self::quantize_to`], never per-read.
#[derive(Clone, PartialEq)]
pub struct WeightTensor {
    rows: usize,
    cols: usize,
    store: WeightStore,
}

impl WeightTensor {
    /// f32 storage from a flat row-major buffer.
    pub fn from_f32(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "WeightTensor::from_f32: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(WeightTensor { rows, cols, store: WeightStore::F32(data) })
    }

    /// bf16 storage from raw bf16 bit patterns (the tensor-file loader).
    pub fn from_bf16(rows: usize, cols: usize, data: Vec<u16>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "WeightTensor::from_bf16: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(WeightTensor { rows, cols, store: WeightStore::Bf16(data) })
    }

    /// PS(μ)-rounded storage. The payload is re-rounded on construction
    /// (idempotent for data that is already μ-rounded), so a loaded tensor
    /// can never carry more precision than its declared format.
    pub fn from_ps(rows: usize, cols: usize, mu: u32, mut data: Vec<f32>) -> Result<Self> {
        WeightFormat::PsRounded { mu }.validate()?;
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "WeightTensor::from_ps: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        for v in &mut data {
            *v = round_to_mantissa(*v, mu);
        }
        Ok(WeightTensor { rows, cols, store: WeightStore::PsRounded { mu, data } })
    }

    /// Quantize an f32 matrix into the given storage format.
    pub fn from_matrix(m: &Matrix, fmt: WeightFormat) -> Result<Self> {
        fmt.validate()?;
        let (rows, cols) = m.shape();
        Ok(match fmt {
            WeightFormat::F32 => {
                WeightTensor { rows, cols, store: WeightStore::F32(m.data().to_vec()) }
            }
            WeightFormat::Bf16 => WeightTensor {
                rows,
                cols,
                store: WeightStore::Bf16(m.data().iter().map(|&x| f32_to_bf16(x)).collect()),
            },
            WeightFormat::PsRounded { mu } => WeightTensor {
                rows,
                cols,
                store: WeightStore::PsRounded {
                    mu,
                    data: m.data().iter().map(|&x| round_to_mantissa(x, mu)).collect(),
                },
            },
        })
    }

    /// Re-store under another format: dequantize (exact), then quantize.
    /// `quantize_to(fmt)` twice equals once — RNE rounding is idempotent
    /// on already-representable values — and `quantize_to(F32)` is the
    /// exact dequantization (every stored value is an exact f32).
    /// Same-format conversion is a plain clone (no dequant/requant pass):
    /// legal because quantization is idempotent, so the re-round could
    /// never change anything — this keeps the default `--weights-fmt f32`
    /// path from paying two extra full-parameter copies.
    pub fn quantize_to(&self, fmt: WeightFormat) -> Result<Self> {
        if fmt == self.format() {
            return Ok(self.clone());
        }
        Self::from_matrix(&self.to_matrix(), fmt)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The backing store (the fused matmul kernels dispatch on it).
    #[inline]
    pub fn store(&self) -> &WeightStore {
        &self.store
    }

    /// Storage format of this tensor.
    pub fn format(&self) -> WeightFormat {
        match &self.store {
            WeightStore::F32(_) => WeightFormat::F32,
            WeightStore::Bf16(_) => WeightFormat::Bf16,
            WeightStore::PsRounded { mu, .. } => WeightFormat::PsRounded { mu: *mu },
        }
    }

    /// Resident payload bytes (what the decode path actually streams).
    pub fn resident_bytes(&self) -> usize {
        self.len() * self.format().bytes_per_element()
    }

    /// Dequantized value at (r, c).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        let i = r * self.cols + c;
        match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => d[i],
            WeightStore::Bf16(d) => bf16_to_f32(d[i]),
        }
    }

    /// The flat f32 payload when storage is already f32-backed (F32 and
    /// PsRounded formats); `None` for bf16.
    #[inline]
    pub fn flat_f32(&self) -> Option<&[f32]> {
        match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => Some(d),
            WeightStore::Bf16(_) => None,
        }
    }

    /// Row `r` as a borrowed f32 slice when storage is f32-backed.
    #[inline]
    pub fn row_slice(&self, r: usize) -> Option<&[f32]> {
        self.flat_f32().map(|d| &d[r * self.cols..(r + 1) * self.cols])
    }

    /// Row `r` dequantized: returns the storage slice directly when it is
    /// f32-backed, otherwise dequantizes into `scratch` (resized, reused).
    pub fn row_dequant<'a>(&'a self, r: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match self.row_slice(r) {
            Some(s) => s,
            None => {
                scratch.clear();
                scratch.extend(self.iter_row(r));
                &scratch[..]
            }
        }
    }

    /// Dequantizing iterator over row `r`.
    pub fn iter_row(&self, r: usize) -> impl Iterator<Item = f32> + '_ {
        debug_assert!(r < self.rows);
        let lo = r * self.cols;
        let hi = lo + self.cols;
        (lo..hi).map(move |i| match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => d[i],
            WeightStore::Bf16(d) => bf16_to_f32(d[i]),
        })
    }

    /// `out = row r` (dequantized). `out.len()` must equal `cols`.
    #[inline]
    pub fn copy_row_into(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
                out.copy_from_slice(&d[r * self.cols..(r + 1) * self.cols]);
            }
            WeightStore::Bf16(d) => {
                for (o, &b) in out.iter_mut().zip(&d[r * self.cols..(r + 1) * self.cols]) {
                    *o = bf16_to_f32(b);
                }
            }
        }
    }

    /// `out += row r` (dequantized, one f32 add per element).
    #[inline]
    pub fn add_row_into(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => {
                for (o, &v) in out.iter_mut().zip(&d[r * self.cols..(r + 1) * self.cols]) {
                    *o += v;
                }
            }
            WeightStore::Bf16(d) => {
                for (o, &b) in out.iter_mut().zip(&d[r * self.cols..(r + 1) * self.cols]) {
                    *o += bf16_to_f32(b);
                }
            }
        }
    }

    /// Full dequantization into an activation [`Matrix`] (exact).
    pub fn to_matrix(&self) -> Matrix {
        let data: Vec<f32> = match &self.store {
            WeightStore::F32(d) | WeightStore::PsRounded { data: d, .. } => d.clone(),
            WeightStore::Bf16(d) => d.iter().map(|&b| bf16_to_f32(b)).collect(),
        };
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Dequantized flat row-major payload (exact).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.to_matrix().into_vec()
    }

    /// Max |a − b| over the dequantized values; error on shape mismatch.
    pub fn max_abs_diff(&self, other: &WeightTensor) -> Result<f32> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "WeightTensor::max_abs_diff: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        let mut m = 0.0f32;
        for r in 0..self.rows {
            for (a, b) in self.iter_row(r).zip(other.iter_row(r)) {
                m = m.max((a - b).abs());
            }
        }
        Ok(m)
    }
}

impl From<Matrix> for WeightTensor {
    /// Zero-copy f32 storage from an activation matrix.
    fn from(m: Matrix) -> Self {
        let (rows, cols) = m.shape();
        WeightTensor { rows, cols, store: WeightStore::F32(m.into_vec()) }
    }
}

impl fmt::Debug for WeightTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "WeightTensor({}x{}, {})",
            self.rows,
            self.cols,
            self.format().label()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked_from_vec() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 6]).is_ok());
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn get_set_row() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 7.0);
        assert_eq!(m.get(1, 2), 7.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(3, 2), m.get(2, 3));
    }

    #[test]
    fn slice_rows_bounds() {
        let m = Matrix::zeros(4, 2);
        assert!(m.slice_rows(1, 3).is_ok());
        assert!(m.slice_rows(3, 5).is_err());
        assert_eq!(m.slice_rows(1, 3).unwrap().shape(), (2, 2));
    }

    #[test]
    fn resize_reuses_capacity_and_copy_from_matches() {
        let mut m = Matrix::zeros(4, 8);
        let cap = m.data.capacity();
        m.resize(2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.data.capacity(), cap, "shrinking must not reallocate");
        m.resize(4, 8);
        assert_eq!(m.data.capacity(), cap, "regrowing within capacity must not reallocate");
        let mut rng = Rng::new(5);
        let src = Matrix::randn(3, 5, 1.0, &mut rng);
        m.copy_from(&src);
        assert_eq!(m, src);
        m.fill(2.5);
        assert!(m.data().iter().all(|&x| x == 2.5));
    }

    #[test]
    fn diff_and_norm() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(1, 2, vec![3.0, 5.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
        let c = Matrix::zeros(2, 1);
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn bf16_conversion_exact_roundtrip_and_rne() {
        // Every bf16 value widens to an exact f32 and narrows back to the
        // same bits (dequantization is error-free).
        for b in [0u16, 0x3F80, 0xBF80, 0x7F7F, 0x0001, 0x8000] {
            let x = bf16_to_f32(b);
            assert_eq!(f32_to_bf16(x), b, "bf16 {b:#06x} round trip");
        }
        // RNE on the discarded half: 1.0 + 2^-9 is exactly halfway between
        // two bf16 neighbours; ties go to the even mantissa (1.0).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(f32_to_bf16(halfway), 0x3F80);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(f32_to_bf16(above), 0x3F81);
        // NaN stays NaN.
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn weight_format_names_roundtrip_and_validate() {
        for fmt in [
            WeightFormat::F32,
            WeightFormat::Bf16,
            WeightFormat::PsRounded { mu: 8 },
        ] {
            assert_eq!(WeightFormat::by_name(&fmt.label()).unwrap(), fmt);
        }
        assert!(WeightFormat::by_name("fp8").is_err());
        assert!(WeightFormat::by_name("ps0").is_err());
        assert!(WeightFormat::by_name("ps24").is_err());
        assert_eq!(WeightFormat::Bf16.bytes_per_element(), 2);
        assert_eq!(WeightFormat::PsRounded { mu: 4 }.bytes_per_element(), 4);
    }

    #[test]
    fn weight_tensor_shape_checked_and_accessors() {
        assert!(WeightTensor::from_f32(2, 3, vec![0.0; 5]).is_err());
        assert!(WeightTensor::from_bf16(2, 3, vec![0; 7]).is_err());
        assert!(WeightTensor::from_ps(2, 3, 0, vec![0.0; 6]).is_err());
        let mut rng = Rng::new(9);
        let m = Matrix::randn(4, 6, 1.0, &mut rng);
        for fmt in [
            WeightFormat::F32,
            WeightFormat::Bf16,
            WeightFormat::PsRounded { mu: 5 },
        ] {
            let w = WeightTensor::from_matrix(&m, fmt).unwrap();
            assert_eq!(w.shape(), (4, 6));
            assert_eq!(w.format(), fmt);
            assert_eq!(w.resident_bytes(), 24 * fmt.bytes_per_element());
            // get / iter_row / copy_row_into / row_dequant all agree.
            let mut scratch = Vec::new();
            for r in 0..4 {
                let row: Vec<f32> = w.iter_row(r).collect();
                let mut buf = vec![0.0f32; 6];
                w.copy_row_into(r, &mut buf);
                assert_eq!(row, buf);
                assert_eq!(w.row_dequant(r, &mut scratch), &row[..]);
                for c in 0..6 {
                    assert_eq!(w.get(r, c).to_bits(), row[c].to_bits());
                }
            }
            // row_slice present exactly when storage is f32-backed.
            assert_eq!(w.row_slice(0).is_some(), fmt != WeightFormat::Bf16);
        }
    }

    #[test]
    fn quantize_is_idempotent_and_f32_is_exact() {
        let mut rng = Rng::new(11);
        let m = Matrix::randn(5, 7, 2.0, &mut rng);
        for fmt in [WeightFormat::Bf16, WeightFormat::PsRounded { mu: 6 }] {
            let once = WeightTensor::from_matrix(&m, fmt).unwrap();
            let twice = once.quantize_to(fmt).unwrap();
            assert_eq!(once, twice, "{fmt:?} requantization must be identity");
            // Round-tripping through F32 storage preserves every value
            // exactly (dequantization is exact).
            let via_f32 = once.quantize_to(WeightFormat::F32).unwrap();
            assert_eq!(via_f32.to_matrix(), once.to_matrix());
            assert_eq!(via_f32.quantize_to(fmt).unwrap(), once);
        }
        let f = WeightTensor::from_matrix(&m, WeightFormat::F32).unwrap();
        assert_eq!(f.to_matrix(), m, "F32 storage is the identity");
    }

    #[test]
    fn add_and_copy_row_match_manual_embedding_sum() {
        let mut rng = Rng::new(13);
        let te = Matrix::randn(3, 8, 1.0, &mut rng);
        let pe = Matrix::randn(3, 8, 1.0, &mut rng);
        for fmt in [WeightFormat::F32, WeightFormat::Bf16] {
            let wte = WeightTensor::from_matrix(&te, fmt).unwrap();
            let wpe = WeightTensor::from_matrix(&pe, fmt).unwrap();
            let mut out = vec![0.0f32; 8];
            wte.copy_row_into(1, &mut out);
            wpe.add_row_into(2, &mut out);
            for c in 0..8 {
                let want = wte.get(1, c) + wpe.get(2, c);
                assert_eq!(out[c].to_bits(), want.to_bits());
            }
        }
    }
}
