//! Row-major dense matrix with shape checking.

use crate::error::{Error, Result};
use crate::util::Rng;
use std::fmt;

/// A dense row-major f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Default for Matrix {
    /// An empty 0×0 matrix — the scratch-buffer starting point.
    fn default() -> Self {
        Matrix::zeros(0, 0)
    }
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Matrix::from_vec: {rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Random N(0, scale²) entries.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32() * scale).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Row `r` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Flat mutable data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place to `rows × cols`, reusing the existing buffer.
    ///
    /// Grows the backing `Vec` only when the new element count exceeds its
    /// capacity — the scratch-reuse primitive of the zero-realloc engine
    /// (`ForwardScratch`, `DecodeSession`): once a scratch matrix has seen
    /// its largest shape, later resizes are free. Newly exposed elements
    /// are zero; retained elements keep their (stale) values, so callers
    /// must fully overwrite the matrix before reading it.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        for x in &mut self.data {
            *x = v;
        }
    }

    /// Copy `other` into `self`, resizing to match. No allocation once
    /// capacity suffices.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.resize(other.data.len(), 0.0);
        self.data.copy_from_slice(&other.data);
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Sub-view copy of rows [r0, r1).
    pub fn slice_rows(&self, r0: usize, r1: usize) -> Result<Matrix> {
        if r0 > r1 || r1 > self.rows {
            return Err(Error::shape(format!(
                "slice_rows: [{r0},{r1}) out of 0..{}",
                self.rows
            )));
        }
        Ok(Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        })
    }

    /// Max |a - b| over all entries; error on shape mismatch.
    pub fn max_abs_diff(&self, other: &Matrix) -> Result<f32> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "max_abs_diff: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked_from_vec() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 6]).is_ok());
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn get_set_row() {
        let mut m = Matrix::zeros(3, 4);
        m.set(1, 2, 7.0);
        assert_eq!(m.get(1, 2), 7.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 7.0, 0.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(3, 2), m.get(2, 3));
    }

    #[test]
    fn slice_rows_bounds() {
        let m = Matrix::zeros(4, 2);
        assert!(m.slice_rows(1, 3).is_ok());
        assert!(m.slice_rows(3, 5).is_err());
        assert_eq!(m.slice_rows(1, 3).unwrap().shape(), (2, 2));
    }

    #[test]
    fn resize_reuses_capacity_and_copy_from_matches() {
        let mut m = Matrix::zeros(4, 8);
        let cap = m.data.capacity();
        m.resize(2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.data.capacity(), cap, "shrinking must not reallocate");
        m.resize(4, 8);
        assert_eq!(m.data.capacity(), cap, "regrowing within capacity must not reallocate");
        let mut rng = Rng::new(5);
        let src = Matrix::randn(3, 5, 1.0, &mut rng);
        m.copy_from(&src);
        assert_eq!(m, src);
        m.fill(2.5);
        assert!(m.data().iter().all(|&x| x == 2.5));
    }

    #[test]
    fn diff_and_norm() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(1, 2, vec![3.0, 5.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
        let c = Matrix::zeros(2, 1);
        assert!(a.max_abs_diff(&c).is_err());
    }
}
