//! A fixed-size work-stealing-free thread pool over `std::sync::mpsc`.
//!
//! Tokio is not available in the offline build, so the coordinator, the
//! experiment harness, and the native engine's attention tiles parallelize
//! over this pool. It supports fire-and-forget jobs, scoped parallel-map
//! (`map`), borrowing scoped index jobs (`scope_run` — the attention-tile
//! primitive), and clean shutdown on drop.
//!
//! Worker panics never poison the pool: both `map` and `scope_run` catch
//! them, drain every outstanding job, and then resurface the failure on
//! the caller's thread together with the failing job indices.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool. `Send + Sync`: submission is serialized behind a
/// mutex so one pool can be shared (e.g. inside an engine used from
/// several serving threads).
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    tx: Mutex<Sender<Message>>,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "ThreadPool::new(0)");
        let (tx, rx) = channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lamp-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx: Mutex::new(tx) }
    }

    /// A pool sized to the number of available CPUs (capped at `cap`).
    pub fn with_cpus(cap: usize) -> Self {
        let n = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(cap.max(1));
        Self::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .lock()
            .expect("pool sender lock")
            .send(Message::Run(Box::new(f)))
            .expect("pool closed");
    }

    /// Parallel map: apply `f` to each item, preserving order.
    ///
    /// Items and results cross thread boundaries, so everything must be
    /// `Send`; `f` is shared behind an `Arc`. A panicking `f` no longer
    /// kills the caller with a bare `RecvError`: panics are caught in the
    /// worker, every remaining job still runs, and the panic is re-raised
    /// here with the indices of the failing jobs.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                // Receiver may be gone if the caller is already unwinding.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panicked: Vec<usize> = Vec::new();
        let mut first_msg = String::new();
        for _ in 0..n {
            match rrx.recv() {
                Ok((i, Ok(r))) => slots[i] = Some(r),
                Ok((i, Err(payload))) => {
                    if panicked.is_empty() {
                        first_msg = panic_message(payload.as_ref()).to_string();
                    }
                    panicked.push(i);
                }
                // All senders gone: every job has reported already.
                Err(_) => break,
            }
        }
        if !panicked.is_empty() {
            panicked.sort_unstable();
            panic!("ThreadPool::map: job(s) {panicked:?} panicked: {first_msg}");
        }
        slots.into_iter().map(|s| s.expect("all slots filled")).collect()
    }

    /// Run `f(0)`, `f(1)`, ..., `f(jobs - 1)` on the pool and block until
    /// every job has finished. Unlike [`Self::map`], `f` may borrow from
    /// the caller's stack (no `'static` bound), which is what the
    /// attention kernel needs to share `&Matrix` inputs across tiles
    /// without cloning them.
    ///
    /// Worker panics are caught per job and re-raised here with the
    /// failing indices after all jobs have drained.
    pub fn scope_run<F>(&self, jobs: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if jobs == 0 {
            return;
        }
        // Erase the borrow lifetime so the closure reference can ride in a
        // 'static job. SAFETY: the receive loop below blocks until each of
        // the `jobs` submissions has sent exactly one completion message
        // (panics included, via catch_unwind), so `f` — and everything it
        // borrows — strictly outlives every dereference of this pointer.
        #[derive(Clone, Copy)]
        struct JobFn(*const (dyn Fn(usize) + Send + Sync + 'static));
        unsafe impl Send for JobFn {}
        let fref: &(dyn Fn(usize) + Send + Sync) = &f;
        let fptr: *const (dyn Fn(usize) + Send + Sync + 'static) =
            unsafe { std::mem::transmute(fref) };
        let jf = JobFn(fptr);

        let (rtx, rrx) = channel::<(usize, bool, String)>();
        for i in 0..jobs {
            let rtx = rtx.clone();
            self.execute(move || {
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    let run = unsafe { &*jf.0 };
                    run(i);
                }));
                let (ok, msg) = match outcome {
                    Ok(()) => (true, String::new()),
                    Err(p) => (false, panic_message(p.as_ref()).to_string()),
                };
                let _ = rtx.send((i, ok, msg));
            });
        }
        drop(rtx);
        let mut panicked: Vec<usize> = Vec::new();
        let mut first_msg = String::new();
        for _ in 0..jobs {
            match rrx.recv() {
                Ok((_, true, _)) => {}
                Ok((i, false, msg)) => {
                    if panicked.is_empty() {
                        first_msg = msg;
                    }
                    panicked.push(i);
                }
                Err(_) => break,
            }
        }
        if !panicked.is_empty() {
            panicked.sort_unstable();
            panic!("ThreadPool::scope_run: job(s) {panicked:?} panicked: {first_msg}");
        }
    }
}

/// Best-effort extraction of a human-readable message from a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "<non-string panic payload>"
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Message>>>) {
    loop {
        let msg = { rx.lock().expect("rx lock").recv() };
        match msg {
            Ok(Message::Run(job)) => job(),
            Ok(Message::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // Recover the sender even if a panicking submitter poisoned the
            // lock — otherwise the workers would never see the shutdown.
            let tx = self.tx.lock().unwrap_or_else(|p| p.into_inner());
            for _ in &self.workers {
                let _ = tx.send(Message::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn with_cpus_capped() {
        let pool = ThreadPool::with_cpus(2);
        assert!(pool.size() <= 2 && pool.size() >= 1);
    }

    #[test]
    fn map_resurfaces_worker_panic_with_index() {
        let pool = ThreadPool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..8).collect::<Vec<i32>>(), |x| {
                if x == 5 {
                    panic!("boom on five");
                }
                x
            })
        }))
        .expect_err("map must propagate the worker panic");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("[5]"), "missing job index: {msg}");
        assert!(msg.contains("boom on five"), "missing payload: {msg}");
        // The pool survives a panicked batch.
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn scope_run_borrows_and_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let data: Vec<usize> = (0..97).collect();
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.scope_run(data.len(), |i| {
            hits[i].fetch_add(data[i] + 1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), i + 1, "job {i} ran wrong");
        }
    }

    #[test]
    fn scope_run_empty_is_noop() {
        let pool = ThreadPool::new(1);
        pool.scope_run(0, |_| panic!("must not run"));
    }

    #[test]
    fn scope_run_resurfaces_panics_after_draining() {
        let pool = ThreadPool::new(2);
        let done = AtomicUsize::new(0);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_run(16, |i| {
                if i % 8 == 3 {
                    panic!("tile {i} failed");
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }))
        .expect_err("scope_run must propagate");
        let msg = panic_message(err.as_ref());
        assert!(msg.contains("[3, 11]"), "bad indices: {msg}");
        // Every non-panicking job still ran before the re-raise.
        assert_eq!(done.load(Ordering::SeqCst), 14);
    }
}
