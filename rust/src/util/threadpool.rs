//! A fixed-size work-stealing-free thread pool over `std::sync::mpsc`.
//!
//! Tokio is not available in the offline build, so the coordinator and the
//! experiment harness parallelize over this pool. It supports fire-and-forget
//! jobs, scoped parallel-map (`map`), and clean shutdown on drop.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    workers: Vec<JoinHandle<()>>,
    tx: Sender<Message>,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "ThreadPool::new(0)");
        let (tx, rx) = channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lamp-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { workers, tx }
    }

    /// A pool sized to the number of available CPUs (capped at `cap`).
    pub fn with_cpus(cap: usize) -> Self {
        let n = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(cap.max(1));
        Self::new(n)
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Message::Run(Box::new(f))).expect("pool closed");
    }

    /// Parallel map: apply `f` to each item, preserving order.
    ///
    /// Items and results cross thread boundaries, so everything must be
    /// `Send`; `f` is shared behind an `Arc`.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                // Receiver may be gone if caller panicked; ignore.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("all slots filled")).collect()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Message>>>) {
    loop {
        let msg = { rx.lock().expect("rx lock").recv() };
        match msg {
            Ok(Message::Run(job)) => job(),
            Ok(Message::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn with_cpus_capped() {
        let pool = ThreadPool::with_cpus(2);
        assert!(pool.size() <= 2 && pool.size() >= 1);
    }
}
