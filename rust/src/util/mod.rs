//! Small infrastructure substrates: PRNG, timing, logging, thread pool.
//!
//! No external crates beyond the bundled `xla` stub are available in the
//! offline build environment, so these are hand-rolled but fully tested.

pub mod logging;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use threadpool::ThreadPool;
pub use timer::Stopwatch;
