//! Deterministic pseudo-random number generation.
//!
//! A splitmix64-seeded xoshiro256** generator: fast, high-quality, and —
//! crucially for reproducibility of the experiment harness — fully
//! deterministic across platforms. The same seeds are used by the Python
//! compile path (see `python/compile/train.py`) only for data generation at
//! build time; the two sides never need to agree on streams.

/// xoshiro256** PRNG with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled sub-task.
    ///
    /// Used to give each worker / layer / head its own reproducible stream.
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    ///
    /// Uses Lemire's multiply-shift rejection method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm, then
    /// shuffled so order is also random). Panics if k > n.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        // Floyd's algorithm: O(k) expected when using a set.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }

    /// Sample an index from an (unnormalized) discrete weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: non-positive total weight");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let n = r.range(1, 50);
            let k = r.range(0, n + 1);
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..60_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
