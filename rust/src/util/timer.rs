//! Wall-clock timing helpers used by the benchmark harness and the serving
//! metrics.

use std::time::{Duration, Instant};

/// A simple stopwatch with lap support.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now(), laps: Vec::new() }
    }

    /// Elapsed time since construction or last `reset`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Record a named lap at the current elapsed time.
    pub fn lap(&mut self, name: impl Into<String>) {
        self.laps.push((name.into(), self.elapsed()));
    }

    /// Recorded laps, in order.
    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
        self.laps.clear();
    }
}

/// Format a duration compactly for human-readable reports (`1.23ms`, `4.5s`).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 2);
        assert!(sw.laps()[1].1 >= sw.laps()[0].1);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("us"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn reset_clears() {
        let mut sw = Stopwatch::new();
        sw.lap("x");
        sw.reset();
        assert!(sw.laps().is_empty());
    }
}
