//! Minimal leveled logger writing to stderr.
//!
//! The level is controlled by `LAMP_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Thread-safe; no external crates.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn level_from_env() -> u8 {
    match std::env::var("LAMP_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => 0,
        "warn" => 1,
        "debug" => 3,
        "trace" => 4,
        _ => 2, // info default
    }
}

/// Current effective level.
pub fn level() -> Level {
    let mut l = LEVEL.load(Ordering::Relaxed);
    if l == 255 {
        l = level_from_env();
        LEVEL.store(l, Ordering::Relaxed);
    }
    match l {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the log level programmatically.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Returns true if `l` is enabled at the current level.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Core log entry point; prefer the macros.
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
