//! Componentwise LAMP for RMS layer normalization (paper §3.2).
//!
//! f(y) = √n · y / ‖y‖₂. Proposition 3.1 gives the exact condition value
//! for any selection support Ω:
//!
//! ```text
//!   κ_c = 2(1 − min_{j∉Ω} y_j²/‖y‖²) − Σ_{i∈Ω} y_i²/‖y‖²     (|Ω| ≤ n−2)
//!   κ_c = max{ y_j²/‖y‖², 1 − y_j²/‖y‖² }                     (Ω^c = {j})
//! ```
//!
//! Proposition 3.2 shows a greedy sorted-prefix solution is within one index
//! of optimal: sort by y_i² descending and take the smallest prefix s with
//! `Σ_{i≤s} y_i² + 2 y_min² ≥ (2 − τ)‖y‖²`.

/// RMS layer normalization: √n · y / ‖y‖₂ (returns y when ‖y‖ = 0).
pub fn rmsnorm(y: &[f32]) -> Vec<f32> {
    let n = y.len();
    let norm = (y.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt();
    if norm == 0.0 {
        return y.to_vec();
    }
    let scale = (n as f64).sqrt() / norm;
    y.iter().map(|&x| (x as f64 * scale) as f32).collect()
}

/// Exact κ_c(f, y; q) for RMS norm per Proposition 3.1.
///
/// `mask[i] == true` means i ∈ Ω (selected for accurate recomputation).
/// Precondition: mask ≠ all-true (Prop 3.1 requires q ≠ 1); returns 0.0 in
/// that degenerate case (everything recomputed accurately).
pub fn kappa_c_rmsnorm(y: &[f32], mask: &[bool]) -> f64 {
    assert_eq!(y.len(), mask.len());
    let n = y.len();
    let norm2: f64 = y.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if norm2 == 0.0 {
        return 0.0;
    }
    let unselected: Vec<usize> = (0..n).filter(|&i| !mask[i]).collect();
    if unselected.is_empty() {
        return 0.0;
    }
    let sum_omega: f64 = (0..n)
        .filter(|&i| mask[i])
        .map(|i| (y[i] as f64) * (y[i] as f64))
        .sum();
    if unselected.len() == 1 {
        let j = unselected[0];
        let r = (y[j] as f64) * (y[j] as f64) / norm2;
        r.max(1.0 - r)
    } else {
        let min_unsel: f64 = unselected
            .iter()
            .map(|&j| (y[j] as f64) * (y[j] as f64))
            .fold(f64::INFINITY, f64::min);
        2.0 * (1.0 - min_unsel / norm2) - sum_omega / norm2
    }
}

/// Greedy closed-form LAMP solution for RMS norm (Prop 3.2).
///
/// Sorts indices by y_i² descending and returns the mask of the smallest
/// prefix s satisfying `Σ_{i≤s} y_i² + 2·y_min² ≥ (2 − τ)·‖y‖²`; the
/// all-but-one selection is used if no such prefix with |Ω| ≤ n−2 exists and
/// the single-left-out formula admits it, otherwise all-true.
pub fn select_rmsnorm(y: &[f32], tau: f64) -> Vec<bool> {
    let n = y.len();
    if n == 0 {
        return Vec::new();
    }
    let norm2: f64 = y.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let mut mask = vec![false; n];
    if norm2 == 0.0 {
        return mask; // exactly zero vector: output is y, perfectly stable
    }
    // Empty selection may already satisfy the constraint.
    if kappa_c_rmsnorm(y, &mask) <= tau {
        return mask;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let qa = (y[a] as f64) * (y[a] as f64);
        let qb = (y[b] as f64) * (y[b] as f64);
        qb.partial_cmp(&qa).unwrap()
    });
    let ymin2 = order
        .last()
        .map(|&i| (y[i] as f64) * (y[i] as f64))
        .unwrap();
    let target = (2.0 - tau) * norm2;
    let mut prefix = 0.0f64;
    for (s, &idx) in order.iter().enumerate() {
        // Prefixes up to n−2 are covered by the greedy criterion.
        if s + 1 <= n.saturating_sub(2) {
            prefix += (y[idx] as f64) * (y[idx] as f64);
            mask[idx] = true;
            if prefix + 2.0 * ymin2 >= target {
                return mask;
            }
        } else {
            break;
        }
    }
    // |Ω| = n−1: leave out only the smallest-square index.
    let mut mask = vec![true; n];
    let last = *order.last().unwrap();
    mask[last] = false;
    if kappa_c_rmsnorm(y, &mask) <= tau {
        return mask;
    }
    vec![true; n]
}

/// Brute-force optimal solution by exhaustive search (for tests; O(2ⁿ)).
pub fn select_rmsnorm_bruteforce(y: &[f32], tau: f64) -> Vec<bool> {
    let n = y.len();
    assert!(n <= 16, "brute force limited to n<=16");
    let mut best: Option<Vec<bool>> = None;
    for bits in 0..(1u32 << n) {
        let mask: Vec<bool> = (0..n).map(|i| bits >> i & 1 == 1).collect();
        if kappa_c_rmsnorm(y, &mask) <= tau {
            let count = mask.iter().filter(|&&b| b).count();
            if best
                .as_ref()
                .map(|b| count < b.iter().filter(|&&x| x).count())
                .unwrap_or(true)
            {
                best = Some(mask);
            }
        }
    }
    best.unwrap_or_else(|| vec![true; n])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn rmsnorm_unit_norm() {
        let y = [3.0f32, 4.0];
        let z = rmsnorm(&y);
        let norm: f64 = z.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - (2.0f64).sqrt()).abs() < 1e-6); // ‖f(y)‖ = √n
    }

    #[test]
    fn rmsnorm_zero_vector() {
        assert_eq!(rmsnorm(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn kappa_empty_selection_is_condition_number() {
        // q = 0 gives the componentwise condition number of f; for a
        // spread-out vector it approaches 2·(1 − 1/n) − 0 ≈ 2.
        let y = vec![1.0f32; 8];
        let mask = vec![false; 8];
        let k = kappa_c_rmsnorm(&y, &mask);
        assert!((k - 2.0 * (1.0 - 1.0 / 8.0)).abs() < 1e-9, "k={k}");
    }

    #[test]
    fn kappa_full_selection_is_zero() {
        let y = [1.0f32, 2.0, 3.0];
        assert_eq!(kappa_c_rmsnorm(&y, &[true, true, true]), 0.0);
    }

    #[test]
    fn greedy_satisfies_constraint() {
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let n = rng.range(1, 40);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = rng.f64() * 2.0;
            let mask = select_rmsnorm(&y, tau);
            assert!(
                kappa_c_rmsnorm(&y, &mask) <= tau + 1e-12,
                "constraint violated: n={n} tau={tau}"
            );
        }
    }

    #[test]
    fn greedy_within_one_of_bruteforce() {
        // Prop 3.2: ‖q'‖₀ ≤ ‖q*‖₀ + 1 (when the optimum has ≤ n−3 indices;
        // we assert the general ±1 bound on small random instances).
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let n = rng.range(2, 11);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 6.0).collect();
            let tau = 0.05 + rng.f64() * 1.5;
            let greedy = select_rmsnorm(&y, tau).iter().filter(|&&b| b).count();
            let optimal = select_rmsnorm_bruteforce(&y, tau)
                .iter()
                .filter(|&&b| b)
                .count();
            assert!(
                greedy <= optimal + 1,
                "greedy={greedy} optimal={optimal} y={y:?} tau={tau}"
            );
        }
    }

    #[test]
    fn massive_outlier_needs_one_recompute() {
        // Paper: "s = 1 when y₁² = 1 and y₂ = ... = yₙ = 0". The greedy
        // criterion Σ_{i≤s} y_i² + 2y_n² ≥ (2−τ)‖y‖² with prefix 1.0 needs
        // τ ≥ 1 — massive outliers admit tiny supports for moderate τ.
        // Use near-zeros to avoid the degenerate all-zero tail.
        let mut y = vec![1e-6f32; 16];
        y[7] = 1.0;
        let mask = select_rmsnorm(&y, 1.2);
        let count = mask.iter().filter(|&&b| b).count();
        assert!(count <= 2, "outlier vector should need ≤2: {count}");
        assert!(mask[7], "the outlier itself must be selected");
    }

    #[test]
    fn spread_vector_needs_many_recomputes() {
        // Paper: y₁²=...=y_{n−1}²=1, yₙ=0 ⇒ s = ⌈(2−τ)(n−1)⌉ — nearly all.
        let n = 20;
        let mut y = vec![1.0f32; n];
        y[n - 1] = 0.0;
        let mask = select_rmsnorm(&y, 0.5);
        let count = mask.iter().filter(|&&b| b).count();
        assert!(count >= n - 3, "spread vector should need nearly all: {count}");
    }

    #[test]
    fn tau_ge_condition_number_selects_nothing() {
        let y = [1.0f32, 2.0, -1.5, 0.25];
        let mask = select_rmsnorm(&y, 2.0);
        assert!(mask.iter().all(|&b| !b));
    }

    #[test]
    fn empty_and_single() {
        assert!(select_rmsnorm(&[], 0.1).is_empty());
        let m = select_rmsnorm(&[2.0], 0.1);
        // n=1: f(y) = √1·y/|y| = ±1, stable; κ_c with Ω=∅ is the n−1 = 0
        // unselected-singleton formula: max{1, 0} = 1 > 0.1 → selected.
        assert_eq!(m.len(), 1);
    }
}
