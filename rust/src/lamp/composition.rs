//! Algorithm 1 — LAMP evaluation of a composition f(g(x)) (paper §2.3).
//!
//! 1. Compute ŷ ≈ g(x) in low-precision FP arithmetic.
//! 2. Set up κ from the computed ŷ (Jacobian assumed stable to small input
//!    variations — paper footnote 4).
//! 3. Solve the LAMP problem ‖q‖₀ → min s.t. κ(q) ≤ τ.
//! 4. Recompute the components flagged by q more accurately.
//!
//! The generic solver here performs greedy column elimination on the
//! sensitivity aggregates — exact for diagonal/rank-one structures (the
//! transformer nonlinearities have closed forms in the sibling modules; this
//! generic path is for *arbitrary* f, the "extension to other architectures"
//! of §1.2).

use super::condition::{kappa_1, kappa_c, VectorFn};
use crate::error::{Error, Result};

/// Which objective the LAMP problem minimizes against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Componentwise, eq. (3).
    Componentwise,
    /// ℓ₁-normwise, eq. (4).
    NormwiseL1,
}

/// The result of LAMP-evaluating a composition.
#[derive(Debug, Clone)]
pub struct LampEvaluation {
    /// Final (mixed-precision) inner value ŷ after recomputation.
    pub y: Vec<f32>,
    /// Final outer value f(ŷ).
    pub z: Vec<f32>,
    /// Selection mask q.
    pub mask: Vec<bool>,
    /// κ(q) achieved after selection.
    pub kappa: f64,
    /// Number of recomputed components.
    pub recomputed: usize,
}

/// Generic greedy solver for the LAMP problem (5): repeatedly select the
/// unselected component with the largest sensitivity aggregate until
/// κ(q) ≤ τ.
///
/// The sensitivity aggregate of column j is its contribution to the active
/// norm (abs column sum for ℓ₁; max |entry| weight for ∞). For the paper's
/// transformer nonlinearities this greedy scheme recovers the closed-form
/// optimum; Appendix B shows it is *not* optimal for componentwise softmax
/// — which is exactly why the paper pivots to the ℓ₁ objective there.
pub fn solve_lamp_greedy(
    func: &VectorFn,
    y: &[f32],
    tau: f64,
    objective: Objective,
) -> Result<Vec<bool>> {
    let n = y.len();
    let mut mask = vec![false; n];
    let eval = |mask: &[bool]| match objective {
        Objective::Componentwise => kappa_c(func, y, mask),
        Objective::NormwiseL1 => kappa_1(func, y, mask),
    };
    let mut kappa = eval(&mask);
    let mut guard = 0;
    while kappa > tau {
        // Greedy: pick the unselected column whose removal reduces κ most.
        let mut best: Option<(usize, f64)> = None;
        for j in 0..n {
            if mask[j] {
                continue;
            }
            mask[j] = true;
            let k = eval(&mask);
            mask[j] = false;
            if best.map(|(_, bk)| k < bk).unwrap_or(true) {
                best = Some((j, k));
            }
        }
        match best {
            Some((j, k)) => {
                mask[j] = true;
                kappa = k;
            }
            None => break, // everything selected
        }
        guard += 1;
        if guard > n {
            return Err(Error::invariant(
                "LAMP greedy solver failed to converge".to_string(),
            ));
        }
    }
    Ok(mask)
}

/// Algorithm 1: LAMP evaluation of f(g(x)).
///
/// * `g_lowprec(x)` — the baseline low-precision evaluation of g.
/// * `g_accurate(x, j)` — accurate recomputation of component j of g(x).
/// * `f` — the ensuing operator with (optional) analytic Jacobian.
pub fn lamp_evaluate(
    x: &[f32],
    g_lowprec: impl Fn(&[f32]) -> Vec<f32>,
    g_accurate: impl Fn(&[f32], usize) -> f32,
    f: &VectorFn,
    tau: f64,
    objective: Objective,
) -> Result<LampEvaluation> {
    // Step 1: baseline inner evaluation.
    let mut y = g_lowprec(x);
    // Steps 2–3: fix κ at the baseline ŷ and solve for q.
    let mask = solve_lamp_greedy(f, &y, tau, objective)?;
    // Step 4: recompute flagged components more accurately.
    let mut recomputed = 0;
    for (j, &m) in mask.iter().enumerate() {
        if m {
            y[j] = g_accurate(x, j);
            recomputed += 1;
        }
    }
    let kappa = match objective {
        Objective::Componentwise => kappa_c(f, &y, &mask),
        Objective::NormwiseL1 => kappa_1(f, &y, &mask),
    };
    let z = f.eval(&y);
    Ok(LampEvaluation { y, z, mask, kappa, recomputed })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::{select_strict, softmax};
    use crate::linalg::Matrix;
    use crate::softfloat::dot::{dot_f32, dot_ps};
    use crate::util::Rng;

    fn softmax_fn<'a>() -> VectorFn<'a> {
        VectorFn::with_jacobian(
            |y| softmax(y),
            |y| {
                let z = softmax(y);
                let n = z.len();
                let mut j = Matrix::zeros(n, n);
                for i in 0..n {
                    for c in 0..n {
                        let d = if i == c { z[i] } else { 0.0 };
                        j.set(i, c, d - z[i] * z[c]);
                    }
                }
                j
            },
        )
    }

    #[test]
    fn greedy_l1_matches_strict_rule_for_softmax() {
        // For the ℓ₁ objective on softmax, greedy = exact thresholding
        // (Prop 3.3 makes κ₁ a max over unselected sensitivities).
        let mut rng = Rng::new(1);
        let f = softmax_fn();
        for _ in 0..50 {
            let n = rng.range(2, 10);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 8.0).collect();
            let tau = 0.02 + rng.f64() * 0.3;
            let greedy = solve_lamp_greedy(&f, &y, tau, Objective::NormwiseL1).unwrap();
            let strict = select_strict(&y, tau as f32);
            // Counts must match (exact minimizer); positions may differ only
            // on ties, which have measure ~0 for random y.
            assert_eq!(
                greedy.iter().filter(|&&b| b).count(),
                strict.iter().filter(|&&b| b).count(),
                "y={y:?} tau={tau}"
            );
        }
    }

    #[test]
    fn end_to_end_matvec_softmax() {
        // g(x) = A·x accumulated in PS(3); LAMP recomputes flagged rows in
        // FP32. The recomputed composition must be closer to the exact one.
        let mut rng = Rng::new(2);
        let n = 24;
        let k = 64;
        let a = Matrix::randn(n, k, 0.4, &mut rng);
        let x: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
        let f = softmax_fn();

        let a1 = a.clone();
        let a2 = a.clone();
        let result = lamp_evaluate(
            &x,
            move |xv| (0..n).map(|i| dot_ps(a1.row(i), xv, 3)).collect(),
            move |xv, j| dot_f32(a2.row(j), xv),
            &f,
            0.05,
            Objective::NormwiseL1,
        )
        .unwrap();

        // Exact reference.
        let y_exact: Vec<f32> = (0..n).map(|i| dot_f32(a.row(i), &x)).collect();
        let z_exact = softmax(&y_exact);
        let y_low: Vec<f32> = (0..n).map(|i| dot_ps(a.row(i), &x, 3)).collect();
        let z_low = softmax(&y_low);

        let l1 = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&p, &q)| (p - q).abs() as f64).sum()
        };
        let err_lamp = l1(&result.z, &z_exact);
        let err_low = l1(&z_low, &z_exact);
        assert!(result.kappa <= 0.05 + 1e-9);
        if result.recomputed > 0 {
            assert!(
                err_lamp <= err_low + 1e-9,
                "LAMP should not be worse: lamp={err_lamp} low={err_low}"
            );
        }
    }

    #[test]
    fn tau_zero_recomputes_all_sensitive() {
        let f = softmax_fn();
        let y = vec![2.0f32, 2.0, 2.0];
        let mask = solve_lamp_greedy(&f, &y, 0.0, Objective::NormwiseL1).unwrap();
        assert!(mask.iter().all(|&b| b));
    }

    #[test]
    fn componentwise_objective_also_converges() {
        let f = softmax_fn();
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let n = rng.range(2, 8);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 6.0).collect();
            let mask = solve_lamp_greedy(&f, &y, 0.1, Objective::Componentwise).unwrap();
            assert!(kappa_c(&f, &y, &mask) <= 0.1 + 1e-9);
        }
    }
}
