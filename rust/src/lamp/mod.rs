//! The LAMP (Look-Ahead Mixed-Precision) selection machinery — the paper's
//! primary contribution.
//!
//! Given the low-precision output ŷ of an inner function g and the ensuing
//! nonlinearity f, LAMP solves
//!
//! ```text
//!   ‖q‖₀ → min   s.t.   κ(f, ŷ; q) ≤ τ          (paper eq. 5)
//! ```
//!
//! for a sparse binary selection vector q, and recomputes the flagged
//! components of ŷ more accurately. The paper proves closed-form solutions
//! for the elementary transformer nonlinearities:
//!
//! * [`softmax`] — ℓ₁-normwise LAMP for softmax: strict rule (eq. 8),
//!   relaxed relative-threshold rule (eq. 9), length-normalized variant
//!   (App. C.5), and the random baseline (App. C.4).
//! * [`activation`] — componentwise LAMP for entrywise activations (§3.1):
//!   diagonal M, immediate thresholding.
//! * [`rmsnorm`] — componentwise LAMP for RMS layer normalization (§3.2):
//!   exact κ_c (Prop 3.1) and the greedy sorted-prefix solver (Prop 3.2).
//! * [`condition`] — the generic condition functionals κ_c (eq. 3) and
//!   κ_p (eq. 4) for arbitrary Jacobians, plus numeric Jacobians.
//! * [`composition`] — Algorithm 1: generic LAMP evaluation of f(g(x)).
//! * [`counterexamples`] — the Appendix-B families proving greedy
//!   heuristics fail for the componentwise softmax problem.

pub mod activation;
pub mod composition;
pub mod condition;
pub mod counterexamples;
pub mod rmsnorm;
pub mod softmax;

pub use softmax::{select_softmax, SoftmaxRule};
