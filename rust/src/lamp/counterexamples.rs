//! Appendix-B counterexample families: greedy heuristics fail for the
//! *componentwise* softmax LAMP problem.
//!
//! The explicit expression (App. B):
//!
//! ```text
//!   κ_c(f, y; q) = Σ_{j∉Ω} z_j|y_j| + max_{i∉Ω} (1 − 2 z_i)|y_i|
//! ```
//!
//! Proposition B.1 builds vectors where the optimal support is the most
//! *negative* entries (to kill the max-term), which a greedy pick of the
//! largest u_j = z_j|y_j| (or largest z_j) misses even with `s` extra picks.
//! Proposition B.2 builds vectors where the optimal support is the largest
//! entries (to kill the sum-term), which a greedy pick of the largest
//! v_i = (1−2z_i)|y_i| misses. These constructions motivate the paper's
//! pivot to the ℓ₁-normwise objective for softmax (§3.3).

use crate::lamp::softmax::softmax;

/// κ_c(f, y; q) for softmax, componentwise objective (App. B formula).
pub fn kappa_c_softmax(y: &[f32], mask: &[bool]) -> f64 {
    assert_eq!(y.len(), mask.len());
    let z = softmax(y);
    let sum: f64 = y
        .iter()
        .zip(&z)
        .zip(mask)
        .filter(|(_, &m)| !m)
        .map(|((&yj, &zj), _)| (zj * yj.abs()) as f64)
        .sum();
    let maxv: f64 = y
        .iter()
        .zip(&z)
        .zip(mask)
        .filter(|(_, &m)| !m)
        .map(|((&yi, &zi), _)| ((1.0 - 2.0 * zi) * yi.abs()) as f64)
        .fold(0.0, f64::max);
    sum + maxv
}

/// The auxiliary vectors u (u_j = z_j|y_j|) and v (v_i = (1−2z_i)|y_i|).
pub fn aux_vectors(y: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let z = softmax(y);
    let u = y.iter().zip(&z).map(|(&yj, &zj)| zj * yj.abs()).collect();
    let v = y
        .iter()
        .zip(&z)
        .map(|(&yi, &zi)| (1.0 - 2.0 * zi) * yi.abs())
        .collect();
    (u, v)
}

/// Greedy heuristic: select the k indices with the largest values of `score`.
pub fn greedy_topk(score: &[f32], k: usize) -> Vec<bool> {
    let mut order: Vec<usize> = (0..score.len()).collect();
    order.sort_by(|&a, &b| score[b].partial_cmp(&score[a]).unwrap());
    let mut mask = vec![false; score.len()];
    for &i in order.iter().take(k) {
        mask[i] = true;
    }
    mask
}

/// The instance of Proposition B.1: n = 2n₀ + s entries, n₀ at −α and
/// n₀ + s at −1. Returns (y, τ) such that:
/// * the optimal support is the n₀ indices at −α with κ_c = τ,
/// * any q with ‖q‖₀ < n₀ violates τ,
/// * the greedy top-(n₀+s) picks by u or z violate τ.
pub struct PropB1 {
    pub y: Vec<f32>,
    pub tau: f64,
    pub n0: usize,
    pub s: usize,
    pub alpha: f64,
}

impl PropB1 {
    pub fn new(n0: usize, s: usize, alpha: f64) -> Self {
        assert!(n0 >= 1 && s >= 1 && alpha >= 3.0);
        let n = 2 * n0 + s;
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            y.push(if i < n0 { -(alpha as f32) } else { -1.0f32 });
        }
        // τ = κ_c at the optimal support Ω = {1..n₀}.
        let opt: Vec<bool> = (0..n).map(|i| i < n0).collect();
        let tau = kappa_c_softmax(&y, &opt);
        PropB1 { y, tau, n0, s, alpha }
    }

    /// The optimal mask (first n₀ entries).
    pub fn optimal_mask(&self) -> Vec<bool> {
        (0..self.y.len()).map(|i| i < self.n0).collect()
    }

    /// The greedy mask by largest u (equivalently largest z here):
    /// the n₀ + s entries at −1.
    pub fn greedy_mask(&self) -> Vec<bool> {
        let (u, _) = aux_vectors(&self.y);
        greedy_topk(&u, self.n0 + self.s)
    }
}

/// The instance of Proposition B.2: n₀ entries at α + log((n₀+s)/n₀) and
/// n₀ + s entries at α, with α chosen so the greedy-by-v mask (the α group)
/// exceeds the τ achieved by the optimal mask (the larger group).
pub struct PropB2 {
    pub y: Vec<f32>,
    pub tau: f64,
    pub n0: usize,
    pub s: usize,
}

impl PropB2 {
    pub fn new(n0: usize, s: usize) -> Self {
        assert!(n0 >= 2 && s >= 1);
        let n = 2 * n0 + s;
        let ratio = ((n0 + s) as f64 / n0 as f64).ln();
        let alpha = ((n0 + s) as f64 * (5.0 * n0 as f64 - 4.0)) / (4.0 * s as f64) * ratio;
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            y.push(if i < n0 {
                (alpha + ratio) as f32
            } else {
                alpha as f32
            });
        }
        let opt: Vec<bool> = (0..n).map(|i| i < n0).collect();
        let tau = kappa_c_softmax(&y, &opt);
        PropB2 { y, tau, n0, s }
    }

    pub fn optimal_mask(&self) -> Vec<bool> {
        (0..self.y.len()).map(|i| i < self.n0).collect()
    }

    /// Greedy mask by largest v: the n₀ + s entries in the α group.
    pub fn greedy_mask(&self) -> Vec<bool> {
        let (_, v) = aux_vectors(&self.y);
        greedy_topk(&v, self.n0 + self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b1_optimal_satisfies_and_greedy_fails() {
        for (n0, s) in [(3usize, 2usize), (5, 3), (8, 1), (4, 8)] {
            let inst = PropB1::new(n0, s, 4.0);
            let opt = inst.optimal_mask();
            assert!(
                kappa_c_softmax(&inst.y, &opt) <= inst.tau + 1e-12,
                "optimal violates its own tau"
            );
            // Greedy picks n0+s indices — MORE than the optimum — yet fails.
            let greedy = inst.greedy_mask();
            assert_eq!(greedy.iter().filter(|&&b| b).count(), n0 + s);
            assert!(
                kappa_c_softmax(&inst.y, &greedy) > inst.tau,
                "greedy unexpectedly satisfied tau (n0={n0} s={s})"
            );
        }
    }

    #[test]
    fn b1_no_smaller_support_works() {
        // Any mask with fewer than n₀ selections leaves an −α entry
        // unselected and κ_c > 2 > τ.
        let inst = PropB1::new(4, 2, 4.0);
        assert!(inst.tau < 2.0);
        let n = inst.y.len();
        // Leave one of the first n₀ out, select everything else possible at
        // size n₀ − 1: still must fail. (Spot-check a few configurations.)
        for skip in 0..inst.n0 {
            let mut mask = vec![false; n];
            let mut cnt = 0;
            for i in 0..inst.n0 {
                if i != skip && cnt < inst.n0 - 1 {
                    mask[i] = true;
                    cnt += 1;
                }
            }
            assert!(kappa_c_softmax(&inst.y, &mask) > 2.0);
        }
    }

    #[test]
    fn b2_optimal_satisfies_and_greedy_fails() {
        for (n0, s) in [(3usize, 2usize), (4, 4), (6, 1)] {
            let inst = PropB2::new(n0, s);
            let opt = inst.optimal_mask();
            assert!(kappa_c_softmax(&inst.y, &opt) <= inst.tau + 1e-6);
            let greedy = inst.greedy_mask();
            assert_eq!(greedy.iter().filter(|&&b| b).count(), n0 + s);
            assert!(
                kappa_c_softmax(&inst.y, &greedy) > inst.tau,
                "greedy-by-v unexpectedly satisfied tau (n0={n0} s={s})"
            );
        }
    }

    #[test]
    fn b2_z_values_match_construction() {
        // z should be 1/(2n₀) for the first group, 1/(2(n₀+s)) for the rest.
        let inst = PropB2::new(4, 3);
        let z = softmax(&inst.y);
        for i in 0..4 {
            assert!((z[i] - 1.0 / 8.0).abs() < 1e-5, "z[{i}]={}", z[i]);
        }
        for i in 4..11 {
            assert!((z[i] - 1.0 / 14.0).abs() < 1e-5, "z[{i}]={}", z[i]);
        }
    }

    #[test]
    fn kappa_c_formula_consistency() {
        // The App. B formula must agree with the generic condition module.
        use crate::lamp::condition::{kappa_c, VectorFn};
        use crate::linalg::Matrix;
        use crate::util::Rng;
        let mut rng = Rng::new(1);
        let f = VectorFn::with_jacobian(
            |y| softmax(y),
            |y| {
                let z = softmax(y);
                let n = z.len();
                let mut j = Matrix::zeros(n, n);
                for i in 0..n {
                    for c in 0..n {
                        let d = if i == c { z[i] } else { 0.0 };
                        j.set(i, c, d - z[i] * z[c]);
                    }
                }
                j
            },
        );
        for _ in 0..50 {
            let n = rng.range(2, 10);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 6.0).collect();
            let mask: Vec<bool> = (0..n).map(|_| rng.f32() < 0.3).collect();
            let a = kappa_c_softmax(&y, &mask);
            let b = kappa_c(&f, &y, &mask);
            assert!((a - b).abs() < 1e-4 * (1.0 + a), "a={a} b={b}");
        }
    }
}
