//! Componentwise LAMP for entrywise activation functions (paper §3.1).
//!
//! For f(y) = [φ(y₁) … φ(yₙ)] the matrix M(f, y) is diagonal with entries
//! `φ′(y_i)·y_i / φ(y_i)`, so the componentwise LAMP problem (eq. 5) has the
//! immediate closed-form solution: select i iff `|M_ii| > τ`.
//!
//! Wired into serving through the [`PrecisionPlan`](crate::model::plan)'s
//! MLP site: `model::mlp` accumulates the fc matmul in PS(μ) and uses
//! [`select_activation_rule`] on the low-precision GELU pre-activations to
//! decide which fc inner products to recompute in FP32.

use super::softmax::{random_mask, SoftmaxRule};
use crate::util::Rng;

/// A differentiable scalar activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    ReLU,
    /// GPT-2's tanh-approximated GELU.
    Gelu,
    Tanh,
    Sigmoid,
    /// SiLU / swish: x·σ(x).
    Silu,
}

impl Activation {
    /// φ(x).
    pub fn apply(self, x: f32) -> f32 {
        match self {
            Activation::ReLU => x.max(0.0),
            Activation::Gelu => gelu(x),
            Activation::Tanh => x.tanh(),
            Activation::Sigmoid => sigmoid(x),
            Activation::Silu => x * sigmoid(x),
        }
    }

    /// φ′(x).
    pub fn derivative(self, x: f32) -> f32 {
        match self {
            Activation::ReLU => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Gelu => gelu_prime(x),
            Activation::Tanh => {
                let t = x.tanh();
                1.0 - t * t
            }
            Activation::Sigmoid => {
                let s = sigmoid(x);
                s * (1.0 - s)
            }
            Activation::Silu => {
                let s = sigmoid(x);
                s + x * s * (1.0 - s)
            }
        }
    }

    /// The diagonal entry of M(f, y): `φ′(y)·y / φ(y)`.
    ///
    /// Returns 0 where φ(y) = 0 and φ′(y)·y = 0 (e.g. ReLU for y < 0: the
    /// output is exactly 0 regardless of rounding in y, hence perfectly
    /// stable), and +∞ where φ(y) = 0 but the numerator is not (a genuine
    /// relative-error singularity, e.g. tanh at an exact zero crossing with
    /// y ≠ 0 — cannot happen for these φ).
    pub fn sensitivity(self, y: f32) -> f32 {
        let num = self.derivative(y) * y;
        let den = self.apply(y);
        if den == 0.0 {
            if num == 0.0 {
                0.0
            } else {
                f32::INFINITY
            }
        } else {
            (num / den).abs()
        }
    }
}

const SQRT_2_OVER_PI: f32 = 0.797_884_56;

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

fn gelu_prime(x: f32) -> f32 {
    let inner = SQRT_2_OVER_PI * (x + 0.044715 * x * x * x);
    let t = inner.tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * x * x)
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Closed-form componentwise LAMP solution for an entrywise activation
/// (§3.1): select i iff the diagonal sensitivity exceeds τ.
pub fn select_activation(y: &[f32], act: Activation, tau: f32) -> Vec<bool> {
    y.iter().map(|&yi| act.sensitivity(yi) > tau).collect()
}

/// Dispatch the activation site's selection rule (the plan's per-site
/// `rule`). The threshold rules coincide here — the componentwise problem
/// has the exact closed-form solution (thresholding the diagonal
/// sensitivity), so Strict/Relaxed/RelaxedLengthNorm all map to
/// [`select_activation`] — while `Random` is the count-matched random
/// baseline of App. C.4, drawing positions from the caller's
/// position-keyed stream.
pub fn select_activation_rule(
    y: &[f32],
    act: Activation,
    tau: f32,
    rule: SoftmaxRule,
    rng: &mut Rng,
) -> Vec<bool> {
    match rule {
        SoftmaxRule::Random => {
            // Count-match without materializing the threshold mask (this
            // runs per (layer, token) on the decode hot path).
            let count = y.iter().filter(|&&yi| act.sensitivity(yi) > tau).count();
            random_mask(y.len(), count, rng)
        }
        _ => select_activation(y, act, tau),
    }
}

/// κ_c for the entrywise activation under the selection `mask` — the max of
/// unselected diagonal sensitivities (the ∞-norm of M(I − diag q) for
/// diagonal M).
pub fn kappa_c_activation(y: &[f32], act: Activation, mask: &[bool]) -> f32 {
    assert_eq!(y.len(), mask.len());
    y.iter()
        .zip(mask)
        .filter(|(_, &m)| !m)
        .map(|(&yi, _)| act.sensitivity(yi))
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivatives_match_finite_differences() {
        let acts = [
            Activation::Gelu,
            Activation::Tanh,
            Activation::Sigmoid,
            Activation::Silu,
        ];
        for act in acts {
            for i in -20..=20 {
                let x = i as f32 * 0.3;
                let h = 1e-3f32;
                let fd = (act.apply(x + h) - act.apply(x - h)) / (2.0 * h);
                let an = act.derivative(x);
                assert!(
                    (fd - an).abs() < 5e-3,
                    "{act:?} at {x}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn relu_negative_is_perfectly_stable() {
        // φ(y)=0 and φ'(y)y=0: rounding y cannot change the output.
        assert_eq!(Activation::ReLU.sensitivity(-3.0), 0.0);
        // Positive side: φ(y)=y ⇒ sensitivity exactly 1.
        assert_eq!(Activation::ReLU.sensitivity(2.0), 1.0);
    }

    #[test]
    fn tanh_sensitivity_shape() {
        // x·(1−tanh²x)/tanh x → 1 as x→0, → 0 as |x|→∞.
        let near0 = Activation::Tanh.sensitivity(1e-3);
        assert!((near0 - 1.0).abs() < 1e-3, "{near0}");
        let far = Activation::Tanh.sensitivity(10.0);
        assert!(far < 1e-3, "{far}");
    }

    #[test]
    fn gelu_negative_tail_is_sensitive() {
        // For x → −∞, gelu(x) → 0 exponentially while x·φ′ does not vanish
        // as fast relative to φ: relative sensitivity blows up. (At x ≲ −5
        // f32 tanh saturates to exactly −1 and φ underflows to an exact 0,
        // which our convention treats as perfectly stable — so probe at −4.)
        let deep = Activation::Gelu.sensitivity(-4.0);
        let shallow = Activation::Gelu.sensitivity(-0.5);
        assert!(deep > shallow, "deep={deep} shallow={shallow}");
        assert!(deep > 10.0, "deep tail should be very sensitive: {deep}");
    }

    #[test]
    fn selection_is_thresholding() {
        let y = [-6.0f32, -0.5, 0.1, 2.0, 8.0];
        let tau = 1.5;
        let mask = select_activation(&y, Activation::Gelu, tau);
        for (i, &yi) in y.iter().enumerate() {
            assert_eq!(mask[i], Activation::Gelu.sensitivity(yi) > tau);
        }
    }

    #[test]
    fn kappa_bound_holds_after_selection() {
        use crate::util::Rng;
        let mut rng = Rng::new(1);
        for act in [Activation::Gelu, Activation::Tanh, Activation::Silu] {
            for _ in 0..200 {
                let n = rng.range(1, 64);
                let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 12.0).collect();
                let tau = rng.f32() * 2.0;
                let mask = select_activation(&y, act, tau);
                assert!(kappa_c_activation(&y, act, &mask) <= tau);
            }
        }
    }

    #[test]
    fn rule_dispatch_thresholds_and_random_count_matches() {
        let mut rng = Rng::new(2);
        let y: Vec<f32> = (0..48).map(|_| (rng.f32() - 0.5) * 12.0).collect();
        let tau = 0.8;
        let strict = select_activation(&y, Activation::Gelu, tau);
        for rule in [
            SoftmaxRule::Strict,
            SoftmaxRule::Relaxed,
            SoftmaxRule::RelaxedLengthNorm { ref_len: 64 },
        ] {
            let mut r = Rng::new(7);
            assert_eq!(
                select_activation_rule(&y, Activation::Gelu, tau, rule, &mut r),
                strict,
                "threshold rules share the closed-form solution"
            );
        }
        let want = strict.iter().filter(|&&b| b).count();
        let mut r1 = Rng::new(7);
        let m1 = select_activation_rule(&y, Activation::Gelu, tau, SoftmaxRule::Random, &mut r1);
        assert_eq!(m1.iter().filter(|&&b| b).count(), want);
        let mut r2 = Rng::new(7);
        let m2 = select_activation_rule(&y, Activation::Gelu, tau, SoftmaxRule::Random, &mut r2);
        assert_eq!(m1, m2, "same stream must reproduce exactly");
    }

    #[test]
    fn zero_input_zero_output_stable() {
        for act in [
            Activation::ReLU,
            Activation::Tanh,
            Activation::Gelu,
            Activation::Silu,
        ] {
            // num = φ'(0)·0 = 0 and φ(0) = 0 ⇒ defined as stable.
            assert_eq!(act.sensitivity(0.0), 0.0, "{act:?}");
        }
        // Sigmoid(0) = 0.5 ≠ 0: sensitivity is 0·φ'(0)/0.5 = 0 too.
        assert_eq!(Activation::Sigmoid.sensitivity(0.0), 0.0);
    }
}
