//! Generic LAMP condition functionals (paper §2.3).
//!
//! For f: ℝⁿ → ℝᵐ with Jacobian J_f(ŷ):
//!
//! ```text
//!   K(f, ŷ) = J_f(ŷ) · diag(ŷ)
//!   M(f, ŷ) = diag(f(ŷ))⁻¹ · K(f, ŷ)
//!   κ_c(q)  = ‖M (I − diag q)‖_{∞,∞}            (componentwise, eq. 3)
//!   κ_p(q)  = ‖K (I − diag q)‖_{p,p} / ‖f(ŷ)‖_p  (normwise, eq. 4)
//! ```
//!
//! These generic forms back [`super::composition`] (Algorithm 1 for
//! arbitrary f) and cross-check the closed-form specializations for
//! softmax / RMS norm / activations in tests.

use crate::linalg::Matrix;

/// A vector-valued function together with an (optionally analytic) Jacobian.
pub struct VectorFn<'a> {
    /// f itself.
    pub f: Box<dyn Fn(&[f32]) -> Vec<f32> + 'a>,
    /// Analytic Jacobian if available; otherwise a central finite difference
    /// is used.
    pub jacobian: Option<Box<dyn Fn(&[f32]) -> Matrix + 'a>>,
}

impl<'a> VectorFn<'a> {
    pub fn new(f: impl Fn(&[f32]) -> Vec<f32> + 'a) -> Self {
        VectorFn { f: Box::new(f), jacobian: None }
    }

    pub fn with_jacobian(
        f: impl Fn(&[f32]) -> Vec<f32> + 'a,
        j: impl Fn(&[f32]) -> Matrix + 'a,
    ) -> Self {
        VectorFn { f: Box::new(f), jacobian: Some(Box::new(j)) }
    }

    pub fn eval(&self, y: &[f32]) -> Vec<f32> {
        (self.f)(y)
    }

    /// Jacobian at `y` (analytic if provided, else central differences with
    /// per-coordinate step h·max(1, |y_i|)).
    pub fn jac(&self, y: &[f32]) -> Matrix {
        if let Some(j) = &self.jacobian {
            return j(y);
        }
        numeric_jacobian(&self.f, y, 1e-3)
    }
}

/// Central-difference Jacobian.
pub fn numeric_jacobian(f: &dyn Fn(&[f32]) -> Vec<f32>, y: &[f32], h_rel: f32) -> Matrix {
    let n = y.len();
    let fy = f(y);
    let m = fy.len();
    let mut jac = Matrix::zeros(m, n);
    let mut yp = y.to_vec();
    for j in 0..n {
        let h = h_rel * y[j].abs().max(1.0);
        yp[j] = y[j] + h;
        let fp = f(&yp);
        yp[j] = y[j] - h;
        let fm = f(&yp);
        yp[j] = y[j];
        for i in 0..m {
            jac.set(i, j, (fp[i] - fm[i]) / (2.0 * h));
        }
    }
    jac
}

/// K(f, ŷ) = J_f(ŷ)·diag(ŷ).
pub fn k_matrix(func: &VectorFn, y: &[f32]) -> Matrix {
    let mut j = func.jac(y);
    for r in 0..j.rows() {
        for c in 0..j.cols() {
            j.set(r, c, j.get(r, c) * y[c]);
        }
    }
    j
}

/// M(f, ŷ) = diag(f(ŷ))⁻¹·K(f, ŷ). Rows with f(ŷ)_i = 0 are treated as
/// +∞-sensitive unless the whole row of K is zero.
pub fn m_matrix(func: &VectorFn, y: &[f32]) -> Matrix {
    let fy = func.eval(y);
    let mut k = k_matrix(func, y);
    for r in 0..k.rows() {
        let d = fy[r];
        for c in 0..k.cols() {
            let v = k.get(r, c);
            let scaled = if d != 0.0 {
                v / d
            } else if v == 0.0 {
                0.0
            } else {
                f32::INFINITY
            };
            k.set(r, c, scaled);
        }
    }
    k
}

/// ‖A (I − diag q)‖_{∞,∞}: max absolute row sum over unselected columns.
pub fn inf_norm_unselected(a: &Matrix, mask: &[bool]) -> f64 {
    assert_eq!(a.cols(), mask.len());
    let mut best = 0.0f64;
    for r in 0..a.rows() {
        let mut s = 0.0f64;
        for c in 0..a.cols() {
            if !mask[c] {
                s += a.get(r, c).abs() as f64;
            }
        }
        best = best.max(s);
    }
    best
}

/// ‖A (I − diag q)‖_{1,1}: max absolute column sum over unselected columns.
pub fn one_norm_unselected(a: &Matrix, mask: &[bool]) -> f64 {
    assert_eq!(a.cols(), mask.len());
    let mut best = 0.0f64;
    for c in 0..a.cols() {
        if !mask[c] {
            let mut s = 0.0f64;
            for r in 0..a.rows() {
                s += a.get(r, c).abs() as f64;
            }
            best = best.max(s);
        }
    }
    best
}

/// κ_c(f, ŷ; q) — componentwise LAMP objective (eq. 3).
pub fn kappa_c(func: &VectorFn, y: &[f32], mask: &[bool]) -> f64 {
    inf_norm_unselected(&m_matrix(func, y), mask)
}

/// κ₁(f, ŷ; q) — ℓ₁-normwise LAMP objective (eq. 4 with p = 1).
pub fn kappa_1(func: &VectorFn, y: &[f32], mask: &[bool]) -> f64 {
    let fy = func.eval(y);
    let denom: f64 = fy.iter().map(|&v| v.abs() as f64).sum();
    if denom == 0.0 {
        return 0.0;
    }
    one_norm_unselected(&k_matrix(func, y), mask) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lamp::softmax::{kappa1_softmax, softmax};
    use crate::util::Rng;

    fn softmax_fn<'a>() -> VectorFn<'a> {
        VectorFn::with_jacobian(
            |y| softmax(y),
            |y| {
                let z = softmax(y);
                let n = z.len();
                let mut j = Matrix::zeros(n, n);
                for i in 0..n {
                    for c in 0..n {
                        let d = if i == c { z[i] } else { 0.0 };
                        j.set(i, c, d - z[i] * z[c]);
                    }
                }
                j
            },
        )
    }

    #[test]
    fn generic_kappa1_matches_closed_form_softmax() {
        // Prop 3.3 closed form vs the generic K-matrix evaluation.
        let mut rng = Rng::new(1);
        let f = softmax_fn();
        for _ in 0..100 {
            let n = rng.range(2, 12);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 6.0).collect();
            let mask: Vec<bool> = (0..n).map(|_| rng.f32() < 0.3).collect();
            if mask.iter().all(|&b| b) {
                continue;
            }
            let generic = kappa_1(&f, &y, &mask);
            let closed = kappa1_softmax(&y, &mask) as f64;
            assert!(
                (generic - closed).abs() < 1e-4 * (1.0 + closed),
                "generic={generic} closed={closed} y={y:?}"
            );
        }
    }

    #[test]
    fn generic_kappa_c_matches_rmsnorm_closed_form() {
        use crate::lamp::rmsnorm::{kappa_c_rmsnorm, rmsnorm};
        let mut rng = Rng::new(2);
        // Analytic Jacobian of √n·y/‖y‖: √n(I − yyᵀ/‖y‖²)/‖y‖.
        let f = VectorFn::with_jacobian(
            |y| rmsnorm(y),
            |y| {
                let n = y.len();
                let norm2: f32 = y.iter().map(|&x| x * x).sum();
                let norm = norm2.sqrt();
                let sn = (n as f32).sqrt();
                let mut j = Matrix::zeros(n, n);
                for i in 0..n {
                    for c in 0..n {
                        let eye = if i == c { 1.0 } else { 0.0 };
                        j.set(i, c, sn * (eye - y[i] * y[c] / norm2) / norm);
                    }
                }
                j
            },
        );
        for _ in 0..100 {
            let n = rng.range(2, 10);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.3) * 4.0 + 0.2).collect();
            let mask: Vec<bool> = (0..n).map(|_| rng.f32() < 0.3).collect();
            if mask.iter().all(|&b| b) {
                continue;
            }
            let generic = kappa_c(&f, &y, &mask);
            let closed = kappa_c_rmsnorm(&y, &mask);
            assert!(
                (generic - closed).abs() < 1e-3 * (1.0 + closed),
                "generic={generic} closed={closed} y={y:?} mask={mask:?}"
            );
        }
    }

    #[test]
    fn numeric_jacobian_matches_analytic_softmax() {
        let mut rng = Rng::new(3);
        let with_j = softmax_fn();
        let without_j = VectorFn::new(|y| softmax(y));
        for _ in 0..20 {
            let n = rng.range(2, 8);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            let ja = with_j.jac(&y);
            let jn = without_j.jac(&y);
            assert!(ja.max_abs_diff(&jn).unwrap() < 1e-2);
        }
    }

    #[test]
    fn full_selection_gives_zero() {
        let f = softmax_fn();
        let y = [1.0f32, -2.0, 0.5];
        let mask = [true, true, true];
        assert_eq!(kappa_c(&f, &y, &mask), 0.0);
        assert_eq!(kappa_1(&f, &y, &mask), 0.0);
    }

    #[test]
    fn norms_on_simple_matrix() {
        let a = Matrix::from_vec(2, 2, vec![1.0, -2.0, 3.0, 4.0]).unwrap();
        // no selection: inf norm = max(3, 7) = 7; one norm = max(4, 6) = 6
        assert_eq!(inf_norm_unselected(&a, &[false, false]), 7.0);
        assert_eq!(one_norm_unselected(&a, &[false, false]), 6.0);
        // select column 1:
        assert_eq!(inf_norm_unselected(&a, &[false, true]), 3.0);
        assert_eq!(one_norm_unselected(&a, &[false, true]), 4.0);
    }
}
