//! LAMP selection rules for softmax (paper §3.3, §4.4, App. C.5, C.4).
//!
//! Softmax probabilities z = softmax(y) have the ℓ₁-normwise LAMP condition
//! (Prop 3.3):
//!
//! ```text
//!   κ₁(f, y; q) = 2 · max_{j ∉ Ω} z_j (1 − z_j) |y_j|
//! ```
//!
//! so the optimal ("strict") solution of eq. (5) flags exactly the indices
//! with `2 z_j (1 − z_j) |y_j| > τ` (eq. 8). The relaxed relative-threshold
//! rule (eq. 9) drops the (1 − z_j) factor and the normalization constant:
//! `|y_j| e^{y_j} > τ max_i |y_i| e^{y_i}` — computable in one pass without
//! materializing z, the stepping stone towards FlashAttention integration.

use crate::linalg::simd;
use crate::util::Rng;

/// Which LAMP selection rule to apply to a softmax row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SoftmaxRule {
    /// Strict optimal rule, eq. (8): `2 z_j (1 − z_j) |y_j| > τ`.
    Strict,
    /// Relaxed relative-threshold rule, eq. (9):
    /// `|y_j| e^{y_j} > τ · max_i |y_i| e^{y_i}`, with 0 ≤ τ < 1.
    Relaxed,
    /// Relaxed rule with length-normalized threshold τ√(ref_len/n)
    /// (App. C.5). `ref_len` is the model's training context (paper: 1024).
    RelaxedLengthNorm { ref_len: usize },
    /// Baseline: same *count* as Strict at this τ, positions chosen
    /// uniformly at random (App. C.4).
    Random,
    /// Tile-granular strict rule (PR 8): score rows are partitioned into
    /// contiguous tiles of `width` columns; a tile is recomputed exactly
    /// when its *summed* strict sensitivity exceeds τ. The last tile —
    /// which contains the causal diagonal — is always recomputed.
    Tile { width: usize },
    /// Baseline for [`SoftmaxRule::Tile`]: same number of *non-diagonal*
    /// tiles as `Tile` at this τ, chosen uniformly at random; the diagonal
    /// tile is always recomputed.
    TileRandom { width: usize },
}

/// Numerically stable softmax (subtract-max), FP32. Defined as a copy fed
/// through [`softmax_inplace`], so the two are bit-identical by
/// construction.
pub fn softmax(y: &[f32]) -> Vec<f32> {
    let mut z = y.to_vec();
    softmax_inplace(&mut z);
    z
}

/// Numerically stable softmax computed in place over `y` — allocation-free
/// variant of [`softmax`] for the engine hot path.
///
/// The subtract-max and normalization reductions run through the pinned
/// SIMD row chains ([`simd::row_max`], [`simd::row_sum`] — the `dot_block`
/// block shape with lanewise max/add, PR 9), so the result is bitwise
/// independent of the dispatched backend; the exponential stays scalar and
/// the final divide is lanewise (bit-transparent).
pub fn softmax_inplace(y: &mut [f32]) {
    if y.is_empty() {
        return;
    }
    let m = simd::row_max(y);
    for v in y.iter_mut() {
        *v = (*v - m).exp();
    }
    let sum = simd::row_sum(y);
    if !simd::div_row_simd(y, sum) {
        for v in y.iter_mut() {
            *v /= sum;
        }
    }
}

/// The strict LAMP sensitivity of entry j: `2 z_j (1 − z_j) |y_j|`.
#[inline]
pub fn strict_sensitivity(zj: f32, yj: f32) -> f32 {
    2.0 * zj * (1.0 - zj) * yj.abs()
}

/// κ₁(f, y; q) for softmax (Prop 3.3): `2 max_{j∉Ω} z_j(1−z_j)|y_j|`.
///
/// `selected[j] == true` means j ∈ Ω (recomputed, hence excluded from the
/// max). Returns 0 when every index is selected.
pub fn kappa1_softmax(y: &[f32], selected: &[bool]) -> f32 {
    assert_eq!(y.len(), selected.len());
    let z = softmax(y);
    let mut k = 0.0f32;
    for j in 0..y.len() {
        if !selected[j] {
            k = k.max(strict_sensitivity(z[j], y[j]));
        }
    }
    k
}

/// Apply the strict rule (eq. 8) to one softmax row.
///
/// Returns the selection mask. `y` is the softmax *input* (the scaled KQ
/// scores). The computed ŷ values are used for both z and |y|, as the paper
/// prescribes (exact values are unknown at run time).
pub fn select_strict(y: &[f32], tau: f32) -> Vec<bool> {
    let z = softmax(y);
    y.iter()
        .zip(&z)
        .map(|(&yj, &zj)| strict_sensitivity(zj, yj) > tau)
        .collect()
}

/// Apply the relaxed relative-threshold rule (eq. 9) to one softmax row.
///
/// Computed with the shift `y_j − max_i y_i` inside the exponential so the
/// comparison is overflow-free and — crucially — independent of the softmax
/// normalization constant:
/// `|y_j| e^{y_j − m} > τ · max_i |y_i| e^{y_i − m}`.
pub fn select_relaxed(y: &[f32], tau: f32) -> Vec<bool> {
    if y.is_empty() {
        return Vec::new();
    }
    let m = y.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let w: Vec<f32> = y.iter().map(|&v| v.abs() * (v - m).exp()).collect();
    let wmax = w.iter().copied().fold(0.0f32, f32::max);
    let cut = tau * wmax;
    w.iter().map(|&wj| wj > cut).collect()
}

/// Length-normalized relaxed rule (App. C.5): τ ← τ·√(ref_len/n) where n is
/// the row length (position in the causal mask).
pub fn select_relaxed_ln(y: &[f32], tau: f32, ref_len: usize) -> Vec<bool> {
    let n = y.len().max(1);
    let scaled = tau * ((ref_len as f32 / n as f32).sqrt());
    // Relative thresholds only make sense in [0, 1); saturate.
    select_relaxed(y, scaled.min(1.0))
}

/// A `len`-long mask with exactly `count` uniformly random positions set —
/// the count-matched random baseline of App. C.4, shared by every site's
/// `Random` rule (softmax here, `lamp::activation::select_activation_rule`,
/// and the norm site's `model::plan::norm_site_row`).
pub fn random_mask(len: usize, count: usize, rng: &mut Rng) -> Vec<bool> {
    let mut mask = vec![false; len];
    for i in rng.sample_indices(len, count) {
        mask[i] = true;
    }
    mask
}

/// Random baseline (App. C.4): flags exactly as many entries as
/// [`select_strict`] would at this τ, at uniformly random positions.
pub fn select_random(y: &[f32], tau: f32, rng: &mut Rng) -> Vec<bool> {
    let count = select_strict(y, tau).iter().filter(|&&b| b).count();
    random_mask(y.len(), count, rng)
}

/// Number of `width`-wide tiles covering a row of `n` columns.
#[inline]
pub fn tile_count(n: usize, width: usize) -> usize {
    n.div_ceil(width.max(1))
}

/// Tile-granular strict rule (PR 8). Partition the row into contiguous
/// tiles of `width` columns (the last tile may be ragged) and recompute a
/// tile exactly when the *sum* of its entries' strict sensitivities
/// `2 z_j (1 − z_j) |y_j|` exceeds τ. The final tile — the one holding the
/// causal diagonal in attention — is always recomputed: the diagonal score
/// is the row's own query-key dot and dominates short rows.
///
/// The returned mask is tile-uniform: `mask[j]` depends only on `j / width`.
pub fn select_tile(y: &[f32], tau: f32, width: usize) -> Vec<bool> {
    let n = y.len();
    let mut mask = vec![false; n];
    if n == 0 {
        return mask;
    }
    let w = width.max(1);
    let z = softmax(y);
    let ntiles = tile_count(n, w);
    for t in 0..ntiles {
        let lo = t * w;
        let hi = ((t + 1) * w).min(n);
        let s: f32 = (lo..hi).map(|j| strict_sensitivity(z[j], y[j])).sum();
        if t + 1 == ntiles || s > tau {
            mask[lo..hi].fill(true);
        }
    }
    mask
}

/// Count-matched random baseline for [`select_tile`]: flags the diagonal
/// (last) tile plus as many uniformly random non-diagonal tiles as
/// [`select_tile`] selects at this τ.
pub fn select_tile_random(y: &[f32], tau: f32, width: usize, rng: &mut Rng) -> Vec<bool> {
    let n = y.len();
    let mut mask = vec![false; n];
    if n == 0 {
        return mask;
    }
    let w = width.max(1);
    let ntiles = tile_count(n, w);
    let strict = select_tile(y, tau, w);
    // Non-diagonal tiles selected by the tile rule (mask is tile-uniform,
    // so the tile's first element witnesses the whole tile).
    let k = (0..ntiles - 1).filter(|&t| strict[t * w]).count();
    for t in rng.sample_indices(ntiles - 1, k) {
        let lo = t * w;
        mask[lo..lo + w].fill(true); // non-diagonal tiles are never ragged
    }
    let lo = (ntiles - 1) * w;
    mask[lo..n].fill(true);
    mask
}

/// Dispatch on [`SoftmaxRule`].
pub fn select_softmax(y: &[f32], tau: f32, rule: SoftmaxRule, rng: &mut Rng) -> Vec<bool> {
    match rule {
        SoftmaxRule::Strict => select_strict(y, tau),
        SoftmaxRule::Relaxed => select_relaxed(y, tau),
        SoftmaxRule::RelaxedLengthNorm { ref_len } => select_relaxed_ln(y, tau, ref_len),
        SoftmaxRule::Random => select_random(y, tau, rng),
        SoftmaxRule::Tile { width } => select_tile(y, tau, width),
        SoftmaxRule::TileRandom { width } => select_tile_random(y, tau, width, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let z = softmax(&[1.0, 2.0, 3.0]);
        let s: f32 = z.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(z[2] > z[1] && z[1] > z[0]);
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = softmax(&[1.0, 2.0, 3.0]);
        let b = softmax(&[101.0, 102.0, 103.0]);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_extreme_inputs_stable() {
        let z = softmax(&[1000.0, -1000.0]);
        assert!((z[0] - 1.0).abs() < 1e-6);
        assert!(z[1] >= 0.0 && z.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn softmax_inplace_bitwise_matches_allocating() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let n = rng.range(0, 64);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 30.0).collect();
            let want = softmax(&y);
            let mut got = y.clone();
            softmax_inplace(&mut got);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn strict_satisfies_kappa_bound() {
        // The defining property: after selection, κ₁ ≤ τ.
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let n = rng.range(1, 64);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 20.0).collect();
            let tau = rng.f32() * 0.5;
            let mask = select_strict(&y, tau);
            assert!(
                kappa1_softmax(&y, &mask) <= tau,
                "kappa exceeded tau={tau} y={y:?}"
            );
        }
    }

    #[test]
    fn strict_is_minimal() {
        // Unselecting any flagged index must violate the constraint:
        // the strict rule is the exact minimizer (thresholding the max).
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let n = rng.range(2, 32);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = 0.05 + rng.f32() * 0.2;
            let mask = select_strict(&y, tau);
            for j in 0..n {
                if mask[j] {
                    let mut weaker = mask.clone();
                    weaker[j] = false;
                    assert!(
                        kappa1_softmax(&y, &weaker) > tau,
                        "index {j} was unnecessary"
                    );
                }
            }
        }
    }

    #[test]
    fn concentrated_distribution_needs_no_recompute() {
        // Paper: "For an extremely concentrated distribution where z is
        // close to a standard basis vector, no recomputations are needed."
        let mut y = vec![-30.0f32; 16];
        y[3] = 30.0;
        let mask = select_strict(&y, 0.1);
        assert!(mask.iter().all(|&b| !b), "mask={mask:?}");
    }

    #[test]
    fn confused_head_needs_recompute() {
        // Multiple equally probable outcomes with large |y| are sensitive.
        let y = vec![8.0f32, 8.0, 8.0, 8.0];
        let mask = select_strict(&y, 0.1);
        assert!(mask.iter().all(|&b| b), "mask={mask:?}");
    }

    #[test]
    fn tau_zero_selects_everything_nonzero() {
        let y = vec![1.0f32, -2.0, 3.0];
        let mask = select_strict(&y, 0.0);
        assert_eq!(mask, vec![true, true, true]);
    }

    #[test]
    fn tau_infinite_selects_nothing() {
        let y = vec![5.0f32, -5.0, 2.0];
        assert!(select_strict(&y, f32::INFINITY).iter().all(|&b| !b));
        assert!(select_relaxed(&y, 1.0).iter().all(|&b| !b)); // τ=1: nothing strictly above max
    }

    #[test]
    fn relaxed_normalization_free() {
        // Shifting y shifts both sides identically: the mask is invariant
        // (this is the FlashAttention-compat property §4.4).
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let n = rng.range(1, 32);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 12.0).collect();
            let tau = rng.f32() * 0.9;
            let m1 = select_relaxed(&y, tau);
            // NB: |y_j| changes under shift, so eq. (9) is *not* exactly
            // shift invariant — but it needs no sum. Here we verify it
            // agrees with the unshifted direct evaluation instead.
            let m = y.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let direct: Vec<bool> = {
                let w: Vec<f32> = y.iter().map(|&v| v.abs() * (v - m).exp()).collect();
                let wmax = w.iter().copied().fold(0.0f32, f32::max);
                w.iter().map(|&x| x > tau * wmax).collect()
            };
            assert_eq!(m1, direct);
        }
    }

    #[test]
    fn relaxed_close_to_strict_on_moderate_rows() {
        // §4.4: relaxed LAMP is almost-optimal — on rows without dominant
        // z≈1 tokens it should select a superset-ish mask of comparable size.
        let mut rng = Rng::new(4);
        let mut total_strict = 0usize;
        let mut total_relaxed = 0usize;
        for _ in 0..300 {
            let n = 32;
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect();
            total_strict += select_strict(&y, 0.1).iter().filter(|&&b| b).count();
            total_relaxed += select_relaxed(&y, 0.1).iter().filter(|&&b| b).count();
        }
        let ratio = total_relaxed as f64 / total_strict.max(1) as f64;
        assert!(ratio > 0.3 && ratio < 10.0, "ratio={ratio}");
    }

    #[test]
    fn length_norm_raises_threshold_for_short_rows() {
        let mut rng = Rng::new(5);
        let y: Vec<f32> = (0..16).map(|_| (rng.f32() - 0.5) * 6.0).collect();
        let base = select_relaxed(&y, 0.1);
        let ln = select_relaxed_ln(&y, 0.1, 1024); // τ·√(1024/16) = 0.8
        let nb = base.iter().filter(|&&b| b).count();
        let nl = ln.iter().filter(|&&b| b).count();
        assert!(nl <= nb, "ln should not select more on short rows: {nl} vs {nb}");
    }

    #[test]
    fn random_matches_strict_count() {
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            let n = rng.range(1, 64);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = rng.f32() * 0.3;
            let ns = select_strict(&y, tau).iter().filter(|&&b| b).count();
            let nr = select_random(&y, tau, &mut rng).iter().filter(|&&b| b).count();
            assert_eq!(ns, nr);
        }
    }

    #[test]
    fn empty_row() {
        let mut rng = Rng::new(7);
        assert!(select_strict(&[], 0.1).is_empty());
        assert!(select_relaxed(&[], 0.1).is_empty());
        assert!(select_random(&[], 0.1, &mut rng).is_empty());
        assert_eq!(kappa1_softmax(&[], &[]), 0.0);
    }

    #[test]
    fn single_element_row_is_stable() {
        // z = [1]: sensitivity 2·1·0·|y| = 0 → never selected by strict.
        let mask = select_strict(&[42.0], 1e-9);
        assert_eq!(mask, vec![false]);
    }

    #[test]
    fn tile_mask_is_tile_uniform_and_covers_diagonal() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let n = rng.range(1, 70);
            let width = rng.range(1, 20);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = rng.f32() * 0.4;
            let mask = select_tile(&y, tau, width);
            assert_eq!(mask.len(), n);
            // Tile-uniform: every element agrees with its tile's first element.
            for (j, &b) in mask.iter().enumerate() {
                assert_eq!(b, mask[(j / width) * width], "j={j} width={width}");
            }
            // The diagonal (last) tile is always selected.
            assert!(mask[n - 1], "diagonal tile must be selected");
        }
    }

    #[test]
    fn tile_selection_monotone_in_tau() {
        let mut rng = Rng::new(12);
        for _ in 0..200 {
            let n = rng.range(1, 64);
            let width = rng.range(1, 12);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 8.0).collect();
            let t1 = rng.f32() * 0.2;
            let t2 = t1 + rng.f32() * 0.5;
            let m1 = select_tile(&y, t1, width);
            let m2 = select_tile(&y, t2, width);
            for j in 0..n {
                if m2[j] {
                    assert!(m1[j], "tile selection not monotone in tau");
                }
            }
        }
    }

    #[test]
    fn tile_width_one_matches_summed_strict_plus_diagonal() {
        // width=1: each tile is one entry, so selection is the strict rule
        // except the last entry is forced on.
        let mut rng = Rng::new(13);
        for _ in 0..100 {
            let n = rng.range(1, 40);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = rng.f32() * 0.3;
            let tiled = select_tile(&y, tau, 1);
            let strict = select_strict(&y, tau);
            for j in 0..n - 1 {
                assert_eq!(tiled[j], strict[j], "j={j}");
            }
            assert!(tiled[n - 1]);
        }
    }

    #[test]
    fn tile_random_matches_tile_count() {
        let mut rng = Rng::new(14);
        for _ in 0..100 {
            let n = rng.range(1, 64);
            let width = rng.range(1, 12);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let tau = rng.f32() * 0.3;
            let w = width.max(1);
            let a = select_tile(&y, tau, width);
            let b = select_tile_random(&y, tau, width, &mut rng);
            let tiles = |m: &[bool]| (0..tile_count(n, w)).filter(|&t| m[t * w]).count();
            assert_eq!(tiles(&a), tiles(&b), "n={n} width={width}");
            assert!(b[n - 1], "random baseline must keep the diagonal tile");
        }
    }

    #[test]
    fn tile_empty_and_zero_width() {
        let mut rng = Rng::new(15);
        assert!(select_tile(&[], 0.1, 8).is_empty());
        assert!(select_tile_random(&[], 0.1, 8, &mut rng).is_empty());
        // width 0 is clamped to 1 rather than panicking.
        let m = select_tile(&[1.0, 2.0], f32::INFINITY, 0);
        assert_eq!(m, vec![false, true]);
    }

    #[test]
    fn monotone_in_tau() {
        // Larger τ ⇒ subset selection.
        let mut rng = Rng::new(8);
        for _ in 0..200 {
            let n = rng.range(1, 48);
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 8.0).collect();
            let t1 = rng.f32() * 0.2;
            let t2 = t1 + rng.f32() * 0.3;
            for (rule1, rule2) in [
                (select_strict(&y, t1), select_strict(&y, t2)),
                (select_relaxed(&y, t1), select_relaxed(&y, t2)),
            ] {
                for j in 0..n {
                    if rule2[j] {
                        assert!(rule1[j], "selection not monotone in tau");
                    }
                }
            }
        }
    }
}
