//! `lamp` — the leader binary: experiment harness, serving driver,
//! artifact inspection.
//!
//! ```text
//! lamp exp <fig1..fig7|table1|appendix_b|all> [--quick] [--seqs N] ...
//! lamp serve --model xl --requests 64 --engine pjrt|native [--tier balanced-whole]
//!     [--kv-fmt f32|bf16|ps<mu>] [--kv-tau 0.01] [--gen-requests 8]
//! lamp inspect --artifacts artifacts
//! lamp forward --model nano --mu 4 --tau 0.1 --rule strict --engine native \
//!     [--mlp-mu 7 --mlp-tau 0.5] [--norm-mu 10 --norm-tau 1.0] \
//!     [--logits-mu 7 --logits-tau 0.05 --logits-rule relaxed] \
//!     [--weights-fmt f32|bf16|ps<mu>]
//! lamp generate --model nano [--kv-fmt bf16 --kv-tau 0.01] \
//!     [--spec-k 4 --spec-draft 2] [--stats-json stats.json] ...
//! lamp serve ... [--stats-json s.json --metrics-out m.json --trace-out t.jsonl]
//! lamp trials run <name> [--trace-out t.jsonl --metrics-out m.json]
//! lamp obs metrics m.json [--format prometheus|json]
//! lamp obs trace t.jsonl [--kind decode] [--request 3] [--chrome]
//! ```
//!
//! The `--mlp-*`/`--norm-*`/`--logits-*` options activate the non-attention
//! LAMP sites of the whole-model `PrecisionPlan`; their defaults keep those
//! sites at the FP32 reference. `--weights-fmt` (forward/generate/serve)
//! re-stores the native engine's weight matrices in bf16 or PS(μ)-rounded
//! storage (`Weights::quantize_to`); f32 is the default and bit-identical
//! to the historical engine. `--kv-fmt` (generate/serve) selects the paged
//! KV-cache block storage (`model::kvstore`), with `--kv-tau` as the LAMP
//! KV repair threshold (rows whose quantization error exceeds it stay
//! pinned at exact f32; `inf` = uniform quantized, `0` = bit-identical to
//! f32 KV). The pjrt engine serves f32 storage only, on both axes.

use lamp::benchkit::Table;
use lamp::cli::{ArgSpec, Args, Command};
use lamp::coordinator::{
    DegradationLadder, Engine, FaultInjector, FaultPlan, GenerateRequest, InferenceRequest,
    KvCacheOptions, NativeEngine, PjrtEngine, PrecisionPolicy, Rule, SchedulerOptions, Server,
    SitePolicy, SpecPolicy, WeightFormat,
};
use lamp::data::{Dataset, Domain};
use lamp::experiments::{self, EvalOptions};
use lamp::obs::ObsHub;
use lamp::runtime::ArtifactStore;
use lamp::util::Stopwatch;
use std::sync::Arc;

fn cli() -> Command {
    Command::new("lamp", "LAMP: look-ahead mixed-precision inference — reproduction harness")
        .subcommand(
            Command::new("exp", "run a paper experiment (fig1..fig7, table1, appendix_b, all)")
                .arg(ArgSpec::pos("name", "experiment name", true))
                .arg(ArgSpec::opt("seqs", "evaluation sequences per panel", "6"))
                .arg(ArgSpec::opt("seq-len", "tokens per sequence", "64"))
                .arg(ArgSpec::opt("seed", "held-out stream seed", "42"))
                .arg(ArgSpec::opt("workers", "parallel workers", "8"))
                .arg(ArgSpec::opt("artifacts", "artifact directory", "artifacts"))
                .arg(ArgSpec::flag("quick", "smoke-test scale")),
        )
        .subcommand(
            Command::new("serve", "run the batching server over a synthetic workload")
                .arg(ArgSpec::opt("model", "model config (nano|small|xl)", "small"))
                .arg(ArgSpec::opt("engine", "native|pjrt", "pjrt"))
                .arg(ArgSpec::opt("requests", "number of requests", "32"))
                .arg(ArgSpec::opt(
                    "tier",
                    "precision tier (exact|high|balanced|economy|balanced-whole)",
                    "balanced",
                ))
                .arg(ArgSpec::opt("domain", "workload domain", "web"))
                .arg(ArgSpec::opt("artifacts", "artifact directory", "artifacts"))
                .arg(ArgSpec::opt(
                    "weights-fmt",
                    "weight storage format (f32|bf16|ps<mu>; native engine only)",
                    "f32",
                ))
                .arg(ArgSpec::opt(
                    "kv-fmt",
                    "paged KV-cache storage format (f32|bf16|ps<mu>; native engine only)",
                    "f32",
                ))
                .arg(ArgSpec::opt(
                    "kv-tau",
                    "LAMP KV repair threshold (inf = uniform quantized, 0 = exact)",
                    "inf",
                ))
                .arg(ArgSpec::opt(
                    "gen-requests",
                    "generation requests driven through the paged-KV decode scheduler",
                    "8",
                ))
                .arg(ArgSpec::opt("gen-tokens", "tokens per generation request", "16"))
                .arg(ArgSpec::opt(
                    "deadline-ms",
                    "total wall-clock deadline per generation request (0 = unbounded)",
                    "0",
                ))
                .arg(ArgSpec::opt(
                    "fault-seed",
                    "wrap the engine in a seeded chaos fault injector (0 = off)",
                    "0",
                ))
                .arg(ArgSpec::flag(
                    "degrade",
                    "enable the precision degradation ladder under pool pressure",
                ))
                .arg(spec_k_arg())
                .arg(spec_draft_arg())
                .arg(ArgSpec::opt(
                    "stats-json",
                    "write the final ServerStats as stable-keyed JSON to this file",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "metrics-out",
                    "write a metrics-registry snapshot (JSON) to this file",
                    "",
                ))
                .arg(ArgSpec::opt(
                    "trace-out",
                    "write the per-request span trace (JSONL) to this file",
                    "",
                ))
                .arg(ArgSpec::opt("seed", "workload seed", "1")),
        )
        .subcommand(
            Command::new("inspect", "list available artifacts and model configs")
                .arg(ArgSpec::opt("artifacts", "artifact directory", "artifacts")),
        )
        .subcommand(site_args(
            Command::new("generate", "autoregressive generation under a precision plan")
                .arg(ArgSpec::opt("model", "model config", "nano"))
                .arg(ArgSpec::opt(
                    "kv-fmt",
                    "paged KV-cache storage format (f32|bf16|ps<mu>)",
                    "f32",
                ))
                .arg(ArgSpec::opt(
                    "kv-tau",
                    "LAMP KV repair threshold (inf = uniform quantized, 0 = exact)",
                    "inf",
                ))
                .arg(ArgSpec::opt("mu", "attention mantissa bits", "4"))
                .arg(ArgSpec::opt("tau", "attention LAMP threshold (inf = uniform)", "0.1"))
                .arg(ArgSpec::opt(
                    "rule",
                    "strict|relaxed|relaxed_ln|random|tile<w>|tile_random<w>",
                    "strict",
                ))
                .arg(ArgSpec::opt("new-tokens", "tokens to generate", "16"))
                .arg(ArgSpec::opt("topk", "0 = greedy, else top-k sampling", "0"))
                .arg(ArgSpec::opt("temperature", "sampling temperature", "1.0"))
                .arg(spec_k_arg())
                .arg(spec_draft_arg())
                .arg(ArgSpec::opt(
                    "stats-json",
                    "write the generation stats as stable-keyed JSON to this file",
                    "",
                ))
                .arg(ArgSpec::opt("artifacts", "artifact directory", "artifacts"))
                .arg(ArgSpec::opt("seed", "seed", "0")),
        ))
        .subcommand(site_args(
            Command::new("forward", "single forward pass; prints per-site recompute stats")
                .arg(ArgSpec::opt("model", "model config", "nano"))
                .arg(ArgSpec::opt("engine", "native|pjrt", "native"))
                .arg(ArgSpec::opt("mu", "attention mantissa bits", "4"))
                .arg(ArgSpec::opt("tau", "attention LAMP threshold (inf = uniform)", "0.1"))
                .arg(ArgSpec::opt(
                    "rule",
                    "strict|relaxed|relaxed_ln|random|tile<w>|tile_random<w>",
                    "strict",
                ))
                .arg(ArgSpec::opt("artifacts", "artifact directory", "artifacts"))
                .arg(ArgSpec::opt("seed", "seed", "0")),
        ))
        .subcommand(
            Command::new("trials", "deterministic trial replay (run|list|diff)")
                .subcommand(
                    Command::new("run", "replay a trial manifest and print its canonical artifact")
                        .arg(ArgSpec::pos(
                            "manifest",
                            "bundled trial name (see `trials list`) or path to a .trial file",
                            true,
                        ))
                        .arg(ArgSpec::opt(
                            "out",
                            "write the canonical artifact to this file instead of stdout",
                            "",
                        ))
                        .arg(ArgSpec::opt(
                            "workers",
                            "override the manifest's [scheduler] workers (empty = keep)",
                            "",
                        ))
                        .arg(ArgSpec::opt(
                            "trace-out",
                            "write the replay's span trace (JSONL; virtual-clock \
                             ticks, deterministic across reruns) to this file",
                            "",
                        ))
                        .arg(ArgSpec::opt(
                            "metrics-out",
                            "write the replay's metrics-registry snapshot (JSON) \
                             to this file",
                            "",
                        )),
                )
                .subcommand(Command::new("list", "list the bundled trial manifests"))
                .subcommand(
                    Command::new("diff", "byte-compare two canonical trial artifacts")
                        .arg(ArgSpec::pos("a", "first artifact path", true))
                        .arg(ArgSpec::pos("b", "second artifact path", true)),
                ),
        )
        .subcommand(
            Command::new("obs", "render observability exports (metrics|trace)")
                .subcommand(
                    Command::new("metrics", "render a metrics snapshot written by --metrics-out")
                        .arg(ArgSpec::pos("snapshot", "metrics snapshot JSON path", true))
                        .arg(ArgSpec::opt("format", "prometheus|json", "prometheus")),
                )
                .subcommand(
                    Command::new("trace", "filter/convert a span trace written by --trace-out")
                        .arg(ArgSpec::pos("trace", "span trace JSONL path", true))
                        .arg(ArgSpec::opt(
                            "kind",
                            "keep only spans of this kind (enqueue|admit|resume|prefill|\
                             decode|draft|verify|preempt|retire|fail; empty = all)",
                            "",
                        ))
                        .arg(ArgSpec::opt(
                            "request",
                            "keep only spans of this request id (empty = all)",
                            "",
                        ))
                        .arg(ArgSpec::flag(
                            "chrome",
                            "emit Chrome trace_event JSON instead of JSONL",
                        )),
                ),
        )
        .subcommand(
            Command::new("bench-diff", "gate a BENCH_*.json record against a committed baseline")
                .arg(ArgSpec::pos("baseline", "baseline bench record path", true))
                .arg(ArgSpec::pos("current", "current bench record path", true))
                .arg(ArgSpec::opt(
                    "tolerance",
                    "relative tolerance for two-sided (exact) metrics",
                    "1e-9",
                ))
                .arg(ArgSpec::opt(
                    "perf-tolerance",
                    "relative tolerance for throughput/latency metrics",
                    "0.25",
                ))
                .arg(ArgSpec::opt(
                    "skip",
                    "comma-separated metric keys (or section.key) to skip",
                    "",
                )),
        )
}

/// Attach the per-site plan options (whole-model LAMP) to a subcommand:
/// `--<site>-mu/--<site>-tau/--<site>-rule` for the mlp, norm, and logits
/// (sampler) sites. Defaults leave every non-attention site at the FP32
/// reference, reproducing the attention-only engine bit for bit.
fn site_args(mut cmd: Command) -> Command {
    cmd = cmd.arg(ArgSpec::opt(
        "weights-fmt",
        "weight storage format (f32|bf16|ps<mu>; native engine only)",
        "f32",
    ));
    for site in ["mlp", "norm", "logits"] {
        cmd = cmd
            .arg(ArgSpec::opt(
                &format!("{site}-mu"),
                &format!("{site} site mantissa bits (23 + tau=inf -> FP32 reference)"),
                "23",
            ))
            .arg(ArgSpec::opt(
                &format!("{site}-tau"),
                &format!("{site} site LAMP threshold (inf = uniform PS)"),
                "inf",
            ))
            .arg(ArgSpec::opt(
                &format!("{site}-rule"),
                &format!("{site} site rule (strict|relaxed|relaxed_ln|random)"),
                "strict",
            ));
    }
    cmd
}

/// The speculative-decoding options shared by `generate` and `serve`.
fn spec_k_arg() -> ArgSpec {
    ArgSpec::opt("spec-k", "speculative look-ahead draft length (0 = off)", "0")
}

fn spec_draft_arg() -> ArgSpec {
    ArgSpec::opt(
        "spec-draft",
        "draft plan for every site: mu[:tau[:rule]] (e.g. 2, or 3:0.2:strict)",
        "2",
    )
}

/// Parse `--spec-k`/`--spec-draft` into an optional speculative policy.
/// The draft spec is `mu[:tau[:rule]]`; omitted parts default to uniform
/// PS(μ) (τ=inf, strict), the cheapest plan at that mantissa width.
fn spec_policy(args: &Args) -> lamp::Result<Option<SpecPolicy>> {
    let k = args.get_usize("spec-k")?;
    if k == 0 {
        return Ok(None);
    }
    let spec = args.get_str("spec-draft")?;
    let mut parts = spec.split(':');
    let mu: u32 = parts
        .next()
        .unwrap_or("")
        .parse()
        .map_err(|_| lamp::Error::config(format!("--spec-draft: bad mu in {spec:?}")))?;
    let tau: f32 = match parts.next() {
        None => f32::INFINITY,
        Some(t) => t
            .parse()
            .map_err(|_| lamp::Error::config(format!("--spec-draft: bad tau in {spec:?}")))?,
    };
    let rule = match parts.next() {
        None => Rule::Strict,
        Some(r) => Rule::by_name(r)?,
    };
    if parts.next().is_some() {
        return Err(lamp::Error::config(format!(
            "--spec-draft: expected mu[:tau[:rule]], got {spec:?}"
        )));
    }
    Ok(Some(SpecPolicy::whole_model(SitePolicy { mu, tau, rule }, k)))
}

/// Parse the `--weights-fmt` storage format.
fn weights_fmt(args: &Args) -> lamp::Result<WeightFormat> {
    WeightFormat::by_name(&args.get_str("weights-fmt")?)
}

/// Parse one site's policy from its `--<prefix>-*` options.
fn site_policy(args: &Args, prefix: &str) -> lamp::Result<SitePolicy> {
    Ok(SitePolicy {
        mu: args.get_u32(&format!("{prefix}-mu"))?,
        tau: args.get_f32(&format!("{prefix}-tau"))?,
        rule: Rule::by_name(&args.get_str(&format!("{prefix}-rule"))?)?,
    })
}

/// Assemble the full per-site policy from a subcommand's options.
fn plan_policy(args: &Args) -> lamp::Result<PrecisionPolicy> {
    let policy = PrecisionPolicy::lamp(
        args.get_u32("mu")?,
        args.get_f32("tau")?,
        Rule::by_name(&args.get_str("rule")?)?,
    )
    .with_mlp(site_policy(args, "mlp")?)
    .with_norm(site_policy(args, "norm")?)
    .with_sampler(site_policy(args, "logits")?);
    policy.validate()?;
    Ok(policy)
}

fn main() {
    let cmd = cli();
    let args = match cmd.parse() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match &args.subcommand {
        Some((name, sub)) => match name.as_str() {
            "exp" => cmd_exp(sub),
            "serve" => cmd_serve(sub),
            "inspect" => cmd_inspect(sub),
            "forward" => cmd_forward(sub),
            "generate" => cmd_generate(sub),
            "trials" => cmd_trials(sub),
            "obs" => cmd_obs(sub),
            "bench-diff" => cmd_bench_diff(sub),
            _ => unreachable!(),
        },
        None => {
            println!("{}", cmd.usage());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn eval_options(args: &Args) -> lamp::Result<EvalOptions> {
    Ok(EvalOptions {
        num_seqs: args.get_usize("seqs")?,
        seq_len: args.get_usize("seq-len")?,
        stream_seed: args.get_u64("seed")?,
        workers: args.get_usize("workers")?,
        artifacts: Some(args.get_str("artifacts")?),
        quick: args.get_flag("quick"),
    })
}

fn cmd_exp(args: &Args) -> lamp::Result<()> {
    let name = args.positionals()[0].clone();
    let opts = eval_options(args)?;
    let names: Vec<&str> = if name == "all" {
        experiments::all_names().to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        let mut sw = Stopwatch::new();
        let tables: Vec<Table> = experiments::run(n, &opts)?;
        for t in &tables {
            t.print();
        }
        sw.lap(n);
        println!("[{n}] completed in {:.1}s\n", sw.secs());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> lamp::Result<()> {
    let model = args.get_str("model")?;
    let store = ArtifactStore::open(args.get_str("artifacts")?)?;
    let fmt = weights_fmt(args)?;
    let kv_fmt = WeightFormat::by_name(&args.get_str("kv-fmt")?)?;
    let kv_tau = args.get_f32("kv-tau")?;
    // Chaos mode: wrap the engine in a seeded deterministic fault injector
    // so the whole serving run is replayable from one seed.
    let fault_seed = args.get_u64("fault-seed")?;
    let engine: Box<dyn Engine> = match args.get_str("engine")?.as_str() {
        // Native serving tiles attention across all host CPUs and backs
        // decode sessions with a shared paged KV block pool sized for the
        // scheduler's slot count.
        "native" => {
            let e = NativeEngine::load(&store, &model)?
                .with_weight_format(fmt)?
                .with_threads(0);
            let sessions = SchedulerOptions::default().max_sessions;
            let opts = KvCacheOptions::serving(e.config(), kv_fmt, sessions)
                .with_repair_tau(kv_tau);
            let e = e.with_kv_cache(opts)?;
            if fault_seed != 0 {
                Box::new(FaultInjector::new(e, FaultPlan::chaos(fault_seed))?)
            } else {
                Box::new(e)
            }
        }
        "pjrt" => {
            if fmt != WeightFormat::F32 {
                return Err(lamp::Error::config(format!(
                    "pjrt serves f32 weight storage only (requested {})",
                    fmt.label()
                )));
            }
            if kv_fmt != WeightFormat::F32 {
                return Err(lamp::Error::config(format!(
                    "pjrt serves f32 KV storage only (requested {})",
                    kv_fmt.label()
                )));
            }
            let e = PjrtEngine::load(&store, &model)?;
            if fault_seed != 0 {
                Box::new(FaultInjector::new(e, FaultPlan::chaos(fault_seed))?)
            } else {
                Box::new(e)
            }
        }
        other => {
            return Err(lamp::Error::config(format!("unknown engine {other:?}")))
        }
    };
    let cfg = engine.config().clone();
    let policy = PrecisionPolicy::tier(&args.get_str("tier")?)?;
    let n = args.get_usize("requests")?;
    let domain = Domain::by_name(&args.get_str("domain")?)
        .ok_or_else(|| lamp::Error::config("unknown domain".to_string()))?;
    let seed = args.get_u64("seed")?;
    let backend = engine.backend();

    println!(
        "serving {n} requests on {} ({} backend), policy {}",
        cfg.name,
        backend,
        policy.label()
    );
    let dataset = Dataset::generate(domain, cfg.vocab, n, cfg.seq, 7, seed);
    let deadline_ms = args.get_u64("deadline-ms")?;
    let degrade = args.get_flag("degrade");
    let mut decode_opts = SchedulerOptions::default();
    if degrade {
        decode_opts.ladder = Some(DegradationLadder::default());
    }
    let stats_json = args.get_str("stats-json")?;
    let metrics_out = args.get_str("metrics-out")?;
    let trace_out = args.get_str("trace-out")?;
    let mut hub = ObsHub::new();
    if !trace_out.is_empty() {
        hub = hub.with_tracer(1 << 16);
    }
    let hub = Arc::new(hub);
    let mut server = Server::new(engine, std::time::Duration::from_millis(5))
        .with_scheduler_options(decode_opts)
        .with_obs(Arc::clone(&hub));
    let mut served = 0usize;
    for (i, seq) in dataset.sequences.into_iter().enumerate() {
        server.submit(InferenceRequest::new(i as u64, seq, policy))?;
        served += server.step(false)?.len();
    }
    served += server.drain()?.len();
    assert_eq!(served, n);

    // Generation traffic through the paged-KV continuous-batching
    // scheduler (native engine only: the artifact has no decode path).
    let gen_requests = args.get_usize("gen-requests")?;
    let gen_tokens = args.get_usize("gen-tokens")?;
    if gen_requests > 0 && backend == "native" {
        // Speculation applies to the decode path only (the batch path has
        // no autoregressive loop to speculate over).
        let gen_policy = policy.with_spec(spec_policy(args)?);
        gen_policy.validate()?;
        let prompt_len = (cfg.seq / 4).max(1);
        let prompts =
            Dataset::generate(domain, cfg.vocab, gen_requests, prompt_len, 7, seed ^ 0x5eed);
        for (i, p) in prompts.sequences.into_iter().enumerate() {
            let mut req = GenerateRequest::new((n + i) as u64, p, gen_tokens, gen_policy);
            if deadline_ms > 0 {
                req = req.with_deadline(std::time::Duration::from_millis(deadline_ms));
            }
            server.submit_generate(req)?;
        }
        let events = server.serve_generation()?;
        let failed = events
            .iter()
            .filter(|e| matches!(e, lamp::coordinator::GenerateEvent::Failed { .. }))
            .count();
        if failed > 0 {
            eprintln!("WARNING: {failed} generation request(s) failed");
        }
    }
    let stats = server.stats();
    let mut t = Table::new("serving summary", &["metric", "value"]);
    t.row(vec!["backend".into(), backend.into()]);
    t.row(vec!["weight format".into(), stats.weight_format.clone()]);
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    t.row(vec!["batches".into(), stats.batches.to_string()]);
    t.row(vec!["padding rows".into(), stats.padding_rows.to_string()]);
    t.row(vec!["tokens".into(), stats.total_tokens.to_string()]);
    t.row(vec![
        "recompute rate".into(),
        format!(
            "{:.4}%",
            100.0 * stats.recomputed as f64 / stats.causal_total.max(1) as f64
        ),
    ]);
    t.row(vec!["mean latency".into(), format!("{:.1}ms", 1e3 * stats.latency_mean_s)]);
    t.row(vec!["p95 latency".into(), format!("{:.1}ms", 1e3 * stats.latency_p95_s)]);
    t.row(vec![
        "throughput".into(),
        format!("{:.1} tok/s", stats.throughput_tok_s),
    ]);
    t.row(vec!["kv format".into(), stats.kv_format.clone()]);
    if stats.kv_blocks_capacity > 0 {
        t.row(vec![
            "kv resident bytes".into(),
            stats.kv_resident_bytes.to_string(),
        ]);
        t.row(vec![
            "kv pool occupancy".into(),
            format!(
                "{}/{} blocks ({:.1}%)",
                stats.kv_blocks_used,
                stats.kv_blocks_capacity,
                100.0 * stats.kv_occupancy
            ),
        ]);
        t.row(vec![
            "prefix-share hits".into(),
            format!(
                "{} ({:.1}% of admissions)",
                stats.prefix_share_hits,
                100.0 * stats.prefix_share_rate
            ),
        ]);
        t.row(vec!["preemptions".into(), stats.preemptions.to_string()]);
    }
    if stats.generate_requests > 0 {
        t.row(vec![
            "generation requests".into(),
            format!("{} ({} failed)", stats.generate_requests, stats.generate_failed),
        ]);
        t.row(vec![
            "generated tokens".into(),
            stats.generated_tokens.to_string(),
        ]);
        t.row(vec![
            "ttft p50/p95".into(),
            format!("{:.1}/{:.1}ms", 1e3 * stats.ttft_p50_s, 1e3 * stats.ttft_p95_s),
        ]);
        t.row(vec![
            "itl p50/p95".into(),
            format!("{:.1}/{:.1}ms", 1e3 * stats.itl_p50_s, 1e3 * stats.itl_p95_s),
        ]);
        t.row(vec![
            "retries/timeouts/canceled".into(),
            format!(
                "{}/{}/{}",
                stats.generate_retries, stats.generate_timeouts, stats.generate_canceled
            ),
        ]);
        if stats.faults_injected > 0 {
            t.row(vec![
                "faults injected".into(),
                stats.faults_injected.to_string(),
            ]);
        }
        if stats.spec_rounds > 0 {
            t.row(vec![
                "spec acceptance".into(),
                format!(
                    "{}/{} drafts ({:.1}%) over {} rounds",
                    stats.spec_accepted,
                    stats.spec_drafted,
                    100.0 * stats.spec_acceptance_rate,
                    stats.spec_rounds
                ),
            ]);
            t.row(vec![
                "spec tokens/round".into(),
                format!("{:.2}", stats.spec_mean_accept_len),
            ]);
        }
        if degrade {
            t.row(vec![
                "degrade/restore transitions".into(),
                format!("{}/{}", stats.degrade_transitions, stats.restore_transitions),
            ]);
            t.row(vec![
                "degraded admissions".into(),
                stats.degraded_admissions.to_string(),
            ]);
            t.row(vec![
                "ladder rung".into(),
                format!("{} ({})", stats.ladder_rung, stats.ladder_rung_name),
            ]);
        }
    }
    t.print();
    if !stats_json.is_empty() {
        std::fs::write(&stats_json, stats.to_json())?;
        eprintln!("wrote server stats to {stats_json}");
    }
    if !metrics_out.is_empty() {
        // Fold any cfg-gated kernel timer samples in before snapshotting.
        lamp::obs::timers::publish(hub.registry());
        std::fs::write(&metrics_out, hub.registry().snapshot().to_json())?;
        eprintln!("wrote metrics snapshot to {metrics_out}");
    }
    if !trace_out.is_empty() {
        if let Some(tr) = hub.tracer() {
            std::fs::write(&trace_out, lamp::obs::trace::to_jsonl(&tr.events()))?;
            eprintln!(
                "wrote span trace to {trace_out} ({} spans, {} dropped)",
                tr.len(),
                tr.dropped()
            );
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> lamp::Result<()> {
    let store = ArtifactStore::open(args.get_str("artifacts")?)?;
    let mut t = Table::new(
        "artifacts",
        &["model", "layers", "heads", "d_model", "vocab", "seq", "batch", "params"],
    );
    for name in store.available_models() {
        let cfg = store.model_config(&name)?;
        t.row(vec![
            cfg.name.clone(),
            cfg.layers.to_string(),
            cfg.heads.to_string(),
            cfg.d_model.to_string(),
            cfg.vocab.to_string(),
            cfg.seq.to_string(),
            cfg.batch.to_string(),
            cfg.param_count().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_generate(args: &Args) -> lamp::Result<()> {
    use lamp::model::Decode;
    let model = args.get_str("model")?;
    let store = ArtifactStore::open(args.get_str("artifacts")?)?;
    let kv_fmt = WeightFormat::by_name(&args.get_str("kv-fmt")?)?;
    let kv_tau = args.get_f32("kv-tau")?;
    let engine = NativeEngine::load(&store, &model)?.with_weight_format(weights_fmt(args)?)?;
    let mut kv_opts =
        KvCacheOptions::serving(engine.config(), kv_fmt, 1).with_repair_tau(kv_tau);
    // One session, one shot: publishing blocks for prefix sharing would be
    // pure bookkeeping overhead with no possible adopter.
    kv_opts.sharing = false;
    let engine = engine.with_kv_cache(kv_opts)?;
    let cfg = engine.config().clone();
    let policy = plan_policy(args)?.with_spec(spec_policy(args)?);
    policy.validate()?;
    let seed = args.get_u64("seed")?;
    let k = args.get_usize("topk")?;
    let decode = if k == 0 {
        Decode::Greedy
    } else {
        Decode::TopK { k, temperature: args.get_f32("temperature")? }
    };
    let prompt = Dataset::generate(Domain::Web, cfg.vocab, 1, cfg.seq / 4, 7, seed)
        .sequences
        .remove(0);
    let new_tokens = args.get_usize("new-tokens")?;
    let mut sw = Stopwatch::new();
    // Paged KV-cache decode: O(S) new inner products per token (DESIGN.md
    // §Perf), through the single shared decode loop (bit-identical to
    // serving; `--kv-fmt bf16` halves resident KV bytes).
    let mut session = engine.decode_session(&policy, seed)?;
    let (tokens, stats) =
        lamp::model::generate_with_session(&mut session, &prompt, new_tokens, decode)?;
    println!(
        "generate({model}): prompt {} tokens -> {} tokens, policy {}, weights {}, kv {}",
        prompt.len(),
        tokens.len(),
        policy.label(),
        engine.weight_format().label(),
        engine.kv_format().label()
    );
    println!("  continuation: {:?}", &tokens[prompt.len()..]);
    for (site, rate) in stats.site_rates() {
        println!("  recompute rate [{site}]: {:.4}%", 100.0 * rate);
    }
    if stats.spec.rounds > 0 {
        println!(
            "  speculation: {} rounds, {}/{} drafts accepted ({:.1}%), \
             {:.2} tokens/round",
            stats.spec.rounds,
            stats.spec.accepted,
            stats.spec.drafted,
            100.0 * stats.spec.acceptance_rate(),
            stats.spec.mean_accept_len()
        );
    }
    println!(
        "  kv cache: {} bytes resident, {:.3}% rows pinned f32 (repair tau {})",
        session.kv().resident_bytes(),
        100.0 * session.kv().pinned_rate(),
        kv_tau
    );
    println!("  wall: {:.3}s", sw.secs());
    sw.lap("generate");
    let stats_json = args.get_str("stats-json")?;
    if !stats_json.is_empty() {
        use lamp::obs::export::json_f64;
        let mut fields: Vec<(String, String)> = vec![
            ("prompt_tokens".to_string(), prompt.len().to_string()),
            (
                "generated_tokens".to_string(),
                (tokens.len() - prompt.len()).to_string(),
            ),
            ("recomputed".to_string(), stats.recomputed.to_string()),
            ("causal_total".to_string(), stats.causal_total.to_string()),
        ];
        for (site, rate) in stats.site_rates() {
            fields.push((format!("recompute_rate.{site}"), json_f64(rate)));
        }
        fields.push(("spec_rounds".to_string(), stats.spec.rounds.to_string()));
        fields.push(("spec_drafted".to_string(), stats.spec.drafted.to_string()));
        fields.push(("spec_accepted".to_string(), stats.spec.accepted.to_string()));
        fields.push((
            "kv_resident_bytes".to_string(),
            session.kv().resident_bytes().to_string(),
        ));
        fields.push(("kv_pinned_rate".to_string(), json_f64(session.kv().pinned_rate())));
        let body = fields
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v}"))
            .collect::<Vec<_>>()
            .join(",\n");
        std::fs::write(&stats_json, format!("{{\n{body}\n}}\n"))?;
        eprintln!("wrote generation stats to {stats_json}");
    }
    Ok(())
}

fn cmd_forward(args: &Args) -> lamp::Result<()> {
    let model = args.get_str("model")?;
    let store = ArtifactStore::open(args.get_str("artifacts")?)?;
    let fmt = weights_fmt(args)?;
    let engine: Box<dyn Engine> = match args.get_str("engine")?.as_str() {
        "native" => Box::new(NativeEngine::load(&store, &model)?.with_weight_format(fmt)?),
        "pjrt" => {
            if fmt != WeightFormat::F32 {
                return Err(lamp::Error::config(format!(
                    "pjrt serves f32 weight storage only (requested {})",
                    fmt.label()
                )));
            }
            Box::new(PjrtEngine::load(&store, &model)?)
        }
        other => {
            return Err(lamp::Error::config(format!("unknown engine {other:?}")))
        }
    };
    let cfg = engine.config().clone();
    let policy = plan_policy(args)?;
    let seed = args.get_u64("seed")? as i32;
    let dataset = Dataset::generate(Domain::Web, cfg.vocab, cfg.batch, cfg.seq, 7, seed as u64);
    let mut sw = Stopwatch::new();
    let out = engine.infer(&dataset.sequences, &policy, seed)?;
    let dt = sw.secs();
    sw.lap("forward");
    println!(
        "forward({}, {} backend): batch={} seq={} policy {} weights {}",
        cfg.name,
        engine.backend(),
        cfg.batch,
        cfg.seq,
        policy.label(),
        engine.weight_format().label()
    );
    println!(
        "  recomputed {} / {} causal products ({:.4}%)",
        out.stats.recomputed,
        out.stats.causal_total,
        100.0 * out.stats.rate()
    );
    for (site, rate) in out.stats.site_rates() {
        println!("  recompute rate [{site}]: {:.4}%", 100.0 * rate);
    }
    println!("  logits[0][0][..4] = {:?}", &out.logits[0].row(0)[..4]);
    println!("  wall: {dt:.3}s");
    Ok(())
}

fn cmd_trials(args: &Args) -> lamp::Result<()> {
    match &args.subcommand {
        Some((name, sub)) => match name.as_str() {
            "run" => cmd_trials_run(sub),
            "list" => cmd_trials_list(),
            "diff" => cmd_trials_diff(sub),
            _ => unreachable!(),
        },
        None => Err(lamp::Error::config("trials: expected a subcommand (run|list|diff)")),
    }
}

fn cmd_trials_run(args: &Args) -> lamp::Result<()> {
    let spec = args.positionals()[0].clone();
    // A bundled name wins; anything else is read from disk, so CI and a
    // local `.trial` experiment go through the identical path.
    let text = match lamp::trials::builtin(&spec) {
        Some(t) => t.to_string(),
        None => std::fs::read_to_string(&spec).map_err(|e| {
            lamp::Error::config(format!(
                "{spec:?} is neither a bundled trial (see `lamp trials list`) \
                 nor a readable manifest file: {e}"
            ))
        })?,
    };
    let mut manifest = lamp::trials::TrialManifest::parse(&text)?;
    let workers = args.get_str("workers")?;
    if !workers.is_empty() {
        manifest.workers = workers
            .parse()
            .map_err(|_| lamp::Error::config(format!("--workers: bad count {workers:?}")))?;
    }
    let trace_out = args.get_str("trace-out")?;
    let metrics_out = args.get_str("metrics-out")?;
    // Observability rides along on a virtual-clock hub (replay drives the
    // ticks), so the exports below are deterministic across reruns; the
    // canonical artifact is byte-identical with or without the hub.
    let hub = if trace_out.is_empty() && metrics_out.is_empty() {
        None
    } else {
        let mut h = ObsHub::new().with_virtual_clock();
        if !trace_out.is_empty() {
            h = h.with_tracer(1 << 16);
        }
        Some(Arc::new(h))
    };
    let trial = lamp::trials::run_with_obs(&manifest, hub.clone())?;
    // Human-facing timing summary goes to stderr so stdout stays the
    // byte-exact canonical artifact (pipe it straight into `trials diff`).
    eprint!("{}", trial.display);
    let out = args.get_str("out")?;
    if out.is_empty() {
        print!("{}", trial.canonical);
    } else {
        std::fs::write(&out, &trial.canonical)?;
        eprintln!("wrote canonical artifact to {out}");
    }
    if let Some(hub) = hub {
        if !metrics_out.is_empty() {
            std::fs::write(&metrics_out, hub.registry().snapshot().to_json())?;
            eprintln!("wrote metrics snapshot to {metrics_out}");
        }
        if !trace_out.is_empty() {
            if let Some(tr) = hub.tracer() {
                std::fs::write(&trace_out, lamp::obs::trace::to_jsonl(&tr.events()))?;
                eprintln!(
                    "wrote span trace to {trace_out} ({} spans, {} dropped)",
                    tr.len(),
                    tr.dropped()
                );
            }
        }
    }
    Ok(())
}

fn cmd_trials_list() -> lamp::Result<()> {
    let mut t = Table::new(
        "bundled trials",
        &["name", "workload", "requests", "policy", "kv", "faults"],
    );
    for (name, text) in lamp::trials::BUILTIN {
        let m = lamp::trials::TrialManifest::parse(text)?;
        // Figure trials replay a paper-figure computation, not a trace;
        // show the driver and sweep size in the workload columns.
        let (workload, requests, policy) = match (&m.trace, &m.figure) {
            (Some(trace), _) => (
                trace.kind.name().to_string(),
                trace.requests.to_string(),
                m.policy_label.clone(),
            ),
            (None, Some(fig)) => (
                format!("figure:{}", fig.exp),
                format!("{} mu", fig.mu_grid.len()),
                format!("tau={} ladder", fig.tau),
            ),
            (None, None) => unreachable!("manifest build guarantees trace xor figure"),
        };
        t.row(vec![
            name.to_string(),
            workload,
            requests,
            policy,
            m.kv_format.map_or_else(|| "off".to_string(), |f| f.label()),
            m.fault_label.clone(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_trials_diff(args: &Args) -> lamp::Result<()> {
    let pos = args.positionals();
    let (pa, pb) = (&pos[0], &pos[1]);
    let a = std::fs::read_to_string(pa)?;
    let b = std::fs::read_to_string(pb)?;
    match lamp::trials::first_divergence(&a, &b) {
        None => {
            println!("identical: {} lines", a.lines().count());
            Ok(())
        }
        Some(d) => Err(lamp::Error::config(format!("{pa} vs {pb}: {d}"))),
    }
}

fn cmd_obs(args: &Args) -> lamp::Result<()> {
    match &args.subcommand {
        Some((name, sub)) => match name.as_str() {
            "metrics" => cmd_obs_metrics(sub),
            "trace" => cmd_obs_trace(sub),
            _ => unreachable!(),
        },
        None => Err(lamp::Error::config("obs: expected a subcommand (metrics|trace)")),
    }
}

fn cmd_obs_metrics(args: &Args) -> lamp::Result<()> {
    let path = args.positionals()[0].clone();
    let snap = lamp::obs::Snapshot::from_json(&std::fs::read_to_string(&path)?)?;
    match args.get_str("format")?.as_str() {
        "prometheus" => print!("{}", snap.to_prometheus()),
        "json" => print!("{}", snap.to_json()),
        other => {
            return Err(lamp::Error::config(format!(
                "unknown format {other:?} (prometheus|json)"
            )))
        }
    }
    Ok(())
}

fn cmd_obs_trace(args: &Args) -> lamp::Result<()> {
    let path = args.positionals()[0].clone();
    let mut events = lamp::obs::trace::parse_jsonl(&std::fs::read_to_string(&path)?);
    let total = events.len();
    let kind = args.get_str("kind")?;
    if !kind.is_empty() {
        let k = lamp::obs::SpanKind::parse(&kind)
            .ok_or_else(|| lamp::Error::config(format!("unknown span kind {kind:?}")))?;
        events.retain(|e| e.kind == k);
    }
    let request = args.get_str("request")?;
    if !request.is_empty() {
        let id: u64 = request
            .parse()
            .map_err(|_| lamp::Error::config(format!("--request: bad id {request:?}")))?;
        events.retain(|e| e.request == id);
    }
    if args.get_flag("chrome") {
        print!("{}", lamp::obs::trace::to_chrome(&events));
    } else {
        print!("{}", lamp::obs::trace::to_jsonl(&events));
    }
    eprintln!("{} of {total} span(s) kept", events.len());
    Ok(())
}

fn cmd_bench_diff(args: &Args) -> lamp::Result<()> {
    let pos = args.positionals();
    let (bpath, cpath) = (&pos[0], &pos[1]);
    let baseline = std::fs::read_to_string(bpath)?;
    let current = std::fs::read_to_string(cpath)?;
    let skip = args.get_str("skip")?;
    let opts = lamp::benchkit::DiffOptions {
        tolerance: args.get_f64("tolerance")?,
        perf_tolerance: args.get_f64("perf-tolerance")?,
        skip: if skip.is_empty() {
            Vec::new()
        } else {
            skip.split(',').map(|s| s.trim().to_string()).collect()
        },
    };
    let report = lamp::benchkit::bench_diff(&baseline, &current, &opts)?;
    print!("{}", report.render());
    if report.passed() {
        Ok(())
    } else {
        Err(lamp::Error::config(format!(
            "bench-diff: {} metric(s) failed the gate vs {bpath}",
            report.failures().len()
        )))
    }
}
