//! Key-value configuration system.
//!
//! Artifacts carry a `meta_<config>.kv` file describing the model that was
//! lowered (layers, heads, dims, vocab, seq len, batch). The same format
//! backs user-supplied experiment configs. Syntax: `key = value` lines,
//! `#` comments, sections via `[section]` prefixes flattened to
//! `section.key`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A flat, ordered key → string-value map with typed accessors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KvConfig {
    map: BTreeMap<String, String>,
}

impl KvConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = sec.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::format(format!("kv line {}: missing '=': {raw:?}", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, v.trim().to_string());
        }
        Ok(KvConfig { map })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    /// Serialize back to text (sorted keys, no sections).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.map {
            s.push_str(&format!("{k} = {v}\n"));
        }
        s
    }

    /// Write to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_text())?;
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::config(format!("missing config key {key:?}")))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.require(key)?
            .parse()
            .map_err(|e| Error::config(format!("{key}: {e}")))
    }

    pub fn get_u32(&self, key: &str) -> Result<u32> {
        self.require(key)?
            .parse()
            .map_err(|e| Error::config(format!("{key}: {e}")))
    }

    pub fn get_f32(&self, key: &str) -> Result<f32> {
        self.require(key)?
            .parse()
            .map_err(|e| Error::config(format!("{key}: {e}")))
    }

    pub fn get_bool(&self, key: &str) -> Result<bool> {
        match self.require(key)? {
            "true" | "1" | "yes" => Ok(true),
            "false" | "0" | "no" => Ok(false),
            other => Err(Error::config(format!("{key}: not a bool: {other:?}"))),
        }
    }

    /// usize with a default when the key is absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(_) => self.get_usize(key),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let c = KvConfig::parse("a = 1\nb=hello # comment\n# full comment\n").unwrap();
        assert_eq!(c.get_usize("a").unwrap(), 1);
        assert_eq!(c.get("b").unwrap(), "hello");
    }

    #[test]
    fn sections_flatten() {
        let c = KvConfig::parse("[model]\nlayers = 4\n[data]\nseed = 7\n").unwrap();
        assert_eq!(c.get_usize("model.layers").unwrap(), 4);
        assert_eq!(c.get_usize("data.seed").unwrap(), 7);
    }

    #[test]
    fn bad_line_rejected() {
        assert!(KvConfig::parse("novalue\n").is_err());
    }

    #[test]
    fn roundtrip() {
        let mut c = KvConfig::new();
        c.set("x", 3.5);
        c.set("name", "xl-sim");
        let c2 = KvConfig::parse(&c.to_text()).unwrap();
        assert_eq!(c, c2);
        assert_eq!(c2.get_f32("x").unwrap(), 3.5);
    }

    #[test]
    fn typed_errors() {
        let c = KvConfig::parse("x = notanumber\n").unwrap();
        assert!(c.get_usize("x").is_err());
        assert!(c.get_usize("missing").is_err());
        assert_eq!(c.usize_or("missing", 9).unwrap(), 9);
        assert!(c.usize_or("x", 9).is_err());
    }

    #[test]
    fn bools() {
        let c = KvConfig::parse("a = true\nb = 0\nc = maybe\n").unwrap();
        assert!(c.get_bool("a").unwrap());
        assert!(!c.get_bool("b").unwrap());
        assert!(c.get_bool("c").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut c = KvConfig::new();
        c.set("k", "v");
        let path = std::env::temp_dir().join("lamp_kv_test.kv");
        c.save(&path).unwrap();
        let c2 = KvConfig::load(&path).unwrap();
        assert_eq!(c, c2);
        let _ = std::fs::remove_file(path);
    }
}
