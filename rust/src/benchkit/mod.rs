//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations, robust statistics (median, MAD,
//! mean, p95), throughput reporting, aligned table output used by the
//! per-figure benches under `benches/`, and the cross-PR [`diff`] gate
//! that compares `BENCH_*.json` records against committed baselines.

pub mod diff;

pub use diff::{compare as bench_diff, DiffOptions, DiffReport};

use crate::util::timer::fmt_duration;
use std::time::{Duration, Instant};

/// Statistics over per-iteration wall-clock samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchStats {
    fn sorted_ns(&self) -> Vec<u128> {
        let mut v: Vec<u128> = self.samples.iter().map(|d| d.as_nanos()).collect();
        v.sort_unstable();
        v
    }

    pub fn median(&self) -> Duration {
        self.quantile(0.5)
    }

    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let total: u128 = self.samples.iter().map(|d| d.as_nanos()).sum();
        Duration::from_nanos((total / self.samples.len() as u128) as u64)
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or(Duration::ZERO)
    }

    pub fn p95(&self) -> Duration {
        self.quantile(0.95)
    }

    /// Nearest-rank quantile over the samples (the shared crate-wide
    /// convention, `metrics::stats::nearest_rank_index` — the old local
    /// floor-index copy reported the max sample as p95 for n ≤ 20).
    fn quantile(&self, q: f64) -> Duration {
        let v = self.sorted_ns();
        if v.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_nanos(v[crate::metrics::stats::nearest_rank_index(v.len(), q)] as u64)
    }

    /// Median absolute deviation (robust spread).
    pub fn mad(&self) -> Duration {
        let med = self.median().as_nanos() as i128;
        let mut devs: Vec<u128> = self
            .samples
            .iter()
            .map(|d| (d.as_nanos() as i128 - med).unsigned_abs())
            .collect();
        devs.sort_unstable();
        if devs.is_empty() {
            return Duration::ZERO;
        }
        let idx = crate::metrics::stats::nearest_rank_index(devs.len(), 0.5);
        Duration::from_nanos(devs[idx] as u64)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} median {:>10}  mean {:>10}  p95 {:>10}  mad {:>9}  n={}",
            self.name,
            fmt_duration(self.median()),
            fmt_duration(self.mean()),
            fmt_duration(self.p95()),
            fmt_duration(self.mad()),
            self.samples.len()
        )
    }
}

/// Benchmark runner: warms up, then collects timed samples.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Cap on total measured time; sampling stops early past this budget.
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            sample_iters: 15,
            max_total: Duration::from_secs(30),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, sample_iters: 5, max_total: Duration::from_secs(10) }
    }

    /// Time `f` repeatedly and collect statistics.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        let budget_start = Instant::now();
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
            if budget_start.elapsed() > self.max_total {
                break;
            }
        }
        BenchStats { name: name.to_string(), samples }
    }
}

/// Accumulates rows of a result table (one per paper figure series point)
/// and prints it aligned. Benches use this to emit the same rows/series the
/// paper reports.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Shared driver for the per-figure benches (`benches/figN.rs`): runs the
/// named experiment at bench scale, prints the regenerated tables, and
/// reports wall time. Scale is controlled by env vars LAMP_BENCH_SEQS /
/// LAMP_BENCH_SEQLEN / LAMP_BENCH_QUICK so `cargo bench` stays bounded.
pub fn run_experiment_bench(name: &str) {
    let opts = crate::experiments::EvalOptions {
        num_seqs: env_usize("LAMP_BENCH_SEQS", 4),
        seq_len: env_usize("LAMP_BENCH_SEQLEN", 48),
        stream_seed: 42,
        workers: env_usize("LAMP_BENCH_WORKERS", 8),
        artifacts: Some(
            crate::runtime::ArtifactStore::default_dir()
                .to_string_lossy()
                .to_string(),
        ),
        quick: env_bool("LAMP_BENCH_QUICK"),
    };
    let t0 = Instant::now();
    match crate::experiments::run(name, &opts) {
        Ok(tables) => {
            for t in &tables {
                t.print();
            }
            println!("[bench {name}] regenerated in {:.1}s\n", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("[bench {name}] FAILED: {e}");
            std::process::exit(1);
        }
    }
}

/// Parse a `usize` env knob, falling back to `default` when unset or
/// malformed. Shared by the bench binaries (`LAMP_BENCH_SEQS`, ...).
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Parse a boolean env gate by *truthiness*, not mere presence:
/// `1`/`true`/`yes`/`on` (case-insensitive) enable it; anything else —
/// including `0`, empty, and unset — disables it.
///
/// The previous `std::env::var(..).is_ok()` convention meant
/// `LAMP_BENCH_QUICK=0` (or `=""`) still silenced the full run; every
/// env gate goes through here now.
pub fn env_bool(key: &str) -> bool {
    matches!(
        std::env::var(key)
            .ok()
            .map(|s| s.trim().to_ascii_lowercase())
            .as_deref(),
        Some("1" | "true" | "yes" | "on")
    )
}

/// A flat JSON object rendered on one line — the unit the perf benches
/// record into `BENCH_PR1.json` (no serde offline, so rendering is
/// hand-rolled; keys appear in insertion order).
#[derive(Debug, Default, Clone)]
pub struct JsonObj {
    fields: Vec<(String, String)>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    fn push(&mut self, key: &str, rendered: String) {
        self.fields.retain(|(k, _)| k != key);
        self.fields.push((key.to_string(), rendered));
    }

    /// Add a numeric field (non-finite values render as `null`).
    pub fn num(mut self, key: &str, v: f64) -> Self {
        let r = if v.is_finite() { format!("{v}") } else { "null".to_string() };
        self.push(key, r);
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, v: u64) -> Self {
        self.push(key, v.to_string());
        self
    }

    /// Add a string field (minimal escaping: backslash and quote).
    pub fn str(mut self, key: &str, v: &str) -> Self {
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        self.push(key, format!("\"{escaped}\""));
        self
    }

    /// Render as a single-line JSON object.
    pub fn render(&self) -> String {
        let body = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{k}\": {v}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!("{{{body}}}")
    }
}

/// Insert or replace one named section of the shared benchmark record
/// (`BENCH_PR1.json`). The file is a JSON object whose top-level values
/// are single-line objects, one per line — a format this writer both
/// produces and parses, so independent benches can each contribute their
/// own section without clobbering the others.
pub fn record_bench_section(
    path: &std::path::Path,
    section: &str,
    body: &JsonObj,
) -> std::io::Result<()> {
    let mut sections: Vec<(String, String)> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for line in text.lines() {
            let line = line.trim().trim_end_matches(',');
            if line.is_empty() || line == "{" || line == "}" {
                continue;
            }
            if let Some((key, val)) = line.split_once(':') {
                let key = key.trim().trim_matches('"').to_string();
                sections.push((key, val.trim().to_string()));
            }
        }
    }
    sections.retain(|(k, _)| k != section);
    sections.push((section.to_string(), body.render()));
    let mut out = String::from("{\n");
    let body_lines = sections
        .iter()
        .map(|(k, v)| format!("  \"{k}\": {v}"))
        .collect::<Vec<_>>()
        .join(",\n");
    out.push_str(&body_lines);
    out.push_str("\n}\n");
    std::fs::write(path, out)
}

/// Default location of the PR-1 benchmark record (repo root), overridable
/// with `LAMP_BENCH_OUT`.
pub fn bench_record_path() -> std::path::PathBuf {
    std::env::var("LAMP_BENCH_OUT")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("BENCH_PR1.json"))
}

/// Format a float for table cells with adaptive precision.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 || x.abs() < 0.001 {
        format!("{x:.3e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let stats = BenchStats {
            name: "t".into(),
            samples: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
            ],
        };
        assert_eq!(stats.median(), Duration::from_millis(20));
        assert_eq!(stats.mean(), Duration::from_millis(20));
        assert_eq!(stats.min(), Duration::from_millis(10));
        assert!(stats.summary().contains("median"));
    }

    #[test]
    fn bencher_runs() {
        let b = Bencher { warmup_iters: 1, sample_iters: 4, max_total: Duration::from_secs(5) };
        let stats = b.run("noop", || 1 + 1);
        assert_eq!(stats.samples.len(), 4);
    }

    #[test]
    fn table_render_aligned() {
        let mut t = Table::new("demo", &["mu", "kl"]);
        t.row(vec!["4".into(), "0.123".into()]);
        t.row(vec!["10".into(), "0.00001".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("mu"));
        assert_eq!(r.lines().count(), 5);
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn json_obj_renders_and_replaces() {
        let o = JsonObj::new()
            .num("tok_s", 1234.5)
            .int("tokens", 240)
            .str("host", "4-core \"test\"")
            .num("bad", f64::NAN)
            .num("tok_s", 99.0); // replaces
        let r = o.render();
        assert!(r.starts_with('{') && r.ends_with('}'));
        assert!(r.contains("\"tokens\": 240"));
        assert!(r.contains("\\\"test\\\""));
        assert!(r.contains("\"bad\": null"));
        assert!(r.contains("\"tok_s\": 99"));
        assert!(!r.contains("1234.5"));
    }

    #[test]
    fn bench_sections_merge_without_clobbering() {
        let path = std::env::temp_dir().join("lamp_bench_record_test.json");
        let _ = std::fs::remove_file(&path);
        record_bench_section(&path, "decode", &JsonObj::new().num("speedup", 6.5)).unwrap();
        record_bench_section(&path, "kernels", &JsonObj::new().num("gflops", 1.25)).unwrap();
        record_bench_section(&path, "decode", &JsonObj::new().num("speedup", 7.0)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"kernels\""), "{text}");
        assert!(text.contains("7"), "{text}");
        assert!(!text.contains("6.5"), "replaced section leaked: {text}");
        assert_eq!(text.matches("\"decode\"").count(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn env_bool_is_truthiness_not_presence() {
        // Set/unset via a uniquely named var to avoid cross-test races.
        let key = "LAMP_TEST_ENV_BOOL_GATE";
        std::env::remove_var(key);
        assert!(!env_bool(key), "unset must be false");
        for truthy in ["1", "true", "YES", " on ", "True"] {
            std::env::set_var(key, truthy);
            assert!(env_bool(key), "{truthy:?} must enable the gate");
        }
        for falsy in ["0", "", "false", "no", "off", "2", "enabled"] {
            std::env::set_var(key, falsy);
            assert!(!env_bool(key), "{falsy:?} must NOT enable the gate");
        }
        std::env::remove_var(key);
    }

    #[test]
    fn p95_nearest_rank_over_twenty_samples() {
        let stats = BenchStats {
            name: "t".into(),
            samples: (1..=20).map(Duration::from_millis).collect(),
        };
        // Nearest-rank p95 of 20 samples is the 19th order statistic —
        // the old floor-index convention reported the max (20ms).
        assert_eq!(stats.p95(), Duration::from_millis(19));
        assert_eq!(stats.median(), Duration::from_millis(10));
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert!(fnum(1234.5).contains('e'));
        assert!(fnum(0.0001).contains('e'));
        assert_eq!(fnum(1.5), "1.5000");
    }
}
