//! Cross-PR bench-diff: compare a `BENCH_*.json` record against a
//! committed baseline with per-metric tolerances.
//!
//! The bench records written by [`super::record_bench_section`] are JSON
//! objects whose top-level values are single-line flat objects. This
//! module parses that exact shape (no serde offline), classifies every
//! metric by key, and reports which ones regressed:
//!
//! * **higher-better** (`*tok_s`, `*speedup`, …) fails when the current
//!   value drops more than `perf_tolerance` below the baseline;
//! * **lower-better** (`*_ms`, `*latency*`, …) fails when it rises more
//!   than `perf_tolerance` above;
//! * **two-sided** (counts, rates — the default) fails on any relative
//!   change beyond `tolerance`, which defaults to exact;
//! * **informational** (`host_cores`, `pool_threads`, …) never fails.
//!
//! Only metrics present in the *baseline* gate: a baseline can therefore
//! commit just the configuration-constant subset of a record (counts and
//! descriptor strings) and still catch a bench that silently stops
//! reporting a metric — missing-in-current is always a failure. Metrics
//! the current run adds are reported as informational drift.

use crate::error::{Error, Result};

/// A parsed bench-record value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Num(f64),
    Str(String),
    Null,
}

impl JsonValue {
    fn render(&self) -> String {
        match self {
            JsonValue::Num(x) => format!("{x}"),
            JsonValue::Str(s) => format!("{s:?}"),
            JsonValue::Null => "null".to_string(),
        }
    }
}

/// How a metric is judged, decided from its key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricClass {
    HigherBetter,
    LowerBetter,
    TwoSided,
    Informational,
}

impl MetricClass {
    pub fn label(self) -> &'static str {
        match self {
            MetricClass::HigherBetter => "higher-better",
            MetricClass::LowerBetter => "lower-better",
            MetricClass::TwoSided => "two-sided",
            MetricClass::Informational => "informational",
        }
    }
}

/// Classify a metric key by substring, most specific list first.
/// Environment-shaped keys are informational; throughputs are
/// higher-better; durations and sizes are lower-better; everything else
/// (counts, recompute rates) must match the baseline exactly.
pub fn classify(key: &str) -> MetricClass {
    const INFORMATIONAL: [&str; 5] = ["host", "cores", "threads", "workers", "wall_s"];
    const HIGHER: [&str; 4] = ["tok_s", "speedup", "gflops", "throughput"];
    const LOWER: [&str; 4] = ["_ms", "latency", "bytes", "_ns"];
    if INFORMATIONAL.iter().any(|p| key.contains(p)) {
        MetricClass::Informational
    } else if HIGHER.iter().any(|p| key.contains(p)) {
        MetricClass::HigherBetter
    } else if LOWER.iter().any(|p| key.contains(p)) {
        MetricClass::LowerBetter
    } else {
        MetricClass::TwoSided
    }
}

/// Comparison tolerances.
#[derive(Debug, Clone)]
pub struct DiffOptions {
    /// Relative tolerance for two-sided metrics (default: exact match).
    pub tolerance: f64,
    /// Allowed fractional perf regression for higher/lower-better metrics
    /// (default 0.25: CI machines are noisy; the gate is for collapses,
    /// not single-digit scatter).
    pub perf_tolerance: f64,
    /// Keys (or `section.key` paths) excluded from the comparison.
    pub skip: Vec<String>,
}

impl Default for DiffOptions {
    fn default() -> Self {
        DiffOptions { tolerance: 1e-9, perf_tolerance: 0.25, skip: Vec::new() }
    }
}

impl DiffOptions {
    fn skipped(&self, section: &str, key: &str) -> bool {
        let path = format!("{section}.{key}");
        self.skip.iter().any(|s| s == key || *s == path)
    }
}

/// Verdict for one metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DiffStatus {
    /// Unchanged (within tolerance zero).
    Pass,
    /// Changed but not gating: within tolerance, informational, string
    /// drift, or a metric the baseline does not know.
    Drift,
    /// Out of tolerance in the bad direction (or type changed).
    Regression,
    /// Present in the baseline, absent from the current record.
    Missing,
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct MetricDiff {
    pub section: String,
    pub key: String,
    pub class: MetricClass,
    pub baseline: String,
    pub current: String,
    /// Relative change for numeric pairs.
    pub rel: Option<f64>,
    pub status: DiffStatus,
    pub note: String,
}

/// The full comparison result.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub diffs: Vec<MetricDiff>,
}

impl DiffReport {
    /// Metrics that gate (regressions and missing metrics).
    pub fn failures(&self) -> Vec<&MetricDiff> {
        self.diffs
            .iter()
            .filter(|d| matches!(d.status, DiffStatus::Regression | DiffStatus::Missing))
            .collect()
    }

    pub fn passed(&self) -> bool {
        self.failures().is_empty()
    }

    /// Human-readable report: one line per non-passing metric plus a
    /// summary tail.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diffs {
            let tag = match d.status {
                DiffStatus::Pass => continue,
                DiffStatus::Drift => "drift",
                DiffStatus::Regression => "FAIL",
                DiffStatus::Missing => "FAIL",
            };
            let rel = match d.rel {
                Some(r) => format!(" ({:+.1}%)", 100.0 * r),
                None => String::new(),
            };
            out.push_str(&format!(
                "[{tag}] {}.{}: {} -> {}{rel} [{}] {}\n",
                d.section,
                d.key,
                d.baseline,
                d.current,
                d.class.label(),
                d.note
            ));
        }
        let failures = self.failures().len();
        out.push_str(&format!(
            "bench-diff: {} metrics compared, {} failure{}\n",
            self.diffs.len(),
            failures,
            if failures == 1 { "" } else { "s" }
        ));
        out
    }
}

/// Parse a bench record: top-level JSON object, one single-line flat
/// object per section per line (the exact shape
/// [`super::record_bench_section`] writes).
pub fn parse_bench_text(text: &str) -> Result<Vec<(String, Vec<(String, JsonValue)>)>> {
    let mut out: Vec<(String, Vec<(String, JsonValue)>)> = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() || line == "{" || line == "}" {
            continue;
        }
        let (key, val) = line.split_once(':').ok_or_else(|| {
            Error::config(format!("bench record line is not a section: {line:?}"))
        })?;
        let section = key.trim().trim_matches('"').to_string();
        out.push((section, parse_flat_object(val.trim())?));
    }
    Ok(out)
}

/// Parse one single-line flat JSON object (string values may contain
/// commas and escaped quotes; numbers may be scientific; `null` allowed).
fn parse_flat_object(s: &str) -> Result<Vec<(String, JsonValue)>> {
    let inner = s
        .strip_prefix('{')
        .and_then(|t| t.strip_suffix('}'))
        .ok_or_else(|| Error::config(format!("bench section is not a flat object: {s:?}")))?;
    let chars: Vec<char> = inner.chars().collect();
    let n = chars.len();
    let mut i = 0usize;
    let mut out = Vec::new();
    loop {
        while i < n && (chars[i].is_whitespace() || chars[i] == ',') {
            i += 1;
        }
        if i >= n {
            break;
        }
        if chars[i] != '"' {
            return Err(Error::config(format!("expected a quoted key in {s:?}")));
        }
        i += 1;
        let key = read_string(&chars, &mut i)?;
        while i < n && chars[i].is_whitespace() {
            i += 1;
        }
        if i >= n || chars[i] != ':' {
            return Err(Error::config(format!("missing ':' after key {key:?}")));
        }
        i += 1;
        while i < n && chars[i].is_whitespace() {
            i += 1;
        }
        let value = if i < n && chars[i] == '"' {
            i += 1;
            JsonValue::Str(read_string(&chars, &mut i)?)
        } else {
            let start = i;
            while i < n && chars[i] != ',' {
                i += 1;
            }
            let token: String = chars[start..i].iter().collect();
            let token = token.trim();
            if token == "null" {
                JsonValue::Null
            } else {
                JsonValue::Num(token.parse().map_err(|_| {
                    Error::config(format!("bad numeric value {token:?} for key {key:?}"))
                })?)
            }
        };
        out.push((key, value));
    }
    Ok(out)
}

/// Read a string body; `i` points past the opening quote and is left past
/// the closing one. Escapes are the two `record_bench_section` emits.
fn read_string(chars: &[char], i: &mut usize) -> Result<String> {
    let mut out = String::new();
    while *i < chars.len() {
        match chars[*i] {
            '\\' => {
                *i += 1;
                if *i < chars.len() {
                    out.push(chars[*i]);
                    *i += 1;
                }
            }
            '"' => {
                *i += 1;
                return Ok(out);
            }
            c => {
                out.push(c);
                *i += 1;
            }
        }
    }
    Err(Error::config("unterminated string in bench record"))
}

/// Compare two bench-record texts. Baseline metrics gate; current-only
/// metrics are informational.
pub fn compare(baseline: &str, current: &str, opts: &DiffOptions) -> Result<DiffReport> {
    let base = parse_bench_text(baseline)?;
    let cur = parse_bench_text(current)?;
    let mut diffs = Vec::new();

    for (section, fields) in &base {
        let cur_fields = cur.iter().find(|(s, _)| s == section).map(|(_, f)| f);
        for (key, bval) in fields {
            if opts.skipped(section, key) {
                continue;
            }
            let cval = cur_fields.and_then(|f| f.iter().find(|(k, _)| k == key));
            diffs.push(diff_metric(section, key, bval, cval.map(|(_, v)| v), opts));
        }
    }
    for (section, fields) in &cur {
        let base_fields = base.iter().find(|(s, _)| s == section).map(|(_, f)| f);
        for (key, cval) in fields {
            if opts.skipped(section, key) {
                continue;
            }
            let known = base_fields.is_some_and(|f| f.iter().any(|(k, _)| k == key));
            if !known {
                diffs.push(MetricDiff {
                    section: section.clone(),
                    key: key.clone(),
                    class: classify(key),
                    baseline: "absent".to_string(),
                    current: cval.render(),
                    rel: None,
                    status: DiffStatus::Drift,
                    note: "not in baseline".to_string(),
                });
            }
        }
    }
    Ok(DiffReport { diffs })
}

fn diff_metric(
    section: &str,
    key: &str,
    baseline: &JsonValue,
    current: Option<&JsonValue>,
    opts: &DiffOptions,
) -> MetricDiff {
    let class = classify(key);
    let mut d = MetricDiff {
        section: section.to_string(),
        key: key.to_string(),
        class,
        baseline: baseline.render(),
        current: "absent".to_string(),
        rel: None,
        status: DiffStatus::Pass,
        note: String::new(),
    };
    let Some(current) = current else {
        d.status = DiffStatus::Missing;
        d.note = "metric disappeared from the current record".to_string();
        return d;
    };
    d.current = current.render();

    match (baseline, current) {
        (JsonValue::Str(b), JsonValue::Str(c)) => {
            if b != c {
                d.status = DiffStatus::Drift;
                d.note = "descriptor changed".to_string();
            }
        }
        (JsonValue::Null, JsonValue::Null) => {}
        (JsonValue::Num(b), JsonValue::Num(c)) => {
            let rel = (c - b) / b.abs().max(1e-12);
            d.rel = Some(rel);
            let (bad, tol) = match class {
                MetricClass::Informational => (false, f64::INFINITY),
                MetricClass::HigherBetter => (rel < -opts.perf_tolerance, opts.perf_tolerance),
                MetricClass::LowerBetter => (rel > opts.perf_tolerance, opts.perf_tolerance),
                MetricClass::TwoSided => {
                    ((c - b).abs() > opts.tolerance * b.abs().max(1.0), opts.tolerance)
                }
            };
            if bad {
                d.status = DiffStatus::Regression;
                d.note = format!("beyond the {:.1}% tolerance", 100.0 * tol);
            } else if c != b {
                d.status = DiffStatus::Drift;
                d.note = "within tolerance".to_string();
            }
        }
        _ => {
            d.status = DiffStatus::Regression;
            d.note = "value type changed".to_string();
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(sections: &[(&str, &str)]) -> String {
        let body = sections
            .iter()
            .map(|(name, obj)| format!("  \"{name}\": {obj}"))
            .collect::<Vec<_>>()
            .join(",\n");
        format!("{{\n{body}\n}}\n")
    }

    const BASE: &str = r#"{"requests": 8, "continuous_tok_s": 1200.5, "ttft_p95_ms": 40.0, "host_cores": 8, "model": "4 layers, d=128"}"#;

    #[test]
    fn identical_records_pass() {
        let a = rec(&[("serving_load", BASE)]);
        let report = compare(&a, &a, &DiffOptions::default()).unwrap();
        assert!(report.passed(), "{}", report.render());
        assert!(report.diffs.iter().all(|d| d.status == DiffStatus::Pass));
    }

    #[test]
    fn tolerated_drift_passes_and_is_reported() {
        let a = rec(&[("serving_load", BASE)]);
        // 10% throughput drop and 10% TTFT rise: inside the 25% gate.
        let b = rec(&[(
            "serving_load",
            r#"{"requests": 8, "continuous_tok_s": 1080.45, "ttft_p95_ms": 44.0, "host_cores": 8, "model": "4 layers, d=128"}"#,
        )]);
        let report = compare(&a, &b, &DiffOptions::default()).unwrap();
        assert!(report.passed(), "{}", report.render());
        let drifted: Vec<&str> = report
            .diffs
            .iter()
            .filter(|d| d.status == DiffStatus::Drift)
            .map(|d| d.key.as_str())
            .collect();
        assert_eq!(drifted, vec!["continuous_tok_s", "ttft_p95_ms"]);
    }

    #[test]
    fn perf_regression_fails_both_directions() {
        let a = rec(&[("serving_load", BASE)]);
        // Throughput halves (higher-better) and TTFT doubles (lower-better).
        let b = rec(&[(
            "serving_load",
            r#"{"requests": 8, "continuous_tok_s": 600.0, "ttft_p95_ms": 80.0, "host_cores": 8, "model": "4 layers, d=128"}"#,
        )]);
        let report = compare(&a, &b, &DiffOptions::default()).unwrap();
        let failed: Vec<&str> = report.failures().iter().map(|d| d.key.as_str()).collect();
        assert_eq!(failed, vec!["continuous_tok_s", "ttft_p95_ms"]);
        assert!(report.render().contains("FAIL"));
    }

    #[test]
    fn missing_metric_fails() {
        let a = rec(&[("serving_load", BASE)]);
        let b = rec(&[(
            "serving_load",
            r#"{"requests": 8, "ttft_p95_ms": 40.0, "host_cores": 8, "model": "4 layers, d=128"}"#,
        )]);
        let report = compare(&a, &b, &DiffOptions::default()).unwrap();
        let failures = report.failures();
        assert_eq!(failures.len(), 1);
        assert_eq!(failures[0].key, "continuous_tok_s");
        assert_eq!(failures[0].status, DiffStatus::Missing);
        // A missing whole section fails every metric of that section.
        let empty = rec(&[("other", r#"{"x": 1}"#)]);
        let report = compare(&a, &empty, &DiffOptions::default()).unwrap();
        assert_eq!(report.failures().len(), 5);
    }

    #[test]
    fn counts_gate_exactly_but_informational_never_fails() {
        let a = rec(&[("serving_load", BASE)]);
        let b = rec(&[(
            "serving_load",
            r#"{"requests": 9, "continuous_tok_s": 1200.5, "ttft_p95_ms": 40.0, "host_cores": 64, "model": "4 layers, d=128"}"#,
        )]);
        let report = compare(&a, &b, &DiffOptions::default()).unwrap();
        let failed: Vec<&str> = report.failures().iter().map(|d| d.key.as_str()).collect();
        assert_eq!(failed, vec!["requests"], "host_cores must stay informational");
    }

    #[test]
    fn string_drift_and_extra_metrics_are_informational() {
        let a = rec(&[("serving_load", r#"{"model": "4 layers", "requests": 8}"#)]);
        let b = rec(&[(
            "serving_load",
            r#"{"model": "5 layers", "requests": 8, "brand_new_metric": 3.5}"#,
        )]);
        let report = compare(&a, &b, &DiffOptions::default()).unwrap();
        assert!(report.passed(), "{}", report.render());
        assert_eq!(
            report.diffs.iter().filter(|d| d.status == DiffStatus::Drift).count(),
            2
        );
    }

    #[test]
    fn skip_list_silences_metrics() {
        let a = rec(&[("serving_load", r#"{"requests": 8, "continuous_tok_s": 1000.0}"#)]);
        let b = rec(&[("serving_load", r#"{"requests": 9, "continuous_tok_s": 10.0}"#)]);
        let opts = DiffOptions {
            skip: vec!["serving_load.requests".to_string(), "continuous_tok_s".to_string()],
            ..Default::default()
        };
        let report = compare(&a, &b, &opts).unwrap();
        assert!(report.passed());
        assert!(report.diffs.is_empty());
    }

    #[test]
    fn parser_handles_commas_escapes_scientific_and_null() {
        let obj = super::super::JsonObj::new()
            .str("workload", r#"Zipf(s=1.1), 3 policies, "mixed" sampling"#)
            .num("tiny", 1.5e-7)
            .num("nan_becomes_null", f64::NAN)
            .int("count", 42);
        let text = rec(&[("sec", &obj.render())]);
        let parsed = parse_bench_text(&text).unwrap();
        assert_eq!(parsed.len(), 1);
        let fields = &parsed[0].1;
        assert_eq!(
            fields[0].1,
            JsonValue::Str(r#"Zipf(s=1.1), 3 policies, "mixed" sampling"#.to_string())
        );
        assert_eq!(fields[1].1, JsonValue::Num(1.5e-7));
        assert_eq!(fields[2].1, JsonValue::Null);
        assert_eq!(fields[3].1, JsonValue::Num(42.0));
        // Round-trip through compare: identical text passes.
        assert!(compare(&text, &text, &DiffOptions::default()).unwrap().passed());
    }

    #[test]
    fn malformed_records_error() {
        assert!(parse_bench_text("not json at all").is_err());
        assert!(parse_flat_object(r#"{"k": }"#).is_err());
        assert!(parse_flat_object(r#"{"k": "unterminated}"#).is_err());
        assert!(parse_flat_object(r#"{"k": bogus}"#).is_err());
    }

    #[test]
    fn classification_is_substring_based() {
        assert_eq!(classify("continuous_tok_s"), MetricClass::HigherBetter);
        assert_eq!(classify("speedup"), MetricClass::HigherBetter);
        assert_eq!(classify("ttft_p95_ms"), MetricClass::LowerBetter);
        assert_eq!(classify("kv_resident_bytes"), MetricClass::LowerBetter);
        assert_eq!(classify("host_cores"), MetricClass::Informational);
        assert_eq!(classify("pool_threads"), MetricClass::Informational);
        assert_eq!(classify("requests"), MetricClass::TwoSided);
        assert_eq!(classify("whole_rate_mlp"), MetricClass::TwoSided);
    }
}
