//! Declarative CLI parsing.
//!
//! ```no_run
//! use lamp::cli::{Command, ArgSpec};
//! let cmd = Command::new("demo", "demo tool")
//!     .arg(ArgSpec::opt("mu", "mantissa bits", "4"))
//!     .arg(ArgSpec::flag("verbose", "chatty output"));
//! let args = cmd.parse_from(vec!["--mu".into(), "7".into()]).unwrap();
//! assert_eq!(args.get_u32("mu").unwrap(), 7);
//! assert!(!args.get_flag("verbose"));
//! ```

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Specification of a single option/flag/positional.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
    pub is_flag: bool,
    pub positional: bool,
    pub required: bool,
}

impl ArgSpec {
    /// `--name <value>` option with a default.
    pub fn opt(name: &str, help: &str, default: &str) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
            positional: false,
            required: false,
        }
    }

    /// `--name <value>` required option.
    pub fn req(name: &str, help: &str) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            positional: false,
            required: true,
        }
    }

    /// Boolean `--name` flag.
    pub fn flag(name: &str, help: &str) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
            positional: false,
            required: false,
        }
    }

    /// Positional argument.
    pub fn pos(name: &str, help: &str, required: bool) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
            positional: true,
            required,
        }
    }
}

/// A command (or subcommand) definition.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: String,
    pub about: String,
    pub specs: Vec<ArgSpec>,
    pub subcommands: Vec<Command>,
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: HashMap<String, String>,
    flags: HashMap<String, bool>,
    positionals: Vec<String>,
    /// Name of the matched subcommand (if any) and its parsed args.
    pub subcommand: Option<(String, Box<Args>)>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), specs: Vec::new(), subcommands: Vec::new() }
    }

    pub fn arg(mut self, spec: ArgSpec) -> Self {
        self.specs.push(spec);
        self
    }

    pub fn subcommand(mut self, cmd: Command) -> Self {
        self.subcommands.push(cmd);
        self
    }

    /// Generated usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str(" <SUBCOMMAND>");
        }
        for spec in &self.specs {
            if spec.positional {
                s.push_str(&format!(" <{}>", spec.name));
            }
        }
        s.push_str(" [OPTIONS]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for sc in &self.subcommands {
                s.push_str(&format!("  {:<16} {}\n", sc.name, sc.about));
            }
        }
        if !self.specs.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for spec in &self.specs {
                let lhs = if spec.is_flag {
                    format!("--{}", spec.name)
                } else if spec.positional {
                    format!("<{}>", spec.name)
                } else {
                    format!("--{} <v>", spec.name)
                };
                let def = spec
                    .default
                    .as_ref()
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {lhs:<20} {}{def}\n", spec.help));
            }
        }
        s
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse(&self) -> Result<Args> {
        self.parse_from(std::env::args().skip(1).collect())
    }

    /// Parse from an explicit token list.
    pub fn parse_from(&self, tokens: Vec<String>) -> Result<Args> {
        let mut args = Args {
            values: HashMap::new(),
            flags: HashMap::new(),
            positionals: Vec::new(),
            subcommand: None,
        };
        // Seed defaults.
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                args.values.insert(spec.name.clone(), d.clone());
            }
            if spec.is_flag {
                args.flags.insert(spec.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped == "help" {
                    return Err(Error::config(self.usage()));
                }
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key && !s.positional)
                    .ok_or_else(|| Error::config(format!("unknown option --{key}\n\n{}", self.usage())))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(Error::config(format!("flag --{key} takes no value")));
                    }
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| Error::config(format!("--{key} needs a value")))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else if args.positionals.is_empty()
                && args.subcommand.is_none()
                && self.subcommands.iter().any(|c| c.name == *tok)
            {
                let sub = self.subcommands.iter().find(|c| c.name == *tok).unwrap();
                let rest = tokens[i + 1..].to_vec();
                let sub_args = sub.parse_from(rest)?;
                args.subcommand = Some((tok.clone(), Box::new(sub_args)));
                break;
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        // Validate required.
        for spec in &self.specs {
            if spec.required && !spec.positional && !args.values.contains_key(&spec.name) {
                return Err(Error::config(format!("missing required --{}", spec.name)));
            }
        }
        let required_pos = self.specs.iter().filter(|s| s.positional && s.required).count();
        if args.positionals.len() < required_pos && args.subcommand.is_none() {
            return Err(Error::config(format!(
                "expected {required_pos} positional argument(s)\n\n{}",
                self.usage()
            )));
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> Result<String> {
        self.get(name)
            .map(|s| s.to_string())
            .ok_or_else(|| Error::config(format!("missing --{name}")))
    }

    pub fn get_u32(&self, name: &str) -> Result<u32> {
        self.get_str(name)?
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get_str(name)?
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get_str(name)?
            .parse()
            .map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        let s = self.get_str(name)?;
        if s == "inf" {
            return Ok(f32::INFINITY);
        }
        s.parse().map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let s = self.get_str(name)?;
        if s == "inf" {
            return Ok(f64::INFINITY);
        }
        s.parse().map_err(|e| Error::config(format!("--{name}: {e}")))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Parse a comma-separated list of values, e.g. `--mus 2,4,7,10`.
    pub fn get_list_u32(&self, name: &str) -> Result<Vec<u32>> {
        self.get_str(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| Error::config(format!("--{name}: {e}"))))
            .collect()
    }

    /// Parse a comma-separated list of f32 values.
    pub fn get_list_f32(&self, name: &str) -> Result<Vec<f32>> {
        self.get_str(name)?
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse().map_err(|e| Error::config(format!("--{name}: {e}"))))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Command {
        Command::new("demo", "test tool")
            .arg(ArgSpec::opt("mu", "mantissa bits", "4"))
            .arg(ArgSpec::opt("tau", "threshold", "0.1"))
            .arg(ArgSpec::flag("verbose", "chatty"))
            .arg(ArgSpec::req("model", "model name"))
    }

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let args = demo().parse_from(v(&["--model", "xl", "--mu=7"])).unwrap();
        assert_eq!(args.get_u32("mu").unwrap(), 7);
        assert_eq!(args.get_f32("tau").unwrap(), 0.1);
        assert_eq!(args.get_str("model").unwrap(), "xl");
        assert!(!args.get_flag("verbose"));
    }

    #[test]
    fn flags() {
        let args = demo().parse_from(v(&["--model", "s", "--verbose"])).unwrap();
        assert!(args.get_flag("verbose"));
    }

    #[test]
    fn missing_required() {
        assert!(demo().parse_from(v(&["--mu", "3"])).is_err());
    }

    #[test]
    fn unknown_option() {
        assert!(demo().parse_from(v(&["--model", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn missing_value() {
        assert!(demo().parse_from(v(&["--model"])).is_err());
    }

    #[test]
    fn subcommands() {
        let cmd = Command::new("lamp", "root")
            .subcommand(Command::new("exp", "experiments").arg(ArgSpec::opt("n", "count", "1")));
        let args = cmd.parse_from(v(&["exp", "--n", "5"])).unwrap();
        let (name, sub) = args.subcommand.unwrap();
        assert_eq!(name, "exp");
        assert_eq!(sub.get_u32("n").unwrap(), 5);
    }

    #[test]
    fn lists() {
        let cmd = Command::new("t", "").arg(ArgSpec::opt("mus", "", "2,4,7"));
        let args = cmd.parse_from(vec![]).unwrap();
        assert_eq!(args.get_list_u32("mus").unwrap(), vec![2, 4, 7]);
        let args = cmd.parse_from(v(&["--mus", "1, 2 ,3"])).unwrap();
        assert_eq!(args.get_list_u32("mus").unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn inf_parse() {
        let cmd = Command::new("t", "").arg(ArgSpec::opt("tau", "", "inf"));
        let args = cmd.parse_from(vec![]).unwrap();
        assert!(args.get_f32("tau").unwrap().is_infinite());
    }

    #[test]
    fn positionals() {
        let cmd = Command::new("t", "").arg(ArgSpec::pos("file", "input", true));
        let args = cmd.parse_from(v(&["a.txt"])).unwrap();
        assert_eq!(args.positionals(), &["a.txt".to_string()]);
        assert!(cmd.parse_from(vec![]).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = demo().usage();
        assert!(u.contains("--mu"));
        assert!(u.contains("default: 4"));
    }
}
