//! Hand-rolled command-line argument parser (no clap offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with typed accessors and defaults, positional arguments, and generated
//! usage text.

pub mod parser;

pub use parser::{ArgSpec, Args, Command};
