//! Deterministic fault injection for the serving plane.
//!
//! Chaos testing in the same spirit as the bit-exactness harness: every
//! fault is a *pure function of a seed*, never of wall-clock time or
//! thread interleaving, so any failure a chaos run surfaces is replayable
//! from its [`FaultPlan`] alone.
//!
//! * [`FaultPlan`] — a seeded schedule of fault rates: transient
//!   decode-step errors, `Error::Resource` spikes, artificial per-step
//!   latency, permanent session poisoning, and tensor-load I/O failures
//!   at session open.
//! * [`FaultInjector`] — an [`Engine`] decorator that installs the plan
//!   as a [`StepFaults`] hook on every decode session it opens and
//!   injects open-time I/O failures itself. All other engine surface is
//!   delegated unchanged, so the scheduler and server cannot tell they
//!   are running over chaos — which is the point.
//! * [`FaultStats`] — counters of everything injected, surfaced through
//!   [`Engine::fault_stats`] into `DecodeMetrics`/`ServerStats`.
//!
//! Fault draws are keyed by `(plan.seed, session_seed, position,
//! attempt)`. The `attempt` key (consecutive injected failures already
//! served at that position) makes transient faults clear on retry while
//! still allowing schedules that exhaust a retry budget.

use super::engine::{Engine, EngineOutput};
use super::policy::PrecisionPolicy;
use crate::error::{Error, Result};
use crate::linalg::WeightFormat;
use crate::model::{
    DecodeSession, KvBlockPool, ModelConfig, StepFaultVerdict, StepFaults,
};
use crate::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A seeded, deterministic chaos schedule. All rates are per-event
/// probabilities in `[0, 1]`; a rate of 0 disables that fault class.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Root seed — two runs with the same plan and workload inject the
    /// same faults at the same `(session, position)` sites.
    pub seed: u64,
    /// Per-step probability of a retryable `Error::Transient` failure.
    pub step_error_rate: f64,
    /// Per-step probability of an injected `Error::Resource` spike
    /// (exercises the preempt/retry machinery without a full pool).
    pub resource_spike_rate: f64,
    /// Per-step probability of permanently poisoning the session — a
    /// non-retryable failure that terminates exactly its own request.
    pub poison_rate: f64,
    /// Probability that opening a decode session fails with a
    /// (non-retryable) tensor-load I/O error.
    pub io_error_rate: f64,
    /// Per-step probability of an artificial latency of [`Self::delay`].
    pub delay_rate: f64,
    /// The injected per-step latency when a delay draw fires.
    pub delay: Duration,
}

impl FaultPlan {
    /// All-zero rates: the injector becomes a transparent pass-through.
    pub fn quiet(seed: u64) -> Self {
        FaultPlan {
            seed,
            step_error_rate: 0.0,
            resource_spike_rate: 0.0,
            poison_rate: 0.0,
            io_error_rate: 0.0,
            delay_rate: 0.0,
            delay: Duration::ZERO,
        }
    }

    /// A moderate all-fault-classes schedule for chaos suites: frequent
    /// transient errors and delays, occasional resource spikes, rare
    /// terminal faults (poison / open-time I/O).
    pub fn chaos(seed: u64) -> Self {
        FaultPlan {
            seed,
            step_error_rate: 0.05,
            resource_spike_rate: 0.02,
            poison_rate: 0.005,
            io_error_rate: 0.03,
            delay_rate: 0.05,
            delay: Duration::from_micros(200),
        }
    }

    pub fn with_step_errors(mut self, rate: f64) -> Self {
        self.step_error_rate = rate;
        self
    }
    pub fn with_resource_spikes(mut self, rate: f64) -> Self {
        self.resource_spike_rate = rate;
        self
    }
    pub fn with_poison(mut self, rate: f64) -> Self {
        self.poison_rate = rate;
        self
    }
    pub fn with_io_errors(mut self, rate: f64) -> Self {
        self.io_error_rate = rate;
        self
    }
    pub fn with_delay(mut self, rate: f64, delay: Duration) -> Self {
        self.delay_rate = rate;
        self.delay = delay;
        self
    }

    /// Rates must be probabilities.
    pub fn validate(&self) -> Result<()> {
        for (name, r) in [
            ("step_error_rate", self.step_error_rate),
            ("resource_spike_rate", self.resource_spike_rate),
            ("poison_rate", self.poison_rate),
            ("io_error_rate", self.io_error_rate),
            ("delay_rate", self.delay_rate),
        ] {
            if !(0.0..=1.0).contains(&r) || r.is_nan() {
                return Err(Error::config(format!(
                    "fault plan: {name} = {r} is not a probability"
                )));
            }
        }
        Ok(())
    }
}

/// Injection counters (monotonic, shared between the injector and the
/// hooks it installed on live sessions).
#[derive(Debug, Default)]
struct FaultCounters {
    step_errors: AtomicUsize,
    resource_spikes: AtomicUsize,
    poisons: AtomicUsize,
    io_errors: AtomicUsize,
    delays: AtomicUsize,
}

/// Snapshot of everything a [`FaultInjector`] has injected so far.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Retryable `Error::Transient` decode-step failures injected.
    pub step_errors: usize,
    /// `Error::Resource` spikes injected.
    pub resource_spikes: usize,
    /// Sessions permanently poisoned.
    pub poisons: usize,
    /// Session opens failed with an I/O error.
    pub io_errors: usize,
    /// Steps artificially delayed.
    pub delays: usize,
}

impl FaultStats {
    /// Total faults injected (delays included — they perturb timing,
    /// which is what deadline tests care about).
    pub fn total(&self) -> usize {
        self.step_errors + self.resource_spikes + self.poisons + self.io_errors + self.delays
    }
}

/// Derive the per-check RNG for one `(session, position, attempt)` site.
/// Distinct keys land on distinct streams; identical keys replay exactly.
fn site_rng(plan_seed: u64, domain: u64, session_seed: u64, pos: u64, attempt: u64) -> Rng {
    let mut mix = Rng::new(plan_seed ^ domain.rotate_left(48));
    let a = mix.fork(session_seed).next_u64();
    let b = mix.fork(pos.wrapping_add(0x9e37_79b9_7f4a_7c15)).next_u64();
    let c = mix.fork(attempt.wrapping_add(0x6a09_e667_f3bc_c909)).next_u64();
    Rng::new(a ^ b.rotate_left(21) ^ c.rotate_left(42))
}

const DOMAIN_STEP: u64 = 0x5354_4550; // "STEP"
const DOMAIN_OPEN: u64 = 0x4f50_454e; // "OPEN"

/// The seeded [`StepFaults`] hook a [`FaultInjector`] installs on every
/// session it opens.
struct SeededFaults {
    plan: FaultPlan,
    counters: Arc<FaultCounters>,
}

impl StepFaults for SeededFaults {
    fn check(&self, session_seed: u64, pos: usize, attempt: u32) -> StepFaultVerdict {
        let p = &self.plan;
        let mut rng =
            site_rng(p.seed, DOMAIN_STEP, session_seed, pos as u64, attempt as u64);
        // Fixed draw order keeps the schedule stable when individual
        // rates change between runs of the same seed.
        let (poison, resource, step, delay) =
            (rng.f64(), rng.f64(), rng.f64(), rng.f64());
        if poison < p.poison_rate {
            self.counters.poisons.fetch_add(1, Ordering::Relaxed);
            return StepFaultVerdict::Poison(format!(
                "injected fault (seed {}, pos {pos})",
                p.seed
            ));
        }
        if resource < p.resource_spike_rate {
            self.counters.resource_spikes.fetch_add(1, Ordering::Relaxed);
            return StepFaultVerdict::Fail(Error::resource(format!(
                "injected resource spike (seed {}, pos {pos}, attempt {attempt})",
                p.seed
            )));
        }
        if step < p.step_error_rate {
            self.counters.step_errors.fetch_add(1, Ordering::Relaxed);
            return StepFaultVerdict::Fail(Error::transient(format!(
                "injected decode-step fault (seed {}, pos {pos}, attempt {attempt})",
                p.seed
            )));
        }
        if delay < p.delay_rate {
            self.counters.delays.fetch_add(1, Ordering::Relaxed);
            return StepFaultVerdict::Delay(p.delay);
        }
        StepFaultVerdict::Proceed
    }
}

/// An [`Engine`] decorator that injects the plan's faults into every
/// decode session it opens — and nothing else: `infer`, formats, pools
/// and policy validation delegate to the inner engine unchanged, so with
/// a [`FaultPlan::quiet`] plan the wrapped engine is behaviorally
/// identical to the bare one.
pub struct FaultInjector<E: Engine> {
    inner: E,
    plan: FaultPlan,
    counters: Arc<FaultCounters>,
    hook: Arc<SeededFaults>,
}

impl<E: Engine> FaultInjector<E> {
    pub fn new(inner: E, plan: FaultPlan) -> Result<Self> {
        plan.validate()?;
        let counters = Arc::new(FaultCounters::default());
        let hook = Arc::new(SeededFaults { plan: plan.clone(), counters: counters.clone() });
        Ok(FaultInjector { inner, plan, counters, hook })
    }

    /// The wrapped engine.
    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// The active chaos schedule.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    fn stats_snapshot(&self) -> FaultStats {
        FaultStats {
            step_errors: self.counters.step_errors.load(Ordering::Relaxed),
            resource_spikes: self.counters.resource_spikes.load(Ordering::Relaxed),
            poisons: self.counters.poisons.load(Ordering::Relaxed),
            io_errors: self.counters.io_errors.load(Ordering::Relaxed),
            delays: self.counters.delays.load(Ordering::Relaxed),
        }
    }
}

impl<E: Engine> Engine for FaultInjector<E> {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput> {
        self.inner.infer(tokens, policy, seed)
    }

    fn validate_policy(&self, policy: &PrecisionPolicy) -> Result<()> {
        self.inner.validate_policy(policy)
    }

    fn decode_precision(&self, policy: &PrecisionPolicy) -> crate::model::PrecisionPlan {
        self.inner.decode_precision(policy)
    }

    /// Session opens model tensor loads: an I/O-failure draw (keyed by
    /// the session seed, so retrying the same request hits the same
    /// verdict) fails the open with a non-retryable `Error::Io`; a
    /// successful open gets the plan's step hook installed.
    fn decode_session(&self, policy: &PrecisionPolicy, seed: u64) -> Result<DecodeSession<'_>> {
        let mut rng = site_rng(self.plan.seed, DOMAIN_OPEN, seed, 0, 0);
        if rng.f64() < self.plan.io_error_rate {
            self.counters.io_errors.fetch_add(1, Ordering::Relaxed);
            return Err(Error::Io(std::io::Error::other(format!(
                "injected tensor-load failure (seed {}, session {seed})",
                self.plan.seed
            ))));
        }
        let mut session = self.inner.decode_session(policy, seed)?;
        session.set_faults(Some(self.hook.clone()));
        Ok(session)
    }

    fn weight_format(&self) -> WeightFormat {
        self.inner.weight_format()
    }

    fn kv_format(&self) -> WeightFormat {
        self.inner.kv_format()
    }

    fn kv_pool(&self) -> Option<Arc<KvBlockPool>> {
        self.inner.kv_pool()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        Some(self.stats_snapshot())
    }

    fn backend(&self) -> &'static str {
        self.inner.backend()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Rule;
    use crate::coordinator::NativeEngine;
    use crate::model::{Decode, ModelConfig, Weights};

    fn engine() -> NativeEngine {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(11);
        NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())
    }

    #[test]
    fn quiet_plan_is_transparent() {
        let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
        let bare = engine();
        let (want, rate) = bare.generate(&[1, 2, 3], 6, &policy, Decode::Greedy, 7).unwrap();
        let inj = FaultInjector::new(engine(), FaultPlan::quiet(99)).unwrap();
        let mut session = inj.decode_session(&policy, 7).unwrap();
        let (got, stats) =
            crate::model::generate_with_session(&mut session, &[1, 2, 3], 6, Decode::Greedy)
                .unwrap();
        assert_eq!(got, want);
        assert!((stats.rate() - rate).abs() < 1e-12);
        assert_eq!(inj.fault_stats().unwrap(), FaultStats::default());
        assert_eq!(inj.backend(), "native");
    }

    #[test]
    fn draws_are_deterministic_and_attempt_keyed() {
        let counters = Arc::new(FaultCounters::default());
        let hook = SeededFaults {
            plan: FaultPlan::quiet(42).with_step_errors(0.5),
            counters: counters.clone(),
        };
        // Same key → same verdict, replayed exactly.
        for _ in 0..3 {
            let a = format!("{:?}", hook.check(7, 5, 0));
            let b = format!("{:?}", hook.check(7, 5, 0));
            assert_eq!(a, b);
        }
        // At a 50% rate, 64 positions must see both outcomes.
        let mut fails = 0;
        for pos in 0..64 {
            if matches!(hook.check(9, pos, 0), StepFaultVerdict::Fail(_)) {
                fails += 1;
            }
        }
        assert!(fails > 8 && fails < 56, "rate wildly off: {fails}/64");
        // Attempt-keying re-draws: some failing site must clear on retry.
        let mut cleared = false;
        for pos in 0..64 {
            if matches!(hook.check(9, pos, 0), StepFaultVerdict::Fail(_))
                && matches!(hook.check(9, pos, 1), StepFaultVerdict::Proceed)
            {
                cleared = true;
                break;
            }
        }
        assert!(cleared, "no transient fault cleared on retry across 64 sites");
    }

    #[test]
    fn injected_step_fault_is_retryable_and_leaves_state_intact() {
        let plan = FaultPlan::quiet(3).with_step_errors(0.4);
        let inj = FaultInjector::new(engine(), plan).unwrap();
        let policy = PrecisionPolicy::reference();
        let (want, _) =
            inj.inner().generate(&[1, 2, 3], 8, &policy, Decode::Greedy, 5).unwrap();
        let mut session = inj.decode_session(&policy, 5).unwrap();
        let mut tokens: Vec<u32> = vec![1, 2, 3];
        let mut fed = 0usize;
        let mut injected = 0usize;
        while tokens.len() < want.len() {
            let t = tokens[fed];
            match session.decode_step(t) {
                Ok(()) => {
                    fed += 1;
                    if fed == tokens.len() {
                        let next = crate::model::Decode::Greedy
                            .pick(session.logits(), &mut Rng::new(0))
                            .unwrap();
                        tokens.push(next);
                    }
                }
                Err(e) => {
                    assert!(e.is_retryable(), "injected fault not retryable: {e}");
                    injected += 1;
                    assert!(injected < 10_000, "fault never cleared");
                }
            }
        }
        assert_eq!(tokens, want, "retried stream diverged from solo decode");
        assert!(injected > 0, "0.4 step-error rate injected nothing");
        assert_eq!(inj.fault_stats().unwrap().step_errors, injected);
    }

    #[test]
    fn poison_terminates_session_until_reset() {
        let plan = FaultPlan::quiet(8).with_poison(1.0);
        let inj = FaultInjector::new(engine(), plan).unwrap();
        let mut s = inj.decode_session(&PrecisionPolicy::reference(), 1).unwrap();
        let e = s.decode_step(1).unwrap_err();
        assert!(e.to_string().contains("poisoned"), "{e}");
        assert!(!e.is_retryable());
        // Poisoned state sticks across steps…
        let e2 = s.decode_step(1).unwrap_err();
        assert!(e2.to_string().contains("poisoned"));
        assert_eq!(inj.fault_stats().unwrap().poisons, 1, "poison double-counted");
        // …and clears on reset (slot recycling) — though the hook stays,
        // so a re-used slot draws fresh verdicts.
        s.reset();
        let e3 = s.decode_step(1).unwrap_err();
        assert!(e3.to_string().contains("poisoned"), "hook removed by reset");
    }

    #[test]
    fn io_failure_at_open_is_deterministic() {
        let plan = FaultPlan::quiet(17).with_io_errors(0.5);
        let inj = FaultInjector::new(engine(), plan).unwrap();
        let policy = PrecisionPolicy::reference();
        let verdicts: Vec<bool> =
            (0..32).map(|s| inj.decode_session(&policy, s).is_err()).collect();
        assert!(verdicts.iter().any(|&v| v), "no open failed at 50%");
        assert!(verdicts.iter().any(|&v| !v), "every open failed at 50%");
        // Replay: identical verdict per session seed.
        for (s, &want) in verdicts.iter().enumerate() {
            assert_eq!(inj.decode_session(&policy, s as u64).is_err(), want);
        }
        let failed = verdicts.iter().filter(|&&v| v).count();
        assert_eq!(inj.fault_stats().unwrap().io_errors, failed * 2);
    }

    #[test]
    fn invalid_plan_rejected() {
        assert!(FaultPlan::quiet(0).with_step_errors(1.5).validate().is_err());
        assert!(FaultPlan::quiet(0).with_poison(-0.1).validate().is_err());
        assert!(FaultInjector::new(engine(), FaultPlan::quiet(0).with_io_errors(2.0)).is_err());
        assert!(FaultPlan::chaos(1).validate().is_ok());
    }
}
