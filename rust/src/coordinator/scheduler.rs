//! Continuous-batching decode scheduler — in-flight batching over a pool
//! of KV-cache [`DecodeSession`]s.
//!
//! The batch `Server` path runs one-shot forward calls: a long generation
//! would monopolize the engine while short requests queue behind it. The
//! scheduler instead performs *iteration-level* scheduling: every
//! [`Scheduler::step`] advances all live sessions by one unit of work — a
//! chunk of prompt prefill, or one sampled token plus its decode step —
//! admits waiting requests into free slots between iterations, and retires
//! sequences the moment they hit EOS / their token budget / the context
//! window. Short requests therefore overtake long ones instead of waiting
//! for them, and the engine's per-token work is fanned across the
//! [`ThreadPool`] (one job per active session; sessions are mutually
//! independent, so the fan-out is embarrassingly parallel).
//!
//! ## Bit-exactness contract (DESIGN.md §Continuous batching)
//!
//! Per request, the scheduler's token stream is **bit-identical** to
//! running that request alone through `NativeEngine::generate` with the
//! same seed — for every precision policy including the seed-dependent
//! `Random` rule — regardless of arrival order, interleaving, or what else
//! is in flight. This holds by construction:
//!
//! 1. each request owns a private session whose attention streams are
//!    keyed by `(seed, layer, head, position)` — functions of the request,
//!    never of the schedule;
//! 2. each request owns a private sampling `Rng::new(seed)` consumed only
//!    by its own `Decode::pick` calls, in the same order as the solo loop;
//! 3. slot recycling goes through [`DecodeSession::reseat`], which is
//!    bit-identical to constructing a fresh session.
//!
//! `rust/tests/scheduler_parity.rs` enforces the contract over randomized
//! arrival schedules; `rust/tests/failure_injection.rs` checks that a
//! failing session retires only its own request.
//!
//! ## Paged-KV admission and preemption (PR 5)
//!
//! On an engine with a shared [`KvBlockPool`](crate::model::KvBlockPool)
//! (`Engine::kv_pool`), the scheduler treats pool blocks as the admission
//! currency: a waiting request is admitted only when the pool can still
//! supply the blocks its prompt needs (FIFO — a gated head blocks the
//! queue rather than being overtaken). If a live session exhausts the
//! pool mid-decode (typed [`Error::Resource`]) while other sessions are
//! running, it is **preempted**: its blocks return to the pool, its
//! progress (tokens, sampling RNG, timing) is re-queued at the front, and
//! on re-admission the whole prefix is *recomputed* (or re-adopted from
//! the prefix-share index). Recompute is deterministic and position-keyed,
//! so the resumed stream is bit-identical to the uninterrupted one — and
//! because the resumed session re-counts its whole prefix from scratch,
//! per-request [`LampStats`] stay deduplicated: each causal product is
//! counted exactly once, exactly as `DecodeSession` already guarantees vs
//! the re-forward loop. A request that exhausts the pool while running
//! *alone* can never fit and fails with the typed error instead.

use super::engine::Engine;
use super::policy::{DegradationLadder, PrecisionPolicy};
use super::request::{GenerateRequest, GenerateResponse};
use crate::error::Error;
use crate::model::{DecodeSession, KvCheckpoint, LampStats, PrecisionPlan};
use crate::obs::metrics::{Counter, Histogram};
use crate::obs::trace::{SpanEvent, SpanKind};
use crate::obs::ObsHub;
use crate::util::{Rng, ThreadPool};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic histogram bucket bounds (seconds / tokens). Fixed here
/// so every registry snapshot of a scheduler has an identical layout.
const TTFT_BOUNDS: [f64; 10] =
    [1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0];
const ITL_BOUNDS: [f64; 9] = [1e-5, 5e-5, 1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1];
/// Bounds for the speculative acceptance-length histogram (tokens per
/// round).
const ACCEPT_BOUNDS: [f64; 8] = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 12.0];

/// Bounded retry with exponential backoff + deterministic jitter for
/// *retryable* step failures ([`Error::is_retryable`]): the failed step
/// changed no session state, so the scheduler re-feeds the same token —
/// never re-samples — and the retried stream stays bit-identical to solo
/// decode.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Consecutive retries per step before the failure turns terminal.
    pub max_retries: usize,
    /// Base backoff; attempt `n` waits `backoff * 2^(n-1) * (1 + jitter)`.
    pub backoff: Duration,
    /// Jitter fraction in `[0, 1)`, drawn deterministically from the
    /// request seed and attempt (never from global randomness — two runs
    /// of the same workload back off identically).
    pub jitter: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, backoff: Duration::from_micros(200), jitter: 0.25 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based) of the request
    /// seeded `seed`.
    pub fn delay(&self, seed: u64, attempt: usize) -> Duration {
        let exp = attempt.saturating_sub(1).min(16) as u32;
        let base = self.backoff.as_secs_f64() * f64::from(1u32 << exp);
        let jitter = if self.jitter > 0.0 {
            Rng::new(seed ^ ((attempt as u64) << 32)).f64() * self.jitter
        } else {
            0.0
        };
        Duration::from_secs_f64(base * (1.0 + jitter))
    }
}

/// Scheduler tuning knobs.
#[derive(Clone)]
pub struct SchedulerOptions {
    /// Maximum concurrently live sessions (slot count, >= 1).
    pub max_sessions: usize,
    /// Prompt tokens fed per prefilling request per iteration. Small chunks
    /// interleave prefill with decode more fairly; large chunks reach the
    /// first token faster.
    pub prefill_chunk: usize,
    /// Pool over which active sessions are stepped in parallel; `None`
    /// steps them sequentially on the caller's thread.
    pub pool: Option<Arc<ThreadPool>>,
    /// Bounded-retry policy for retryable step failures.
    pub retry: RetryPolicy,
    /// Budget on scheduler iterations per `run`-family drive; `None` is
    /// unbounded (the historical behavior). On expiry every in-flight and
    /// waiting request fails with one typed timeout event and the drive
    /// returns [`Error::Timeout`] — a wedged slot can no longer hang the
    /// caller forever.
    pub max_run_steps: Option<usize>,
    /// Wall-clock twin of [`Self::max_run_steps`].
    pub max_run_wall: Option<Duration>,
    /// Graceful-degradation ladder; `None` (the default) disables the
    /// overload controller entirely — zero behavior change.
    pub ladder: Option<DegradationLadder>,
    /// Observability hub the scheduler reports into (metrics registry,
    /// optional span tracer, wall-or-virtual clock). `None` creates a
    /// private wall-clock hub, so the reporting paths are identical with
    /// observability on or off — instrumentation is provably inert.
    pub obs: Option<Arc<ObsHub>>,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions {
            max_sessions: 8,
            prefill_chunk: 8,
            pool: None,
            retry: RetryPolicy::default(),
            max_run_steps: None,
            max_run_wall: None,
            ladder: None,
            obs: None,
        }
    }
}

/// One entry of the event stream produced by [`Scheduler::step`].
#[derive(Debug)]
pub enum GenerateEvent {
    /// A freshly sampled token (streamed as soon as it exists).
    Token {
        id: u64,
        token: u32,
        /// Index within the generated continuation (0 = first new token).
        index: usize,
    },
    /// The request retired normally.
    Finished(GenerateResponse),
    /// The request's session failed; only this request is affected.
    Failed { id: u64, error: Error },
}

/// Decode-path metrics aggregated over a scheduler's lifetime.
#[derive(Debug, Clone, Default)]
pub struct DecodeMetrics {
    pub completed: usize,
    pub failed: usize,
    pub generated_tokens: usize,
    /// Scheduler iterations executed.
    pub steps: usize,
    /// Time-to-first-token percentiles over completed-or-not requests, s.
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    /// Inter-token latency percentiles, s.
    pub itl_p50_s: f64,
    pub itl_p95_s: f64,
    /// Mean number of live sessions per iteration (occupancy).
    pub mean_active_sessions: f64,
    /// Aggregate LAMP counters over every retired session.
    pub recomputed: usize,
    pub causal_total: usize,
    /// **Attention-site** recompute rate per policy label
    /// (`PrecisionPolicy::label`); non-attention sites are broken out in
    /// [`Self::recompute_by_site`], aggregated across policies.
    pub recompute_by_policy: Vec<(String, f64)>,
    /// Recompute rate per composition site (`LampStats::site_rates`),
    /// aggregated over every retired session.
    pub recompute_by_site: Vec<(String, f64)>,
    // --- Paged KV-cache metrics (engines with a shared block pool). ---
    /// Sessions preempted on pool exhaustion (recomputed on re-admission).
    pub preemptions: usize,
    /// The engine's KV storage format label (`f32`/`bf16`/`ps<mu>`).
    pub kv_format: String,
    /// Slab-resident bytes of live KV blocks (0 without a shared pool).
    pub kv_resident_bytes: usize,
    /// Block-pool occupancy: live blocks / capacity.
    pub kv_blocks_used: usize,
    pub kv_blocks_capacity: usize,
    pub kv_occupancy: f64,
    /// Prefix-share adoptions / adoption attempts over the pool's life.
    pub prefix_share_hits: usize,
    pub prefix_share_rate: f64,
    // --- Fault-tolerance metrics (PR 6). ---
    /// In-place step retries (backoff re-feeds) across all requests.
    pub retries: usize,
    /// Requests failed on a deadline or run-budget expiry.
    pub timeouts: usize,
    /// Requests failed through their cancellation token.
    pub canceled: usize,
    /// Faults injected by a wrapping `FaultInjector` (0 on real engines).
    pub faults_injected: usize,
    /// Admissions whose effective policy was stepped down the ladder.
    pub degraded_admissions: usize,
    /// Ladder transitions: steps down (degrade) and back up (restore).
    pub degrade_transitions: usize,
    pub restore_transitions: usize,
    /// Current ladder rung (0 = no degradation) and its metric label.
    pub ladder_rung: usize,
    pub ladder_rung_name: String,
    // --- Speculative decoding metrics (PR 9). ---
    /// Speculation rounds completed (one batched verify each) over every
    /// retired session.
    pub spec_rounds: usize,
    /// Draft tokens proposed / accepted by verification.
    pub spec_drafted: usize,
    pub spec_accepted: usize,
    /// Draft forward steps and batched verify passes executed.
    pub spec_draft_steps: usize,
    pub spec_verify_chunks: usize,
    /// accepted / drafted (0 when nothing was drafted).
    pub spec_acceptance_rate: f64,
    /// Mean tokens emitted per round (1.0 = speculation never paid off).
    pub spec_mean_accept_len: f64,
    /// Acceptance-length histogram: entry `i` counts rounds that emitted
    /// `i + 1` tokens.
    pub spec_accept_hist: Vec<usize>,
}

/// A queued request: fresh, or preempted and awaiting recompute.
struct WaitingEntry {
    req: GenerateRequest,
    /// Original enqueue instant — preemption does not reset the
    /// TTFT/latency origin.
    enqueued: Instant,
    resume: Option<ResumeState>,
}

/// Progress carried across a preemption. The sampling RNG continues where
/// it stopped (already-sampled tokens are re-*fed*, never re-sampled), so
/// the resumed stream is bit-identical to the uninterrupted one. No
/// `LampStats` are carried: the resumed session re-counts its whole
/// prefix from scratch, which is exactly the single-count accounting —
/// merging saved counters on top would double-count the recomputed
/// prefill (the regression `scheduler_parity.rs` pins).
struct ResumeState {
    /// Prompt + tokens generated before preemption (all previously fed).
    tokens: Vec<u32>,
    prompt_len: usize,
    generated: usize,
    rng: Rng,
    first_token: Option<Instant>,
    last_event: Instant,
}

/// A request bound to a live session.
struct ActiveSlot<'e> {
    /// The admitted request, its prompt moved out into [`Self::tokens`]
    /// (single copy; `prompt_len` marks the boundary).
    req: GenerateRequest,
    session: DecodeSession<'e>,
    /// Private sampling stream (`Rng::new(req.seed)`, as in solo decode).
    rng: Rng,
    /// Prompt (prefix of `prompt_len` tokens) + generated tokens.
    tokens: Vec<u32>,
    prompt_len: usize,
    generated: usize,
    /// Tokens fed to the session (== `session.len()`, adopted prefix
    /// included). Sampling happens only once every token in [`Self::tokens`]
    /// has been fed — which also makes a token whose *feed* failed on pool
    /// exhaustion (sampled, streamed, but not yet cached) get re-fed, not
    /// re-sampled, when the slot survives a victim preemption and retries.
    prefilled: usize,
    /// Enqueue time ([`Scheduler::admit`]) — the TTFT/latency origin, so
    /// queue wait counts against the request, not just slot residence.
    admitted: Instant,
    first_token: Option<Instant>,
    last_event: Instant,
    outcome: StepOutcome,
    /// Consecutive retryable failures at the current step (cleared on any
    /// successful iteration); terminal once it exceeds the retry budget.
    retries: usize,
    /// The slot sits out iterations until this backoff deadline passes.
    backoff_until: Option<Instant>,
    /// Virtual-clock twin of [`Self::backoff_until`]: under a virtual
    /// hub clock the slot sits out this many scheduler iterations
    /// instead of wall time, so replayed (trials) schedules are
    /// deterministic across machines and reruns.
    backoff_steps: usize,
    /// Speculative-decoding state machine; `None` when the request's
    /// policy carries no draft plan (plain one-token-per-step decode).
    spec: Option<SlotSpec>,
}

/// Speculation config and round state a slot carries when its policy
/// requests a draft plan (PR 9).
struct SlotSpec {
    k: usize,
    draft_plan: PrecisionPlan,
    state: SpecPhase,
}

/// Per-slot speculative round state. Each variant is one *schedulable
/// unit* of work — one draft step, or one batched verify + commit — so
/// deadlines, cancellation, retries, and victim preemption all land
/// between units, exactly like plain decode steps. Preemption simply
/// drops this state: the draft RNG is a clone and the real RNG is only
/// consumed at verify time, so a resumed slot replays its round against
/// the recomputed (bit-identical) session state.
enum SpecPhase {
    /// Between rounds: the next unit feeds/retires or opens a new round.
    Seed,
    /// Mid-draft against the scratch KV extension.
    Drafting { cp: KvCheckpoint, cands: Vec<u32>, draft_rng: Rng, m: usize },
    /// Drafts rolled back; the next unit verifies and commits.
    Verify { cands: Vec<u32> },
}

/// Scratch for one slot-iteration, harvested after the parallel fan-out.
/// A speculation round's verify+commit unit emits several tokens at once;
/// every other unit emits at most one.
#[derive(Default)]
struct StepOutcome {
    emitted: Vec<u32>,
    done: bool,
    error: Option<Error>,
    /// What unit of work this iteration performed (span attribution
    /// only; never read by scheduling decisions).
    unit: SpanKind,
}

impl ActiveSlot<'_> {
    /// Advance this request by one scheduler iteration. Mirrors the solo
    /// `generate` loop exactly: prefill the prompt, then alternate
    /// `Decode::pick` / `decode_step` in the solo order — including
    /// feeding the final sampled token (unless the context is full),
    /// which the solo loop also does, so session statistics agree.
    fn run_iteration(&mut self, prefill_chunk: usize) {
        self.outcome = StepOutcome::default();
        if let Err(e) = self.iterate(prefill_chunk) {
            self.outcome.error = Some(e);
        }
    }

    fn iterate(&mut self, prefill_chunk: usize) -> crate::error::Result<()> {
        let seq = self.session.config().seq;
        if self.spec.is_some() {
            return self.iterate_spec(prefill_chunk, seq);
        }
        if self.prefilled < self.tokens.len() {
            // Feed phase: the prompt (chunked), a preempted request's
            // recomputed prefix, or a single dangling token whose feed
            // failed on pool exhaustion last iteration.
            self.outcome.unit = SpanKind::Prefill;
            let end = (self.prefilled + prefill_chunk.max(1)).min(self.tokens.len());
            while self.prefilled < end {
                let tok = self.tokens[self.prefilled];
                self.session.decode_step(tok)?;
                self.prefilled += 1;
            }
            return Ok(());
        }
        self.outcome.unit = SpanKind::Decode;
        if self.generated >= self.req.max_new_tokens {
            // Reachable only on the retry/resume paths: the final token
            // was sampled before the interruption and has now been fed —
            // retire instead of over-sampling past the budget.
            self.outcome.done = true;
            return Ok(());
        }
        // Decode phase: the session's logits are fresh for the last fed
        // token.
        let decode = self.req.decode;
        let next = decode.pick(self.session.logits(), &mut self.rng)?;
        self.tokens.push(next);
        self.generated += 1;
        self.outcome.emitted.push(next);
        if self.tokens.len() >= seq {
            // Context exhausted: retire without feeding, exactly like the
            // solo loop's early break.
            self.outcome.done = true;
            return Ok(());
        }
        if self.req.eos == Some(next) {
            // Stop token (a scheduler extension — solo decode has none):
            // retire immediately; the emitted stream stays a prefix of
            // the solo stream.
            self.outcome.done = true;
            return Ok(());
        }
        // Feed the sampled token — also on the final iteration, exactly
        // as the solo loop does, so `LampStats` match solo accounting.
        self.session.decode_step(next)?;
        self.prefilled += 1;
        if self.generated >= self.req.max_new_tokens {
            self.outcome.done = true;
        }
        Ok(())
    }

    /// Advance a speculative slot by one schedulable unit: a prefill
    /// chunk, a round-opening/bookkeeping step, one draft step, or one
    /// batched verify + commit ([`SpecPhase`]). The emitted stream
    /// replays `model::sampler`'s speculative loop exactly — every token
    /// is picked from target-plan logits in solo order, draft picks
    /// consume only a clone of the RNG — so per-request output stays
    /// bit-identical to solo decode under the same policy.
    fn iterate_spec(&mut self, prefill_chunk: usize, seq: usize) -> crate::error::Result<()> {
        let k = self.spec.as_ref().expect("spec slot").k;
        match &self.spec.as_ref().expect("spec slot").state {
            SpecPhase::Drafting { .. } => return self.draft_unit(seq),
            SpecPhase::Verify { .. } => {
                let state = std::mem::replace(
                    &mut self.spec.as_mut().expect("spec slot").state,
                    SpecPhase::Seed,
                );
                let SpecPhase::Verify { cands } = state else { unreachable!() };
                return self.verify_unit(cands, seq);
            }
            SpecPhase::Seed => {}
        }
        // Feed phase: the prompt or a preempted request's recomputed
        // prefix. A generated trailing token is the next round's *unfed*
        // base (the solo speculative loop keeps it unfed too), so it is
        // excluded from the feed target.
        let fed_target =
            if self.generated == 0 { self.tokens.len() } else { self.tokens.len() - 1 };
        if self.prefilled < fed_target {
            self.outcome.unit = SpanKind::Prefill;
            let end = (self.prefilled + prefill_chunk.max(1)).min(fed_target);
            while self.prefilled < end {
                let tok = self.tokens[self.prefilled];
                self.session.decode_step(tok)?;
                self.prefilled += 1;
            }
            return Ok(());
        }
        if self.generated == 0 {
            // First pick straight off the prefilled prompt, exactly like
            // the solo speculative loop's entry.
            self.outcome.unit = SpanKind::Decode;
            let next = self.req.decode.pick(self.session.logits(), &mut self.rng)?;
            self.tokens.push(next);
            self.generated += 1;
            self.outcome.emitted.push(next);
            if self.tokens.len() >= seq || self.req.eos == Some(next) {
                self.outcome.done = true;
            }
            return Ok(());
        }
        let next = *self.tokens.last().expect("seed token");
        if self.generated >= self.req.max_new_tokens {
            // Budget spent: feed the final sampled token (solo parity —
            // the context is not full, or the slot would have retired at
            // pick time) and retire.
            self.outcome.unit = SpanKind::Decode;
            self.session.decode_step(next)?;
            self.prefilled += 1;
            self.outcome.done = true;
            return Ok(());
        }
        let n = self.session.len();
        let m =
            (1 + k).min(self.req.max_new_tokens - self.generated).min(seq - n - 1);
        if m < 2 {
            return self.degenerate_step(seq);
        }
        // Open a round — checkpoint, clone the sampling RNG for drafting,
        // enter scratch mode — and run its first draft step right away so
        // every iteration does real forward work.
        let cp = self.session.spec_checkpoint();
        let draft_rng = self.rng.clone();
        self.session.begin_draft();
        self.spec.as_mut().expect("spec slot").state =
            SpecPhase::Drafting { cp, cands: vec![next], draft_rng, m };
        self.draft_unit(seq)
    }

    /// One draft step + draft pick against the scratch KV extension.
    /// Draft work is disposable (solo behavior): any step failure —
    /// typically pool pressure from the scratch extension — just ends the
    /// draft phase early; with nothing drafted the round degenerates to a
    /// plain committed step this same iteration.
    fn draft_unit(&mut self, seq: usize) -> crate::error::Result<()> {
        self.outcome.unit = SpanKind::Draft;
        let decode = self.req.decode;
        let (last, draft_plan) = {
            let spec = self.spec.as_ref().expect("spec slot");
            let SpecPhase::Drafting { cands, .. } = &spec.state else {
                unreachable!("draft unit outside a round")
            };
            (*cands.last().expect("nonempty"), spec.draft_plan)
        };
        let drafting = match self.session.draft_step(last, draft_plan) {
            Ok(()) => {
                let spec = self.spec.as_mut().expect("spec slot");
                let SpecPhase::Drafting { cands, draft_rng, m, .. } = &mut spec.state
                else {
                    unreachable!("draft unit outside a round")
                };
                // Draft pick from the *cloned* stream; the real RNG stays
                // untouched until the acceptance walk.
                cands.push(decode.pick(self.session.logits(), draft_rng)?);
                cands.len() < *m
            }
            Err(_) => false,
        };
        if drafting {
            return Ok(());
        }
        // Draft phase over (full or died): roll the scratch extension
        // back, then verify what survived (nothing ⇒ solo's degenerate
        // plain step).
        let state = std::mem::replace(
            &mut self.spec.as_mut().expect("spec slot").state,
            SpecPhase::Seed,
        );
        let SpecPhase::Drafting { cp, cands, .. } = state else {
            unreachable!("draft unit outside a round")
        };
        self.session.rollback(&cp);
        if cands.len() >= 2 {
            self.spec.as_mut().expect("spec slot").state = SpecPhase::Verify { cands };
            return Ok(());
        }
        self.degenerate_step(seq)
    }

    /// The round's verify + commit as one schedulable unit: one batched
    /// target-plan forward over the candidates, the acceptance walk on
    /// the real RNG, then an atomic commit of the accepted prefix. A
    /// failed verify changed no session state and consumed no real RNG,
    /// so the standard retry/preemption machinery re-runs this unit (the
    /// phase is restored) or replays the whole round after preemption —
    /// bit-identically either way.
    fn verify_unit(&mut self, cands: Vec<u32>, seq: usize) -> crate::error::Result<()> {
        self.outcome.unit = SpanKind::Verify;
        if let Err(e) = self.session.verify_chunk(&cands) {
            self.spec.as_mut().expect("spec slot").state = SpecPhase::Verify { cands };
            return Err(e);
        }
        let decode = self.req.decode;
        let mut round = Vec::with_capacity(cands.len());
        round.push(decode.pick(self.session.chunk_logits_row(0), &mut self.rng)?);
        while round.len() < cands.len()
            && *round.last().expect("nonempty") == cands[round.len()]
        {
            let j = round.len();
            round.push(decode.pick(self.session.chunk_logits_row(j), &mut self.rng)?);
        }
        let accepted_rows = round.len();
        self.session.commit_round(&cands[..accepted_rows]);
        self.session
            .spec_stats_mut()
            .record_round(cands.len() - 1, accepted_rows - 1, round.len());
        self.prefilled += accepted_rows;
        // Emit the round, honoring the scheduler's eos extension: stop at
        // the stop token and drop the tail, keeping the emitted stream a
        // prefix of the solo stream. The context bound can only trip on
        // the round's last token (m ≤ seq - n - 1 at round open).
        for &t in &round {
            self.tokens.push(t);
            self.generated += 1;
            self.outcome.emitted.push(t);
            if self.tokens.len() >= seq || self.req.eos == Some(t) {
                self.outcome.done = true;
                break;
            }
        }
        Ok(())
    }

    /// One plain committed decode step + pick — the solo loop body, used
    /// when a round has no look-ahead room or none of its drafts
    /// survived.
    fn degenerate_step(&mut self, seq: usize) -> crate::error::Result<()> {
        self.outcome.unit = SpanKind::Decode;
        let next = *self.tokens.last().expect("seed token");
        self.session.decode_step(next)?;
        self.prefilled += 1;
        let t = self.req.decode.pick(self.session.logits(), &mut self.rng)?;
        self.tokens.push(t);
        self.generated += 1;
        self.outcome.emitted.push(t);
        if self.tokens.len() >= seq || self.req.eos == Some(t) {
            self.outcome.done = true;
        }
        Ok(())
    }
}

/// Raw slot pointer handed to the worker jobs: each job mutates exactly one
/// distinct slot index, so the aliasing is benign (same argument as the
/// attention tiles in `model/attention.rs`).
struct SlotsPtr<'e>(*mut Option<ActiveSlot<'e>>);
unsafe impl Send for SlotsPtr<'_> {}
unsafe impl Sync for SlotsPtr<'_> {}

/// Continuous-batching scheduler over one engine's decode sessions.
pub struct Scheduler<'e> {
    engine: &'e dyn Engine,
    opts: SchedulerOptions,
    /// Waiting requests (fresh and preempted) with their enqueue
    /// timestamps (the TTFT/latency origin — queue wait counts against
    /// the scheduler).
    waiting: VecDeque<WaitingEntry>,
    slots: Vec<Option<ActiveSlot<'e>>>,
    /// Retired sessions kept warm for slot recycling (reseat on admit).
    parked: Vec<DecodeSession<'e>>,
    /// Observability hub: metrics registry (the counters below live in
    /// it), optional span tracer, wall-or-virtual clock. Always present —
    /// a private hub is created when the options carry none, so the
    /// accounting paths are identical with observability on or off.
    hub: Arc<ObsHub>,
    // Lifetime accounting — registry-backed counter handles (same cost
    // as the plain fields they replaced: one relaxed atomic add each).
    steps: Counter,
    active_steps: Counter,
    completed: Counter,
    failed: Counter,
    preemptions: Counter,
    generated_tokens: Counter,
    retries: Counter,
    timeouts: Counter,
    canceled: Counter,
    // Degradation-ladder controller state (all 0/idle without a ladder).
    ladder_rung: usize,
    pressured_steps: usize,
    clear_steps: usize,
    degrades: Counter,
    restores: Counter,
    degraded_admissions: Counter,
    /// Raw latency samples, kept alongside the bucketed histograms: the
    /// exact nearest-rank percentiles in [`DecodeMetrics`] come from
    /// these (`metrics::stats::percentile`), the histograms serve
    /// exposition.
    ttfts: Vec<f64>,
    itls: Vec<f64>,
    ttft_hist: Histogram,
    itl_hist: Histogram,
    by_policy: Vec<(String, LampStats)>,
    totals: LampStats,
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e dyn Engine, opts: SchedulerOptions) -> Self {
        assert!(opts.max_sessions >= 1, "scheduler needs at least one slot");
        if let Some(ladder) = &opts.ladder {
            ladder.validate().expect("invalid degradation ladder");
        }
        let slots = (0..opts.max_sessions).map(|_| None).collect();
        let hub = opts.obs.clone().unwrap_or_else(|| Arc::new(ObsHub::new()));
        let reg = hub.registry();
        let steps = reg.counter("sched.steps");
        let active_steps = reg.counter("sched.active_steps");
        let completed = reg.counter("sched.completed");
        let failed = reg.counter("sched.failed");
        let preemptions = reg.counter("sched.preemptions");
        let generated_tokens = reg.counter("sched.generated_tokens");
        let retries = reg.counter("sched.retries");
        let timeouts = reg.counter("sched.timeouts");
        let canceled = reg.counter("sched.canceled");
        let degrades = reg.counter("sched.degrade_transitions");
        let restores = reg.counter("sched.restore_transitions");
        let degraded_admissions = reg.counter("sched.degraded_admissions");
        let ttft_hist = reg.histogram("sched.ttft_s", &TTFT_BOUNDS);
        let itl_hist = reg.histogram("sched.itl_s", &ITL_BOUNDS);
        Scheduler {
            engine,
            opts,
            waiting: VecDeque::new(),
            slots,
            parked: Vec::new(),
            hub,
            steps,
            active_steps,
            completed,
            failed,
            preemptions,
            generated_tokens,
            retries,
            timeouts,
            canceled,
            ladder_rung: 0,
            pressured_steps: 0,
            clear_steps: 0,
            degrades,
            restores,
            degraded_admissions,
            ttfts: Vec::new(),
            itls: Vec::new(),
            ttft_hist,
            itl_hist,
            by_policy: Vec::new(),
            totals: LampStats::default(),
        }
    }

    /// The hub this scheduler reports into (for snapshotting its
    /// registry or dumping its trace after a drive).
    pub fn obs(&self) -> &Arc<ObsHub> {
        &self.hub
    }

    /// Enqueue a request. No validation happens here (the `Server` front
    /// door validates); a request whose tokens violate the engine contract
    /// fails at its own session without affecting the others. The enqueue
    /// instant is recorded: time spent waiting for a slot counts toward
    /// the request's TTFT and latency.
    pub fn admit(&mut self, req: GenerateRequest) {
        if let Some(tr) = self.hub.tracer() {
            tr.instant(req.id, SpanKind::Enqueue, self.hub.now());
        }
        self.waiting
            .push_back(WaitingEntry { req, enqueued: Instant::now(), resume: None });
    }

    /// Requests waiting for a slot.
    pub fn pending(&self) -> usize {
        self.waiting.len()
    }

    /// Live sessions.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when nothing is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    fn open_session(
        &mut self,
        policy: &PrecisionPolicy,
        seed: u64,
    ) -> crate::error::Result<DecodeSession<'e>> {
        if let Some(mut s) = self.parked.pop() {
            // The engine owns the policy → precision translation for both
            // fresh and recycled sessions; recycled slots can never diverge.
            s.reseat(self.engine.decode_precision(policy), seed);
            return Ok(s);
        }
        let engine = self.engine;
        engine.decode_session(policy, seed)
    }

    /// Park a retired session for reuse. The reset here is load-bearing
    /// for paged KV: it releases the session's blocks back to the pool
    /// immediately — a parked session must not hog admission capacity.
    /// (`reseat` inside [`Self::open_session`] still re-keys plan/seed.)
    fn recycle(&mut self, mut session: DecodeSession<'e>) {
        session.reset();
        if self.parked.len() < self.slots.len() {
            self.parked.push(session);
        }
    }

    fn merge_policy_stats(&mut self, policy: &PrecisionPolicy, stats: &LampStats) {
        self.totals.merge(stats);
        let label = policy.label();
        if let Some((_, s)) = self.by_policy.iter_mut().find(|(l, _)| *l == label) {
            s.merge(stats);
        } else {
            self.by_policy.push((label, stats.clone()));
        }
        // Mirror the retired session's LAMP/spec counters into the
        // registry. Retirement is a cold path (once per request), and the
        // stats arrive exactly once per session — the single-count
        // contract the parity tests pin carries straight over.
        let reg = self.hub.registry();
        reg.counter("lamp.attention.recomputed").add(stats.recomputed as u64);
        reg.counter("lamp.attention.total").add(stats.causal_total as u64);
        reg.counter("lamp.mlp.recomputed").add(stats.mlp.recomputed as u64);
        reg.counter("lamp.mlp.total").add(stats.mlp.total as u64);
        reg.counter("lamp.norm.recomputed").add(stats.norm.recomputed as u64);
        reg.counter("lamp.norm.total").add(stats.norm.total as u64);
        reg.counter("lamp.sampler.recomputed").add(stats.sampler.recomputed as u64);
        reg.counter("lamp.sampler.total").add(stats.sampler.total as u64);
        reg.counter("lamp.attention_tiles.recomputed").add(stats.tiles.recomputed as u64);
        reg.counter("lamp.attention_tiles.total").add(stats.tiles.total as u64);
        reg.counter("spec.rounds").add(stats.spec.rounds as u64);
        reg.counter("spec.drafted").add(stats.spec.drafted as u64);
        reg.counter("spec.accepted").add(stats.spec.accepted as u64);
        reg.counter("spec.draft_steps").add(stats.spec.draft_steps as u64);
        reg.counter("spec.verify_chunks").add(stats.spec.verify_chunks as u64);
        if !stats.spec.accept_hist.is_empty() {
            let hist = reg.histogram("spec.accept_len", &ACCEPT_BOUNDS);
            for (i, &n) in stats.spec.accept_hist.iter().enumerate() {
                hist.observe_n((i + 1) as f64, n as u64);
            }
        }
    }

    /// Move waiting requests into free slots. Requests that can produce
    /// nothing (prompt fills the context, zero token budget) complete
    /// immediately, mirroring `generate`'s early return; requests whose
    /// session cannot be opened fail without consuming a slot. On an
    /// engine with a shared KV block pool, admission is gated on the pool
    /// being able to supply the request's prompt blocks — FIFO: a gated
    /// queue head stops admission rather than being overtaken.
    fn admit_waiting(&mut self, events: &mut Vec<GenerateEvent>) {
        let kv_pool = self.engine.kv_pool();
        for slot_idx in 0..self.opts.max_sessions {
            if self.slots[slot_idx].is_some() {
                continue;
            }
            loop {
                let Some(mut entry) = self.waiting.pop_front() else { return };
                if entry.resume.is_none() {
                    // Degenerate-request checks apply to fresh admissions
                    // only (a resumed request passed them already, and
                    // its `req.prompt` has been moved out).
                    let req = &entry.req;
                    let seq = self.engine.config().seq;
                    if req.prompt.is_empty() {
                        self.failed.inc();
                        events.push(GenerateEvent::Failed {
                            id: req.id,
                            error: Error::shape("empty prompt".to_string()),
                        });
                        continue;
                    }
                    if req.prompt.len() >= seq || req.max_new_tokens == 0 {
                        self.completed.inc();
                        events.push(GenerateEvent::Finished(GenerateResponse {
                            id: entry.req.id,
                            prompt_len: entry.req.prompt.len(),
                            policy: entry.req.policy,
                            tokens: entry.req.prompt,
                            stats: LampStats::default(),
                            ttft_s: 0.0,
                            latency_s: entry.enqueued.elapsed().as_secs_f64(),
                        }));
                        continue;
                    }
                }
                if let Some(pool) = &kv_pool {
                    // Gate on the blocks the known prefix provably needs
                    // right now; decode growth beyond that is handled by
                    // preemption, not over-reservation.
                    let prefix = match &entry.resume {
                        Some(r) => r.tokens.len(),
                        None => entry.req.prompt.len(),
                    };
                    let needed = pool.blocks_for(prefix);
                    if pool.capacity_blocks() < needed {
                        // Can never fit, even alone — fail instead of
                        // blocking the queue forever.
                        self.failed.inc();
                        events.push(GenerateEvent::Failed {
                            id: entry.req.id,
                            error: Error::resource(format!(
                                "prompt needs {needed} KV blocks, pool capacity is {}",
                                pool.capacity_blocks()
                            )),
                        });
                        continue;
                    }
                    if pool.available_blocks() < needed {
                        self.waiting.push_front(entry);
                        return;
                    }
                }
                // Degradation applies at admission only, to fresh requests:
                // the effective policy is fixed for the request's lifetime
                // (preemption resume reuses it), so "bit-identical to solo
                // decode under the final effective plan" is well-defined.
                if entry.resume.is_none() && self.ladder_rung > 0 {
                    if let Some(ladder) = &self.opts.ladder {
                        let eff = ladder.apply(self.ladder_rung, &entry.req.policy);
                        if eff != entry.req.policy {
                            entry.req.policy = eff;
                            self.degraded_admissions.inc();
                        }
                    }
                }
                let (req_id, resumed) = (entry.req.id, entry.resume.is_some());
                match self.open_session(&entry.req.policy, entry.req.seed) {
                    Ok(mut session) => {
                        let mut req = entry.req;
                        // A policy carrying a draft plan decodes through
                        // the per-slot speculative state machine. Resumed
                        // requests re-derive it fresh: preemption dropped
                        // any in-flight round, which replays after the
                        // prefix recompute.
                        let spec = session.plan().spec.map(|s| SlotSpec {
                            k: s.k,
                            draft_plan: session
                                .plan()
                                .draft_plan()
                                .expect("validated spec has a draft plan"),
                            state: SpecPhase::Seed,
                        });
                        let slot = match entry.resume {
                            Some(r) => {
                                // Recompute the whole pre-preemption
                                // prefix (or re-adopt it from the share
                                // index); the sampling RNG continues.
                                let adopted =
                                    session.adopt_prefix(&r.tokens[..r.tokens.len() - 1]);
                                ActiveSlot {
                                    rng: r.rng,
                                    prompt_len: r.prompt_len,
                                    tokens: r.tokens,
                                    generated: r.generated,
                                    prefilled: adopted,
                                    admitted: entry.enqueued,
                                    first_token: r.first_token,
                                    last_event: r.last_event,
                                    outcome: StepOutcome::default(),
                                    retries: 0,
                                    backoff_until: None,
                                    backoff_steps: 0,
                                    spec,
                                    session,
                                    req,
                                }
                            }
                            None => {
                                // Single copy: the prompt becomes the
                                // prefix of the slot's token buffer. A
                                // shared prompt prefix (all but the last
                                // token) is adopted instead of computed.
                                let prompt = std::mem::take(&mut req.prompt);
                                let adopted = if prompt.len() > 1 {
                                    session.adopt_prefix(&prompt[..prompt.len() - 1])
                                } else {
                                    0
                                };
                                ActiveSlot {
                                    rng: Rng::new(req.seed),
                                    prompt_len: prompt.len(),
                                    tokens: prompt,
                                    generated: 0,
                                    prefilled: adopted,
                                    admitted: entry.enqueued,
                                    first_token: None,
                                    last_event: entry.enqueued,
                                    outcome: StepOutcome::default(),
                                    retries: 0,
                                    backoff_until: None,
                                    backoff_steps: 0,
                                    spec,
                                    session,
                                    req,
                                }
                            }
                        };
                        self.slots[slot_idx] = Some(slot);
                        if let Some(tr) = self.hub.tracer() {
                            let kind =
                                if resumed { SpanKind::Resume } else { SpanKind::Admit };
                            tr.instant(req_id, kind, self.hub.now());
                        }
                        break;
                    }
                    Err(e) => {
                        self.failed.inc();
                        events.push(GenerateEvent::Failed { id: entry.req.id, error: e });
                        continue;
                    }
                }
            }
        }
    }

    /// Fail queued requests that were canceled or whose deadline expired
    /// before ever reaching a slot — exactly one typed terminal event
    /// each, never a session open.
    fn expire_waiting(&mut self, events: &mut Vec<GenerateEvent>) {
        let now = Instant::now();
        let mut kept = VecDeque::with_capacity(self.waiting.len());
        while let Some(entry) = self.waiting.pop_front() {
            let waited = now.duration_since(entry.enqueued);
            let error = if entry.req.is_canceled() {
                self.canceled.inc();
                Some(Error::canceled(format!("request {} canceled while queued", entry.req.id)))
            } else if entry.req.deadline.total.is_some_and(|d| waited >= d) {
                self.timeouts.inc();
                Some(Error::timeout(format!(
                    "request {} exceeded its total deadline while queued",
                    entry.req.id
                )))
            } else if entry.resume.as_ref().map_or(true, |r| r.first_token.is_none())
                && entry.req.deadline.ttft.is_some_and(|d| waited >= d)
            {
                self.timeouts.inc();
                Some(Error::timeout(format!(
                    "request {} exceeded its TTFT deadline while queued",
                    entry.req.id
                )))
            } else {
                None
            };
            match error {
                Some(error) => {
                    self.failed.inc();
                    events.push(GenerateEvent::Failed { id: entry.req.id, error });
                }
                None => kept.push_back(entry),
            }
        }
        self.waiting = kept;
    }

    /// Fail live slots that were canceled or blew a deadline. Tokens
    /// already streamed are kept (they remain a prefix of the solo
    /// stream); the slot is recycled and exactly one typed terminal
    /// event is emitted.
    fn expire_active(&mut self, events: &mut Vec<GenerateEvent>) {
        let now = Instant::now();
        for i in 0..self.slots.len() {
            let Some(slot) = &self.slots[i] else { continue };
            let age = now.duration_since(slot.admitted);
            let error = if slot.req.is_canceled() {
                self.canceled.inc();
                Some(Error::canceled(format!("request {} canceled", slot.req.id)))
            } else if slot.req.deadline.total.is_some_and(|d| age >= d) {
                self.timeouts.inc();
                Some(Error::timeout(format!(
                    "request {} exceeded its total deadline mid-decode",
                    slot.req.id
                )))
            } else if slot.first_token.is_none()
                && slot.req.deadline.ttft.is_some_and(|d| age >= d)
            {
                self.timeouts.inc();
                Some(Error::timeout(format!(
                    "request {} exceeded its TTFT deadline before the first token",
                    slot.req.id
                )))
            } else {
                None
            };
            if let Some(error) = error {
                let slot = self.slots[i].take().expect("live slot");
                self.failed.inc();
                self.recycle(slot.session);
                events.push(GenerateEvent::Failed { id: slot.req.id, error });
            }
        }
    }

    /// Hysteresis controller for the degradation ladder, driven once per
    /// step by pool occupancy and this step's deadline misses/preemptions:
    /// degrade fast under sustained pressure, restore slowly once clear.
    fn update_ladder(&mut self, step_timeouts: usize, step_preemptions: usize) {
        let Some(ladder) = &self.opts.ladder else { return };
        let occupancy =
            self.engine.kv_pool().map(|p| p.stats().occupancy()).unwrap_or(0.0);
        let pressured =
            occupancy >= ladder.occupancy_high || step_timeouts > 0 || step_preemptions > 0;
        let clear =
            occupancy <= ladder.occupancy_low && step_timeouts == 0 && step_preemptions == 0;
        if pressured {
            self.clear_steps = 0;
            self.pressured_steps += 1;
            if self.pressured_steps >= ladder.degrade_after
                && self.ladder_rung < ladder.max_rung()
            {
                self.ladder_rung += 1;
                self.degrades.inc();
                self.pressured_steps = 0;
            }
        } else if clear {
            self.pressured_steps = 0;
            self.clear_steps += 1;
            if self.clear_steps >= ladder.restore_after && self.ladder_rung > 0 {
                self.ladder_rung -= 1;
                self.restores.inc();
                self.clear_steps = 0;
            }
        } else {
            // Between the thresholds: hold the rung, reset both streaks.
            self.pressured_steps = 0;
            self.clear_steps = 0;
        }
    }

    /// One scheduler iteration: expire canceled/overdue requests, admit,
    /// advance every runnable session (across the pool when configured),
    /// harvest tokens / retirements / failures, update the ladder.
    pub fn step(&mut self) -> Vec<GenerateEvent> {
        let mut events = Vec::new();
        let (timeouts0, preemptions0) = (self.timeouts.get(), self.preemptions.get());
        self.expire_waiting(&mut events);
        self.admit_waiting(&mut events);
        self.expire_active(&mut events);
        let backoff_now = Instant::now();
        let virtual_clock = self.hub.is_virtual();
        let mut active: Vec<usize> = Vec::with_capacity(self.slots.len());
        for i in 0..self.slots.len() {
            let Some(s) = self.slots[i].as_mut() else { continue };
            // Under a virtual hub clock, backoff is counted in scheduler
            // iterations instead of wall time — replayed schedules (and
            // the traces/metrics recorded from them) are deterministic.
            let runnable = if virtual_clock {
                if s.backoff_steps > 0 {
                    s.backoff_steps -= 1;
                    false
                } else {
                    true
                }
            } else {
                s.backoff_until.map_or(true, |until| until <= backoff_now)
            };
            if runnable {
                active.push(i);
            }
        }
        if active.is_empty() {
            let (dt, dp) = (self.timeouts.get() - timeouts0, self.preemptions.get() - preemptions0);
            self.update_ladder(dt as usize, dp as usize);
            self.record_terminal_spans(&events);
            return events;
        }
        self.steps.inc();
        self.active_steps.add(active.len() as u64);
        let t0 = self.hub.now();
        let chunk = self.opts.prefill_chunk.max(1);
        let pool = self.opts.pool.clone();
        match pool {
            Some(pool) if pool.size() > 1 && active.len() > 1 => {
                let base = SlotsPtr(self.slots.as_mut_ptr());
                let idxs = &active;
                pool.scope_run(idxs.len(), |j| {
                    // SAFETY: the indices in `idxs` are distinct, so each
                    // job has exclusive access to its slot, and `scope_run`
                    // joins every job before returning, so the pointer
                    // outlives all accesses.
                    let slot = unsafe { &mut *base.0.add(idxs[j]) };
                    slot.as_mut().expect("active slot").run_iteration(chunk);
                });
            }
            _ => {
                for &i in &active {
                    self.slots[i].as_mut().expect("active slot").run_iteration(chunk);
                }
            }
        }
        let now = Instant::now();
        let t1 = self.hub.now();
        let tracer = self.hub.tracer().cloned();
        // Pass 1: stream every sampled token first — also for slots that
        // erred or are about to be preempted, whose progress (including a
        // token sampled right before a failed feed) must be kept.
        let mut outcomes: Vec<(usize, bool, Option<Error>)> = Vec::with_capacity(active.len());
        for &i in &active {
            let (emitted, done, error) = {
                let slot = self.slots[i].as_mut().expect("active slot");
                let o = std::mem::take(&mut slot.outcome);
                if o.error.is_none() {
                    // Any successful iteration clears the retry streak.
                    slot.retries = 0;
                    slot.backoff_until = None;
                    slot.backoff_steps = 0;
                }
                if let Some(tr) = &tracer {
                    if o.unit != SpanKind::Idle {
                        let detail = if o.emitted.is_empty() {
                            String::new()
                        } else {
                            format!("tokens={}", o.emitted.len())
                        };
                        tr.record(SpanEvent {
                            request: slot.req.id,
                            kind: o.unit,
                            start: t0,
                            end: t1,
                            detail,
                        });
                    }
                }
                (o.emitted, o.done, o.error)
            };
            // A plain iteration emits at most one token; a speculation
            // round's verify+commit emits its whole accepted run at once
            // (they genuinely became available at the same instant, so
            // the tokens after the first record ~zero inter-token gaps).
            for (off, &token) in emitted.iter().enumerate() {
                let (id, index, is_first, dt) = {
                    let slot = self.slots[i].as_mut().expect("active slot");
                    let is_first = slot.first_token.is_none();
                    let since = if is_first { slot.admitted } else { slot.last_event };
                    if is_first {
                        slot.first_token = Some(now);
                    }
                    slot.last_event = now;
                    (
                        slot.req.id,
                        slot.generated - emitted.len() + off,
                        is_first,
                        now.duration_since(since).as_secs_f64(),
                    )
                };
                if is_first {
                    self.ttfts.push(dt);
                    self.ttft_hist.observe(dt);
                } else {
                    self.itls.push(dt);
                    self.itl_hist.observe(dt);
                }
                self.generated_tokens.inc();
                events.push(GenerateEvent::Token { id, token, index });
            }
            outcomes.push((i, done, error));
        }
        // Pass 2a: retire completed requests first, freeing their blocks.
        let mut failures: Vec<(usize, Error)> = Vec::new();
        for (i, done, error) in outcomes {
            if let Some(err) = error {
                failures.push((i, err));
            } else if done {
                let slot = self.slots[i].take().expect("active slot");
                self.completed.inc();
                let stats = slot.session.stats().clone();
                self.merge_policy_stats(&slot.req.policy, &stats);
                self.recycle(slot.session);
                let ttft = slot
                    .first_token
                    .map(|t| t.duration_since(slot.admitted).as_secs_f64())
                    .unwrap_or(0.0);
                events.push(GenerateEvent::Finished(GenerateResponse {
                    id: slot.req.id,
                    prompt_len: slot.prompt_len,
                    policy: slot.req.policy,
                    tokens: slot.tokens,
                    stats,
                    ttft_s: ttft,
                    latency_s: now.duration_since(slot.admitted).as_secs_f64(),
                }));
            }
        }
        // Pass 2b: a resource error (KV pool exhausted) preempts the
        // *youngest* live healthy session — the vLLM-style victim policy —
        // so the erroring slot (its failed step changed no session state)
        // simply retries next iteration with the victim's blocks freed.
        // With no healthy co-tenant the erroring slot itself is preempted
        // — EXCEPT the oldest failing slot, which stays live: co-admitted
        // equal-length sessions exhaust the pool in lockstep, and without
        // a protected survivor they would mutually preempt, re-admit, and
        // re-exhaust forever. With no co-tenant at all the request can
        // never fit: fail it.
        let pending: Vec<usize> = failures.iter().map(|(i, _)| *i).collect();
        let mut protected: Option<(usize, Instant)> = None;
        for (i, err) in &failures {
            if err.is_resource() {
                if let Some(slot) = &self.slots[*i] {
                    if protected.map(|(_, t)| slot.admitted < t).unwrap_or(true) {
                        protected = Some((*i, slot.admitted));
                    }
                }
            }
        }
        let protected = protected.map(|(i, _)| i);
        for (i, err) in failures {
            if self.slots[i].is_none() {
                // Already preempted as another slot's victim: its progress
                // is queued for recompute; nothing to fail.
                continue;
            }
            if err.is_resource() {
                // Prefer the youngest live *healthy* co-tenant as victim.
                let mut victim: Option<(usize, Instant)> = None;
                for (j, s) in self.slots.iter().enumerate() {
                    if j == i || pending.contains(&j) {
                        continue;
                    }
                    if let Some(slot) = s {
                        if victim.map(|(_, t)| slot.admitted >= t).unwrap_or(true) {
                            victim = Some((j, slot.admitted));
                        }
                    }
                }
                if let Some((j, _)) = victim {
                    self.preempt(j);
                    continue;
                }
                if active.len() > 1 {
                    if protected == Some(i) {
                        // The oldest failing slot stays live and retries
                        // next step: the other failing co-tenants preempt
                        // below, so their freed blocks guarantee progress.
                        continue;
                    }
                    // Every healthy co-tenant is gone — requeue this
                    // request's progress and retry after the protected
                    // survivor advances.
                    self.preempt(i);
                    continue;
                }
            }
            if err.is_retryable() {
                // Transient fault — or pool exhaustion while running alone
                // (an injected spike clears on retry; real exhaustion
                // persists and exhausts the budget). The failed step
                // changed no session state, so the retry *re-feeds* the
                // same token: the stream stays bit-identical to solo
                // decode. Exponential backoff with deterministic jitter.
                let retry = self.opts.retry.clone();
                let slot = self.slots[i].as_mut().expect("live slot");
                if slot.retries < retry.max_retries {
                    slot.retries += 1;
                    if virtual_clock {
                        // Iteration-counted exponential backoff: same
                        // doubling shape as the wall policy, but ticked
                        // by `step` calls so replays are deterministic.
                        slot.backoff_steps = 1usize << (slot.retries - 1).min(6);
                    } else {
                        slot.backoff_until =
                            Some(now + retry.delay(slot.req.seed, slot.retries));
                    }
                    self.retries.inc();
                    continue;
                }
            }
            // Non-retryable failure, or the retry budget is spent.
            let slot = self.slots[i].take().expect("live slot");
            self.failed.inc();
            self.recycle(slot.session);
            events.push(GenerateEvent::Failed { id: slot.req.id, error: err });
        }
        let (dt, dp) = (self.timeouts.get() - timeouts0, self.preemptions.get() - preemptions0);
        self.update_ladder(dt as usize, dp as usize);
        self.record_terminal_spans(&events);
        events
    }

    /// Record a Retire/Fail marker for every terminal event in this
    /// step's batch. Centralized here (events carry the request ids) so
    /// the half-dozen retire/fail sites stay span-free.
    fn record_terminal_spans(&self, events: &[GenerateEvent]) {
        let Some(tr) = self.hub.tracer() else { return };
        let tick = self.hub.now();
        for ev in events {
            match ev {
                GenerateEvent::Finished(r) => tr.instant(r.id, SpanKind::Retire, tick),
                GenerateEvent::Failed { id, .. } => tr.instant(*id, SpanKind::Fail, tick),
                GenerateEvent::Token { .. } => {}
            }
        }
    }

    /// Preempt the live slot at `idx`: release its blocks (recycle resets
    /// the session) and queue its progress — tokens, sampling RNG, timing
    /// — at the *front* for recompute-on-resume. No `LampStats` are
    /// carried: the resumed session re-counts its whole prefix, keeping
    /// every causal product single-counted (the dedupe contract
    /// `scheduler_parity.rs` pins).
    fn preempt(&mut self, idx: usize) {
        let slot = self.slots[idx].take().expect("live victim slot");
        self.preemptions.inc();
        if let Some(tr) = self.hub.tracer() {
            tr.instant(slot.req.id, SpanKind::Preempt, self.hub.now());
        }
        self.recycle(slot.session);
        self.waiting.push_front(WaitingEntry {
            req: slot.req,
            enqueued: slot.admitted,
            resume: Some(ResumeState {
                tokens: slot.tokens,
                prompt_len: slot.prompt_len,
                generated: slot.generated,
                rng: slot.rng,
                first_token: slot.first_token,
                last_event: slot.last_event,
            }),
        });
    }

    /// Fail everything queued and in flight with one typed timeout event
    /// each — the run-budget backstop. The one-terminal-event invariant
    /// holds: every aborted request gets exactly one `Failed`.
    fn abort_all(&mut self, events: &mut Vec<GenerateEvent>, why: &str) {
        let first_new = events.len();
        while let Some(entry) = self.waiting.pop_front() {
            self.failed.inc();
            self.timeouts.inc();
            events.push(GenerateEvent::Failed {
                id: entry.req.id,
                error: Error::timeout(why.to_string()),
            });
        }
        for i in 0..self.slots.len() {
            if let Some(slot) = self.slots[i].take() {
                self.failed.inc();
                self.timeouts.inc();
                self.recycle(slot.session);
                events.push(GenerateEvent::Failed {
                    id: slot.req.id,
                    error: Error::timeout(why.to_string()),
                });
            }
        }
        self.record_terminal_spans(&events[first_new..]);
    }

    /// When a step made no observable progress, sleep only if every live
    /// slot is sitting out a retry backoff (so spinning can't help), or
    /// briefly when nothing is live but the queue is pool-gated. Steps
    /// that advanced a session (prefill emits no events) never sleep.
    fn idle_backoff(&self) {
        if self.hub.is_virtual() {
            // Virtual-clock drives (replay) never sleep: backoff is
            // counted in iterations, and wall sleeps would only slow the
            // deterministic schedule down.
            return;
        }
        let now = Instant::now();
        let mut runnable = false;
        let mut earliest: Option<Instant> = None;
        for slot in self.slots.iter().flatten() {
            match slot.backoff_until {
                Some(until) if until > now => {
                    earliest = Some(earliest.map_or(until, |e| e.min(until)));
                }
                _ => runnable = true,
            }
        }
        if runnable {
            return;
        }
        if let Some(until) = earliest {
            std::thread::sleep((until - now).min(Duration::from_millis(2)));
        } else if !self.waiting.is_empty() {
            std::thread::sleep(Duration::from_micros(20));
        }
    }

    /// Step until idle or until the configured step/wall budget trips,
    /// extending `sink` with every event. On budget expiry all remaining
    /// requests are failed with typed timeout events (pushed to `sink`)
    /// and `Err(Error::Timeout)` is returned — the caller can no longer
    /// hang on a wedged slot or a permanently gated queue.
    pub fn run_until_idle(
        &mut self,
        sink: &mut Vec<GenerateEvent>,
    ) -> crate::error::Result<()> {
        let started = Instant::now();
        let mut iterations = 0usize;
        while !self.is_idle() {
            if self.opts.max_run_steps.is_some_and(|max| iterations >= max) {
                let why = format!(
                    "scheduler run exceeded its {} iteration budget",
                    self.opts.max_run_steps.unwrap_or(0)
                );
                self.abort_all(sink, &why);
                return Err(Error::timeout(why));
            }
            if self.opts.max_run_wall.is_some_and(|max| started.elapsed() >= max) {
                let why = format!(
                    "scheduler run exceeded its {:.3}s wall budget",
                    self.opts.max_run_wall.unwrap_or_default().as_secs_f64()
                );
                self.abort_all(sink, &why);
                return Err(Error::timeout(why));
            }
            iterations += 1;
            let events = self.step();
            let quiet = events.is_empty();
            sink.extend(events);
            if quiet {
                self.idle_backoff();
            }
        }
        Ok(())
    }

    /// Step until everything queued has retired; returns the full event
    /// stream in emission order. A tripped run budget surfaces as typed
    /// timeout `Failed` events at the tail of the stream (use
    /// [`Self::run_until_idle`] to observe the `Err` itself).
    pub fn run(&mut self) -> Vec<GenerateEvent> {
        let mut all = Vec::new();
        let _ = self.run_until_idle(&mut all);
        all
    }

    /// Like [`Self::run`], keeping only the completed responses. Returns
    /// the typed [`Error::Timeout`] when the run budget tripped.
    pub fn run_to_completion(&mut self) -> crate::error::Result<Vec<GenerateResponse>> {
        let mut all = Vec::new();
        self.run_until_idle(&mut all)?;
        Ok(all
            .into_iter()
            .filter_map(|e| match e {
                GenerateEvent::Finished(r) => Some(r),
                _ => None,
            })
            .collect())
    }

    /// Metrics snapshot. Counters are read back from the registry
    /// handles; point-in-time state (KV pool, ladder rung, per-site
    /// rates, fault totals) is mirrored into registry gauges here so a
    /// registry snapshot taken after `metrics()` is self-contained.
    pub fn metrics(&self) -> DecodeMetrics {
        let kv = self.engine.kv_pool().map(|pool| pool.stats());
        let (kv_format, kv_resident_bytes, kv_blocks_used, kv_blocks_capacity) = match &kv {
            Some(s) => (s.format.clone(), s.resident_bytes, s.used_blocks, s.capacity_blocks),
            None => (self.engine.kv_format().label(), 0, 0, 0),
        };
        let kv_occupancy = kv.as_ref().map(|s| s.occupancy()).unwrap_or(0.0);
        let prefix_share_hits = kv.as_ref().map(|s| s.share_hits).unwrap_or(0);
        let prefix_share_rate = kv.as_ref().map(|s| s.share_rate()).unwrap_or(0.0);
        let faults_injected =
            self.engine.fault_stats().map(|f| f.total()).unwrap_or(0);
        let recompute_by_site = self.totals.site_rates();
        let steps = self.steps.get() as usize;
        let reg = self.hub.registry();
        reg.gauge("kv.occupancy").set(kv_occupancy);
        reg.gauge("kv.blocks_used").set(kv_blocks_used as f64);
        reg.gauge("kv.blocks_capacity").set(kv_blocks_capacity as f64);
        reg.gauge("kv.resident_bytes").set(kv_resident_bytes as f64);
        reg.gauge("kv.prefix_share_hits").set(prefix_share_hits as f64);
        reg.gauge("kv.prefix_share_rate").set(prefix_share_rate);
        reg.gauge("sched.ladder_rung").set(self.ladder_rung as f64);
        reg.gauge("faults.injected").set(faults_injected as f64);
        for (site, rate) in &recompute_by_site {
            reg.gauge(&format!("lamp.recompute_rate.{site}")).set(*rate);
        }
        DecodeMetrics {
            completed: self.completed.get() as usize,
            failed: self.failed.get() as usize,
            generated_tokens: self.generated_tokens.get() as usize,
            steps,
            ttft_p50_s: percentile(&self.ttfts, 0.50),
            ttft_p95_s: percentile(&self.ttfts, 0.95),
            itl_p50_s: percentile(&self.itls, 0.50),
            itl_p95_s: percentile(&self.itls, 0.95),
            mean_active_sessions: if steps == 0 {
                0.0
            } else {
                self.active_steps.get() as f64 / steps as f64
            },
            recomputed: self.totals.recomputed,
            causal_total: self.totals.causal_total,
            recompute_by_policy: self
                .by_policy
                .iter()
                .map(|(l, s)| (l.clone(), s.rate()))
                .collect(),
            recompute_by_site,
            preemptions: self.preemptions.get() as usize,
            kv_format,
            kv_resident_bytes,
            kv_blocks_used,
            kv_blocks_capacity,
            kv_occupancy,
            prefix_share_hits,
            prefix_share_rate,
            retries: self.retries.get() as usize,
            timeouts: self.timeouts.get() as usize,
            canceled: self.canceled.get() as usize,
            faults_injected,
            degraded_admissions: self.degraded_admissions.get() as usize,
            degrade_transitions: self.degrades.get() as usize,
            restore_transitions: self.restores.get() as usize,
            ladder_rung: self.ladder_rung,
            ladder_rung_name: self
                .opts
                .ladder
                .as_ref()
                .map(|l| l.rung_name(self.ladder_rung).to_string())
                .unwrap_or_else(|| "none".to_string()),
            spec_rounds: self.totals.spec.rounds,
            spec_drafted: self.totals.spec.drafted,
            spec_accepted: self.totals.spec.accepted,
            spec_draft_steps: self.totals.spec.draft_steps,
            spec_verify_chunks: self.totals.spec.verify_chunks,
            spec_acceptance_rate: self.totals.spec.acceptance_rate(),
            spec_mean_accept_len: self.totals.spec.mean_accept_len(),
            spec_accept_hist: self.totals.spec.accept_hist.clone(),
        }
    }
}

// The crate's single nearest-rank percentile (TTFT/ITL latencies here,
// `BenchStats` in benchkit, server batch latencies) lives in
// `metrics::stats`; the old floor-index copy that silently reported the
// max sample as p95 over 15–20 samples is gone.
pub(crate) use crate::metrics::stats::percentile;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineOutput, NativeEngine};
    use crate::coordinator::policy::Rule;
    use crate::model::{Decode, ModelConfig, Weights};

    fn engine() -> NativeEngine {
        let mut rng = Rng::new(11);
        NativeEngine::new(Weights::random(&ModelConfig::nano(), &mut rng).unwrap())
    }

    fn greedy(id: u64, prompt: Vec<u32>, n: usize, policy: PrecisionPolicy) -> GenerateRequest {
        GenerateRequest::new(id, prompt, n, policy)
    }

    #[test]
    fn single_request_matches_solo_generate() {
        let e = engine();
        let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
        let (solo, rate) = e.generate(&[1, 2, 3], 6, &policy, Decode::Greedy, 1).unwrap();
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(1, vec![1, 2, 3], 6, policy));
        let responses = sched.run_to_completion().unwrap();
        assert_eq!(responses.len(), 1);
        assert_eq!(responses[0].tokens, solo);
        assert_eq!(responses[0].prompt_len, 3);
        assert_eq!(responses[0].stats.rate(), rate, "stats must match solo accounting");
        assert!(sched.is_idle());
    }

    #[test]
    fn token_events_stream_the_continuation() {
        let e = engine();
        let policy = PrecisionPolicy::reference();
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(7, vec![4, 5], 5, policy));
        let events = sched.run();
        let mut streamed = Vec::new();
        let mut finished = None;
        for ev in events {
            match ev {
                GenerateEvent::Token { id, token, index } => {
                    assert_eq!(id, 7);
                    assert_eq!(index, streamed.len(), "tokens must stream in order");
                    streamed.push(token);
                }
                GenerateEvent::Finished(r) => finished = Some(r),
                GenerateEvent::Failed { error, .. } => panic!("unexpected failure: {error}"),
            }
        }
        let r = finished.expect("finished event");
        assert_eq!(r.generated(), &streamed[..], "stream equals final suffix");
        assert_eq!(streamed.len(), 5);
    }

    #[test]
    fn degenerate_requests_complete_immediately() {
        let e = engine();
        let policy = PrecisionPolicy::reference();
        let seq = e.config().seq;
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(1, vec![1; seq], 4, policy)); // prompt fills context
        sched.admit(greedy(2, vec![1, 2], 0, policy)); // zero budget
        let responses = sched.run_to_completion().unwrap();
        assert_eq!(responses.len(), 2);
        for r in &responses {
            assert_eq!(r.generated(), &[] as &[u32]);
            assert_eq!(r.stats.causal_total, 0);
        }
    }

    #[test]
    fn eos_stops_a_prefix_of_the_solo_stream() {
        let e = engine();
        let policy = PrecisionPolicy::reference();
        let (solo, _) = e.generate(&[3, 14], 10, &policy, Decode::Greedy, 2).unwrap();
        let continuation = &solo[2..];
        assert!(!continuation.is_empty());
        // Stop at the first generated token: exactly one token comes out.
        let eos = continuation[0];
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(1, vec![3, 14], 10, policy).with_seed(2).with_eos(eos));
        let responses = sched.run_to_completion().unwrap();
        assert_eq!(responses[0].generated(), &continuation[..1]);
    }

    #[test]
    fn more_requests_than_slots_all_complete() {
        let e = engine();
        let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Random);
        let opts =
            SchedulerOptions { max_sessions: 2, prefill_chunk: 2, ..Default::default() };
        let mut sched = Scheduler::new(&e, opts);
        let mut solos = Vec::new();
        for id in 0..5u64 {
            let prompt = vec![(id as u32 * 13 + 1) % 128, 2, 3];
            let n = 3 + (id as usize % 4);
            solos.push(e.generate(&prompt, n, &policy, Decode::Greedy, id).unwrap().0);
            sched.admit(greedy(id, prompt, n, policy));
        }
        let mut responses = sched.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 5);
        for (r, solo) in responses.iter().zip(&solos) {
            assert_eq!(&r.tokens, solo, "id {} diverged from solo decode", r.id);
        }
        let m = sched.metrics();
        assert_eq!(m.completed, 5);
        assert_eq!(m.failed, 0);
        assert!(m.mean_active_sessions > 1.0, "slots should overlap");
        assert!(m.mean_active_sessions <= 2.0 + 1e-9);
        assert_eq!(m.recompute_by_policy.len(), 1);
        assert!(m.causal_total > 0);
    }

    #[test]
    fn pool_stepping_is_bit_identical_to_sequential() {
        let e = engine();
        let pool = Arc::new(ThreadPool::new(3));
        let policies = [
            PrecisionPolicy::reference(),
            PrecisionPolicy::uniform(3),
            PrecisionPolicy::lamp(3, 0.05, Rule::Random),
        ];
        let build = |sched: &mut Scheduler| {
            for id in 0..4u64 {
                let prompt = vec![(id as u32 + 5) % 128; 2 + id as usize];
                sched.admit(
                    greedy(id, prompt, 6, policies[id as usize % 3])
                        .with_decode(Decode::TopK { k: 4, temperature: 1.3 }),
                );
            }
        };
        let mut seq_sched = Scheduler::new(
            &e,
            SchedulerOptions { max_sessions: 4, prefill_chunk: 3, ..Default::default() },
        );
        build(&mut seq_sched);
        let mut seq_out = seq_sched.run_to_completion().unwrap();
        seq_out.sort_by_key(|r| r.id);

        let mut par_sched = Scheduler::new(
            &e,
            SchedulerOptions {
                max_sessions: 4,
                prefill_chunk: 3,
                pool: Some(pool),
                ..Default::default()
            },
        );
        build(&mut par_sched);
        let mut par_out = par_sched.run_to_completion().unwrap();
        par_out.sort_by_key(|r| r.id);

        assert_eq!(seq_out.len(), par_out.len());
        for (a, b) in seq_out.iter().zip(&par_out) {
            assert_eq!(a.tokens, b.tokens, "pool changed request {}", a.id);
            assert_eq!(a.stats.recomputed, b.stats.recomputed);
        }
    }

    #[test]
    fn speculative_requests_match_solo_and_account_rounds() {
        use crate::coordinator::policy::{SitePolicy, SpecPolicy};
        let e = engine();
        let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
        let spec = target.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 3)));
        // Solo oracle under the *same spec policy* (bit-identical to the
        // non-speculative target stream by the sampler-level parity test;
        // here we pin the scheduler against it, mixed with plain slots).
        let mut solos = Vec::new();
        let mut sched = Scheduler::new(
            &e,
            SchedulerOptions { max_sessions: 3, prefill_chunk: 2, ..Default::default() },
        );
        for id in 0..4u64 {
            let prompt = vec![(id as u32 * 7 + 3) % 128, 11, 2];
            let policy = if id % 2 == 0 { spec } else { target };
            let n = 5 + id as usize;
            solos.push(e.generate(&prompt, n, &policy, Decode::Greedy, id).unwrap().0);
            sched.admit(greedy(id, prompt, n, policy).with_seed(id));
        }
        let mut responses = sched.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 4);
        for (r, solo) in responses.iter().zip(&solos) {
            assert_eq!(&r.tokens, solo, "id {} diverged from solo decode", r.id);
        }
        // Spec slots accounted rounds; plain slots did not.
        for r in &responses {
            if r.id % 2 == 0 {
                assert!(r.stats.spec.rounds > 0, "id {}: no rounds", r.id);
                assert!(r.stats.spec.verify_chunks > 0);
            } else {
                assert_eq!(r.stats.spec.rounds, 0, "id {}: phantom rounds", r.id);
            }
        }
        let m = sched.metrics();
        assert!(m.spec_rounds > 0 && m.spec_drafted > 0);
        assert_eq!(
            m.spec_accept_hist.iter().sum::<usize>(),
            m.spec_rounds,
            "histogram must partition the rounds"
        );
        assert!(m.spec_mean_accept_len >= 1.0);
        assert_eq!(m.spec_verify_chunks, m.spec_rounds);
    }

    #[test]
    fn speculative_eos_stops_a_prefix_of_the_solo_stream() {
        use crate::coordinator::policy::{SitePolicy, SpecPolicy};
        let e = engine();
        let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
        let spec = target.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(3), 4)));
        let (solo, _) = e.generate(&[3, 14], 10, &spec, Decode::Greedy, 2).unwrap();
        let continuation = &solo[2..];
        assert!(continuation.len() >= 3);
        // Stop mid-continuation: a round may overshoot the stop token
        // internally, but the emitted stream must cut exactly there.
        let eos = continuation[2];
        let cut = continuation.iter().position(|&t| t == eos).unwrap();
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(1, vec![3, 14], 10, spec).with_seed(2).with_eos(eos));
        let responses = sched.run_to_completion().unwrap();
        assert_eq!(responses[0].generated(), &continuation[..=cut]);
    }

    #[test]
    fn speculative_slots_survive_tiny_pool_preemption() {
        use crate::coordinator::policy::{SitePolicy, SpecPolicy};
        use crate::coordinator::{KvCacheOptions, WeightFormat};
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(41);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let oracle = NativeEngine::new(w.clone());
        let mut opts = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
        opts.block_size = 4;
        opts.capacity_blocks = 12;
        opts.sharing = false;
        let e = NativeEngine::new(w).with_kv_cache(opts).unwrap();
        let policy = PrecisionPolicy::lamp(3, 0.1, Rule::Strict)
            .with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 2)));
        let mut sched = Scheduler::new(
            &e,
            SchedulerOptions { max_sessions: 2, prefill_chunk: 4, ..Default::default() },
        );
        let mut solos = Vec::new();
        for id in 0..3u64 {
            let prompt = vec![(id as u32 * 11 + 3) % 128, 7, 9, 2];
            solos.push(oracle.generate(&prompt, 24, &policy, Decode::Greedy, id).unwrap().0);
            sched.admit(greedy(id, prompt, 24, policy).with_seed(id));
        }
        let mut responses = sched.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 3, "every spec request completes despite pressure");
        for (r, solo) in responses.iter().zip(&solos) {
            assert_eq!(&r.tokens, solo, "id {}: preemption broke a spec stream", r.id);
        }
        // Rollback-heavy run: the pool must settle back to empty once the
        // scheduler is idle (no leaked scratch/staged blocks).
        assert!(sched.is_idle());
        assert_eq!(e.kv_pool().unwrap().stats().used_blocks, 0, "leaked KV blocks");
    }

    #[test]
    fn tiny_kv_pool_preempts_and_streams_match_solo() {
        use crate::coordinator::{KvCacheOptions, WeightFormat};
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(31);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let oracle = NativeEngine::new(w.clone());
        let mut opts = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
        opts.block_size = 4;
        opts.capacity_blocks = 12; // ~1.5 full-context sessions
        opts.sharing = false; // keep per-request stats comparable to solo
        let e = NativeEngine::new(w).with_kv_cache(opts).unwrap();
        let policy = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
        let mut sched = Scheduler::new(
            &e,
            SchedulerOptions { max_sessions: 2, prefill_chunk: 4, ..Default::default() },
        );
        let mut solos = Vec::new();
        for id in 0..3u64 {
            let prompt = vec![(id as u32 * 11 + 3) % 128, 7, 9, 2];
            solos.push(oracle.generate(&prompt, 27, &policy, Decode::Greedy, id).unwrap());
            sched.admit(greedy(id, prompt, 27, policy).with_seed(id));
        }
        let mut responses = sched.run_to_completion().unwrap();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 3, "every request completes despite preemption");
        for (r, (toks, rate)) in responses.iter().zip(&solos) {
            assert_eq!(&r.tokens, toks, "id {}: preemption changed the stream", r.id);
            // The LampStats dedupe regression: recomputed prefill after a
            // preemption must not re-count products — totals and rate
            // equal the uninterrupted solo run exactly.
            assert_eq!(
                r.stats.causal_total,
                e.config().causal_products(r.tokens.len()),
                "id {}: products double-counted across preemption",
                r.id
            );
            assert_eq!(r.stats.rate(), *rate, "id {}: recompute rate diverged", r.id);
        }
        let m = sched.metrics();
        assert!(m.preemptions > 0, "a 1.5-session pool must force preemption");
        assert_eq!(m.failed, 0);
        assert_eq!(m.kv_format, "f32");
        assert_eq!(m.kv_blocks_capacity, 12);
        assert!(sched.is_idle());
    }

    #[test]
    fn sessionless_backend_fails_requests_cleanly() {
        struct NoDecode(ModelConfig);
        impl Engine for NoDecode {
            fn config(&self) -> &ModelConfig {
                &self.0
            }
            fn infer(
                &self,
                _tokens: &[Vec<u32>],
                _policy: &PrecisionPolicy,
                _seed: i32,
            ) -> crate::error::Result<EngineOutput> {
                Err(Error::runtime("stub".to_string()))
            }
            fn backend(&self) -> &'static str {
                "stub"
            }
        }
        let e = NoDecode(ModelConfig::nano());
        let mut sched = Scheduler::new(&e, SchedulerOptions::default());
        sched.admit(greedy(1, vec![1, 2], 4, PrecisionPolicy::reference()));
        sched.admit(greedy(2, vec![3], 4, PrecisionPolicy::reference()));
        let events = sched.run();
        let failed: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                GenerateEvent::Failed { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(failed, vec![1, 2]);
        assert!(sched.is_idle());
        assert_eq!(sched.metrics().failed, 2);
    }

    #[test]
    fn percentile_basics() {
        // The scheduler's percentiles ride the consolidated nearest-rank
        // implementation in `metrics::stats` (full coverage lives there).
        assert_eq!(percentile(&[], 0.5), 0.0);
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 0.95), 5.0);
        // 20 samples: p95 is the 19th order statistic, not the max (the
        // floor-index bug this consolidation removed).
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 19.0);
    }
}
