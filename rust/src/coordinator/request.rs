//! Request/response types for the serving API, plus padding helpers.

use super::policy::PrecisionPolicy;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::model::LampStats;

/// A single-sequence inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Token ids; 1..=seq tokens (shorter sequences are padded into the
    /// fixed-shape artifact batch and the padding positions discarded).
    pub tokens: Vec<u32>,
    /// Requested precision policy.
    pub policy: PrecisionPolicy,
    /// Seed for the Random rule (ignored otherwise).
    pub seed: i32,
}

impl InferenceRequest {
    pub fn new(id: u64, tokens: Vec<u32>, policy: PrecisionPolicy) -> Self {
        InferenceRequest { id, tokens, policy, seed: id as i32 }
    }

    pub fn validate(&self, vocab: usize, max_seq: usize) -> Result<()> {
        self.policy.validate()?;
        if self.tokens.is_empty() || self.tokens.len() > max_seq {
            return Err(Error::shape(format!(
                "request {}: {} tokens out of 1..={max_seq}",
                self.id,
                self.tokens.len()
            )));
        }
        if let Some(&t) = self.tokens.iter().find(|&&t| t as usize >= vocab) {
            return Err(Error::shape(format!(
                "request {}: token {t} >= vocab {vocab}",
                self.id
            )));
        }
        Ok(())
    }

    /// Pad to `seq` tokens by repeating the last token (attention is
    /// causal, so padding after the real prefix cannot change the prefix's
    /// logits; the response slices them away).
    pub fn padded(&self, seq: usize) -> Vec<u32> {
        let mut out = self.tokens.clone();
        let last = *out.last().expect("validated non-empty");
        out.resize(seq, last);
        out
    }
}

/// The response for one request.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Logits for the *real* (unpadded) positions: [len, vocab].
    pub logits: Matrix,
    /// Recomputation statistics for the batch this request rode in
    /// (batch-level: the artifact reports one counter per execution).
    pub batch_stats: LampStats,
    /// End-to-end latency of this request (queue + execute), seconds.
    pub latency_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Rule;

    #[test]
    fn validation() {
        let p = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        let r = InferenceRequest::new(1, vec![1, 2, 3], p);
        assert!(r.validate(128, 32).is_ok());
        assert!(r.validate(2, 32).is_err()); // token out of vocab
        assert!(r.validate(128, 2).is_err()); // too long
        let empty = InferenceRequest::new(2, vec![], p);
        assert!(empty.validate(128, 32).is_err());
    }

    #[test]
    fn padding_repeats_last() {
        let p = PrecisionPolicy::reference();
        let r = InferenceRequest::new(1, vec![5, 9], p);
        assert_eq!(r.padded(5), vec![5, 9, 9, 9, 9]);
        assert_eq!(r.padded(2), vec![5, 9]);
    }
}
