//! Request/response types for the serving API, plus padding helpers.

use super::policy::PrecisionPolicy;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::model::{Decode, LampStats};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Per-request latency budgets, measured from admission to the scheduler
/// (enqueue time). `None` fields are unbounded — the default.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Deadline {
    /// Budget for the first generated token (TTFT).
    pub ttft: Option<Duration>,
    /// Budget for the whole request (enqueue → retirement).
    pub total: Option<Duration>,
}

impl Deadline {
    /// True when no budget is set (the unbounded default).
    pub fn is_unbounded(&self) -> bool {
        self.ttft.is_none() && self.total.is_none()
    }
}

/// Shared cancellation handle for one generation request.
///
/// Clone it, hand the clone to the submitter, and `cancel()` from any
/// thread: the scheduler retires the request with a typed
/// `Error::Canceled` terminal event at its next step boundary, keeping
/// every token already streamed.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_canceled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// A single-sequence inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Token ids; 1..=seq tokens (shorter sequences are padded into the
    /// fixed-shape artifact batch and the padding positions discarded).
    pub tokens: Vec<u32>,
    /// Requested precision policy.
    pub policy: PrecisionPolicy,
    /// Seed for the Random rule (ignored otherwise).
    pub seed: i32,
}

impl InferenceRequest {
    pub fn new(id: u64, tokens: Vec<u32>, policy: PrecisionPolicy) -> Self {
        InferenceRequest { id, tokens, policy, seed: id as i32 }
    }

    pub fn validate(&self, vocab: usize, max_seq: usize) -> Result<()> {
        self.policy.validate()?;
        if self.tokens.is_empty() || self.tokens.len() > max_seq {
            return Err(Error::shape(format!(
                "request {}: {} tokens out of 1..={max_seq}",
                self.id,
                self.tokens.len()
            )));
        }
        if let Some(&t) = self.tokens.iter().find(|&&t| t as usize >= vocab) {
            return Err(Error::shape(format!(
                "request {}: token {t} >= vocab {vocab}",
                self.id
            )));
        }
        Ok(())
    }

    /// Pad to `seq` tokens by repeating the last token (attention is
    /// causal, so padding after the real prefix cannot change the prefix's
    /// logits; the response slices them away).
    pub fn padded(&self, seq: usize) -> Vec<u32> {
        let mut out = self.tokens.clone();
        let last = *out.last().expect("validated non-empty");
        out.resize(seq, last);
        out
    }
}

/// An autoregressive generation request, served by the continuous-batching
/// decode scheduler (`coordinator::scheduler`).
///
/// Each request carries its own sampling parameters and seed; the scheduler
/// guarantees the resulting token stream is bit-identical to running the
/// request alone through `NativeEngine::generate` with the same seed,
/// regardless of what else is in flight.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Client-assigned id, echoed in every event for this request.
    pub id: u64,
    /// Prompt token ids (non-empty, within the context window).
    pub prompt: Vec<u32>,
    /// Upper bound on generated tokens (the context window also caps it).
    pub max_new_tokens: usize,
    /// Requested precision policy.
    pub policy: PrecisionPolicy,
    /// Per-request sampling strategy (greedy or top-k + temperature).
    pub decode: Decode,
    /// Seed for both the sampling stream and the Random selection rule.
    pub seed: u64,
    /// Optional stop token: generation retires after emitting it.
    pub eos: Option<u32>,
    /// Latency budgets (TTFT / total); unbounded by default.
    pub deadline: Deadline,
    /// Cancellation handle; `None` means not cancelable.
    pub cancel: Option<CancelToken>,
}

impl GenerateRequest {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize, policy: PrecisionPolicy) -> Self {
        GenerateRequest {
            id,
            prompt,
            max_new_tokens,
            policy,
            decode: Decode::Greedy,
            seed: id,
            eos: None,
            deadline: Deadline::default(),
            cancel: None,
        }
    }

    /// Set the sampling strategy.
    pub fn with_decode(mut self, decode: Decode) -> Self {
        self.decode = decode;
        self
    }

    /// Set the sampling / Random-rule seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set a stop token.
    pub fn with_eos(mut self, eos: u32) -> Self {
        self.eos = eos.into();
        self
    }

    /// Set the TTFT budget.
    pub fn with_ttft_deadline(mut self, budget: Duration) -> Self {
        self.deadline.ttft = Some(budget);
        self
    }

    /// Set the total-latency budget.
    pub fn with_deadline(mut self, budget: Duration) -> Self {
        self.deadline.total = Some(budget);
        self
    }

    /// Make the request cancelable, returning the handle to cancel with.
    pub fn cancel_token(&mut self) -> CancelToken {
        let token = self.cancel.get_or_insert_with(CancelToken::new);
        token.clone()
    }

    /// True once the request's token (if any) has been canceled.
    pub fn is_canceled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|t| t.is_canceled())
    }

    pub fn validate(&self, vocab: usize, max_seq: usize) -> Result<()> {
        self.policy.validate()?;
        if self.prompt.is_empty() || self.prompt.len() > max_seq {
            return Err(Error::shape(format!(
                "generate request {}: {} prompt tokens out of 1..={max_seq}",
                self.id,
                self.prompt.len()
            )));
        }
        if let Some(&t) = self.prompt.iter().find(|&&t| t as usize >= vocab) {
            return Err(Error::shape(format!(
                "generate request {}: token {t} >= vocab {vocab}",
                self.id
            )));
        }
        if let Some(eos) = self.eos {
            if eos as usize >= vocab {
                return Err(Error::shape(format!(
                    "generate request {}: eos {eos} >= vocab {vocab}",
                    self.id
                )));
            }
        }
        if let Decode::TopK { k, temperature } = self.decode {
            // NaN must not slip through a `<= 0.0` comparison: a NaN
            // temperature would poison every sampling weight downstream.
            if k == 0 || temperature.is_nan() || temperature <= 0.0 {
                return Err(Error::config(format!(
                    "generate request {}: top-k needs k >= 1 and temperature > 0",
                    self.id
                )));
            }
        }
        Ok(())
    }
}

/// The completed output of one generation request.
#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub id: u64,
    /// Prompt followed by the generated continuation.
    pub tokens: Vec<u32>,
    /// Length of the prompt prefix inside [`Self::tokens`].
    pub prompt_len: usize,
    /// This request's own LAMP recomputation statistics (each causal
    /// product of its session counted exactly once).
    pub stats: LampStats,
    /// The precision policy the request was actually decoded under — the
    /// requested policy unless the degradation ladder stepped it down at
    /// admission. The stream is bit-identical to solo decode under *this*
    /// policy.
    pub policy: PrecisionPolicy,
    /// Time to first generated token, seconds (0 when nothing was generated).
    pub ttft_s: f64,
    /// End-to-end latency (admission → retirement), seconds.
    pub latency_s: f64,
}

impl GenerateResponse {
    /// The generated continuation (without the prompt).
    pub fn generated(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }
}

/// The response for one request.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// Logits for the *real* (unpadded) positions: [len, vocab].
    pub logits: Matrix,
    /// Recomputation statistics for the batch this request rode in
    /// (batch-level: the artifact reports one counter per execution).
    pub batch_stats: LampStats,
    /// End-to-end latency of this request (queue + execute), seconds.
    pub latency_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Rule;

    #[test]
    fn validation() {
        let p = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        let r = InferenceRequest::new(1, vec![1, 2, 3], p);
        assert!(r.validate(128, 32).is_ok());
        assert!(r.validate(2, 32).is_err()); // token out of vocab
        assert!(r.validate(128, 2).is_err()); // too long
        let empty = InferenceRequest::new(2, vec![], p);
        assert!(empty.validate(128, 32).is_err());
    }

    #[test]
    fn generate_request_validation() {
        let p = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        let ok = GenerateRequest::new(1, vec![1, 2, 3], 8, p);
        assert!(ok.validate(128, 32).is_ok());
        assert_eq!(ok.seed, 1, "seed defaults to the id");
        assert!(GenerateRequest::new(2, vec![], 8, p).validate(128, 32).is_err());
        assert!(GenerateRequest::new(3, vec![200], 8, p).validate(128, 32).is_err());
        assert!(GenerateRequest::new(4, vec![1; 40], 8, p).validate(128, 32).is_err());
        assert!(GenerateRequest::new(5, vec![1], 8, p)
            .with_eos(999)
            .validate(128, 32)
            .is_err());
        let bad_decode = GenerateRequest::new(6, vec![1], 8, p)
            .with_decode(Decode::TopK { k: 0, temperature: 1.0 });
        assert!(bad_decode.validate(128, 32).is_err());
        let bad_temp = GenerateRequest::new(7, vec![1], 8, p)
            .with_decode(Decode::TopK { k: 4, temperature: 0.0 });
        assert!(bad_temp.validate(128, 32).is_err());
        let nan_temp = GenerateRequest::new(8, vec![1], 8, p)
            .with_decode(Decode::TopK { k: 4, temperature: f32::NAN });
        assert!(nan_temp.validate(128, 32).is_err(), "NaN temperature must be rejected");
    }

    #[test]
    fn generate_response_suffix() {
        let r = GenerateResponse {
            id: 1,
            tokens: vec![5, 6, 7, 8],
            prompt_len: 2,
            stats: LampStats::default(),
            policy: PrecisionPolicy::reference(),
            ttft_s: 0.0,
            latency_s: 0.0,
        };
        assert_eq!(r.generated(), &[7, 8]);
    }

    #[test]
    fn deadlines_and_cancel_handle() {
        let p = PrecisionPolicy::reference();
        let r = GenerateRequest::new(1, vec![1], 4, p);
        assert!(r.deadline.is_unbounded());
        assert!(!r.is_canceled());
        let r = r
            .with_ttft_deadline(Duration::from_millis(5))
            .with_deadline(Duration::from_millis(50));
        assert_eq!(r.deadline.ttft, Some(Duration::from_millis(5)));
        assert_eq!(r.deadline.total, Some(Duration::from_millis(50)));
        assert!(!r.deadline.is_unbounded());
        let mut r = GenerateRequest::new(2, vec![1], 4, p);
        let token = r.cancel_token();
        // Repeated calls hand out the same underlying token.
        let again = r.cancel_token();
        assert!(!r.is_canceled());
        token.cancel();
        assert!(r.is_canceled() && again.is_canceled());
        token.cancel(); // idempotent
        assert!(r.is_canceled());
    }

    #[test]
    fn padding_repeats_last() {
        let p = PrecisionPolicy::reference();
        let r = InferenceRequest::new(1, vec![5, 9], p);
        assert_eq!(r.padded(5), vec![5, 9, 9, 9, 9]);
        assert_eq!(r.padded(2), vec![5, 9]);
    }
}
