//! Deterministic workload-trace replay over the scheduler.
//!
//! The replay driver feeds a [`TraceRequest`] list (see `data::traces`)
//! through an unmodified [`Scheduler`] on a *virtual clock*: one scheduler
//! iteration is one clock tick, and a trace request is admitted the first
//! iteration whose tick reaches its `arrival_step`. When the scheduler
//! drains before the next arrival, the clock jumps forward — idle gaps
//! cost no wall time and, more importantly, no nondeterminism.
//!
//! Determinism contract: the scheduler steps sessions
//! iteration-synchronously and keys every sampling stream by
//! `(seed, site, position)`, so per-request token streams and LAMP
//! counters depend only on the trace — not on the thread-pool size or the
//! host's speed. The replay hub's clock is always virtual, which also
//! makes retry backoff iteration-counted and recorded span timestamps
//! tick-valued: the observability output of a replay (trace exports,
//! registry counters) is deterministic across reruns too. Wall-clock
//! outputs (TTFT/latency percentiles) remain *not* deterministic and are
//! reported separately; the trials subsystem excludes them from
//! canonical output.

use std::sync::Arc;
use std::time::Instant;

use super::engine::Engine;
use super::policy::PrecisionPolicy;
use super::request::{GenerateRequest, GenerateResponse};
use super::scheduler::{DecodeMetrics, GenerateEvent, Scheduler, SchedulerOptions};
use crate::data::traces::TraceRequest;
use crate::error::{Error, Result};
use crate::obs::ObsHub;

/// How a trace is turned into scheduler traffic.
#[derive(Clone)]
pub struct ReplayOptions {
    /// Precision policy applied to every request of the trace.
    pub policy: PrecisionPolicy,
    /// Scheduler configuration (slot count, prefill chunk, pool, retry).
    pub scheduler: SchedulerOptions,
    /// Optional EOS token id applied to every request.
    pub eos: Option<u32>,
    /// Iteration budget; `None` derives a generous bound from the trace
    /// (arrival span plus a per-token allowance) so a wedged replay
    /// errors out instead of spinning forever.
    pub max_steps: Option<usize>,
}

impl ReplayOptions {
    pub fn new(policy: PrecisionPolicy) -> Self {
        ReplayOptions {
            policy,
            scheduler: SchedulerOptions::default(),
            eos: None,
            max_steps: None,
        }
    }
}

/// Everything a replayed trace produced.
#[derive(Debug)]
pub struct ReplayReport {
    /// Completed responses, sorted by request id.
    pub responses: Vec<GenerateResponse>,
    /// Failed requests as `(id, error message)`, sorted by id.
    pub failures: Vec<(u64, String)>,
    /// Scheduler metrics snapshot after the replay drained.
    pub metrics: DecodeMetrics,
    /// Scheduler iterations actually driven.
    pub steps: usize,
    /// Host wall time of the drive (NOT deterministic; for display only).
    pub wall_s: f64,
}

/// Replay `trace` through a fresh scheduler over `engine`. Request ids
/// are the trace indices, so outputs can be joined back to the trace.
pub fn replay(
    engine: &dyn Engine,
    trace: &[TraceRequest],
    opts: &ReplayOptions,
) -> Result<ReplayReport> {
    let budget = opts.max_steps.unwrap_or_else(|| {
        let tokens: usize = trace.iter().map(|r| r.prompt.len() + r.new_tokens).sum();
        let span = trace.last().map(|r| r.arrival_step).unwrap_or(0);
        // 64 iterations of slack per token covers retries and chunked
        // prefill at any slot count; the constant floor covers tiny traces.
        span + 1024 + tokens * 64
    });

    let started = Instant::now();
    // Replay always runs on a virtual-clock hub: scheduler timestamps,
    // retry backoff, and recorded spans are then counted in iterations,
    // making the whole drive — including its observability output —
    // deterministic across machines and reruns. A caller-supplied hub
    // (e.g. with a tracer attached) must itself be built with
    // `with_virtual_clock()` — `set_virtual` below is a no-op on wall
    // hubs, and a wall-clock hub would silently reintroduce host-speed
    // dependence into timestamps. The default is always virtual.
    let hub = opts
        .scheduler
        .obs
        .clone()
        .unwrap_or_else(|| Arc::new(ObsHub::new().with_virtual_clock()));
    let mut sched_opts = opts.scheduler.clone();
    sched_opts.obs = Some(Arc::clone(&hub));
    let mut sched = Scheduler::new(engine, sched_opts);
    let mut events: Vec<GenerateEvent> = Vec::new();
    let mut next = 0usize; // next trace index to admit
    let mut vstep = 0usize; // virtual clock, in scheduler iterations
    let mut iterations = 0usize;

    loop {
        hub.set_virtual(vstep as u64);
        while next < trace.len() && trace[next].arrival_step <= vstep {
            let r = &trace[next];
            let mut req = GenerateRequest::new(
                next as u64,
                r.prompt.clone(),
                r.new_tokens,
                opts.policy,
            )
            .with_decode(r.decode)
            .with_seed(r.seed);
            if let Some(eos) = opts.eos {
                req = req.with_eos(eos);
            }
            sched.admit(req);
            next += 1;
        }
        if sched.is_idle() {
            if next >= trace.len() {
                break;
            }
            // Idle gap: jump the virtual clock to the next arrival.
            vstep = vstep.max(trace[next].arrival_step);
            continue;
        }
        if iterations >= budget {
            return Err(Error::timeout(format!(
                "trace replay exceeded its {budget} iteration budget \
                 ({} of {} requests still in flight)",
                sched.pending() + sched.active(),
                trace.len()
            )));
        }
        iterations += 1;
        events.extend(sched.step());
        vstep += 1;
    }

    let mut responses = Vec::new();
    let mut failures = Vec::new();
    for event in events {
        match event {
            GenerateEvent::Finished(resp) => responses.push(resp),
            GenerateEvent::Failed { id, error } => failures.push((id, error.to_string())),
            GenerateEvent::Token { .. } => {}
        }
    }
    responses.sort_by_key(|r| r.id);
    failures.sort_by_key(|f| f.0);

    Ok(ReplayReport {
        responses,
        failures,
        metrics: sched.metrics(),
        steps: iterations,
        wall_s: started.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::data::traces::{TraceKind, TraceSpec};
    use crate::model::{Decode, ModelConfig, Weights};
    use crate::util::Rng;

    fn engine() -> NativeEngine {
        let cfg = ModelConfig::nano();
        let weights = Weights::random(&cfg, &mut Rng::new(7)).unwrap();
        NativeEngine::new(weights)
    }

    fn spec(kind: TraceKind, requests: usize) -> TraceSpec {
        let cfg = ModelConfig::nano();
        let mut s = TraceSpec::new(kind, cfg.vocab, cfg.seq);
        s.requests = requests;
        s.new_tokens = 4;
        s
    }

    #[test]
    fn replay_completes_every_request_and_is_deterministic() {
        let eng = engine();
        let trace = spec(TraceKind::Bursty, 6).generate().unwrap();
        let opts = ReplayOptions::new(PrecisionPolicy::reference());
        let a = replay(&eng, &trace, &opts).unwrap();
        assert_eq!(a.responses.len(), trace.len());
        assert!(a.failures.is_empty());
        assert!(a.steps > 0);
        // Ids are trace indices, sorted.
        let ids: Vec<u64> = a.responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..trace.len() as u64).collect::<Vec<_>>());

        let b = replay(&eng, &trace, &opts).unwrap();
        for (x, y) in a.responses.iter().zip(&b.responses) {
            assert_eq!(x.tokens, y.tokens, "same trace must replay identically");
        }
    }

    #[test]
    fn replay_matches_solo_generation() {
        // Interleaved replay must not change any request's tokens versus
        // running it alone through the engine.
        let eng = engine();
        let trace = spec(TraceKind::ZipfMix, 5).generate().unwrap();
        let opts = ReplayOptions::new(PrecisionPolicy::reference());
        let report = replay(&eng, &trace, &opts).unwrap();
        assert_eq!(report.responses.len(), trace.len());
        for resp in &report.responses {
            let r = &trace[resp.id as usize];
            let (solo, _) = eng
                .generate(&r.prompt, r.new_tokens, &opts.policy, r.decode, r.seed)
                .unwrap();
            assert_eq!(resp.tokens, solo, "request {} diverged from solo", resp.id);
        }
    }

    #[test]
    fn virtual_clock_jumps_idle_gaps() {
        // A two-request trace with a huge arrival gap must not cost a huge
        // number of iterations: the clock jumps the idle stretch.
        let eng = engine();
        let mut trace = spec(TraceKind::ZipfMix, 2).generate().unwrap();
        trace[1].arrival_step = 1_000_000;
        let opts = ReplayOptions::new(PrecisionPolicy::reference());
        let report = replay(&eng, &trace, &opts).unwrap();
        assert_eq!(report.responses.len(), 2);
        assert!(
            report.steps < 10_000,
            "idle gap was stepped through ({} iterations)",
            report.steps
        );
    }

    #[test]
    fn budget_trips_on_impossible_traces() {
        let eng = engine();
        let trace = spec(TraceKind::ZipfMix, 3).generate().unwrap();
        let mut opts = ReplayOptions::new(PrecisionPolicy::reference());
        opts.max_steps = Some(1);
        assert!(replay(&eng, &trace, &opts).is_err());
    }

    #[test]
    fn decode_mix_round_trips() {
        let eng = engine();
        let mut s = spec(TraceKind::ZipfMix, 4);
        s.topk = 3;
        let trace = s.generate().unwrap();
        assert!(trace.iter().any(|r| matches!(r.decode, Decode::TopK { .. })));
        let opts = ReplayOptions::new(PrecisionPolicy::reference());
        let report = replay(&eng, &trace, &opts).unwrap();
        assert_eq!(report.responses.len(), 4);
    }
}
