//! Precision policies: the coordinator-level vocabulary for whole-model
//! LAMP.
//!
//! A policy is one [`SitePolicy`] (μ, τ, rule) per composition site —
//! attention scores, MLP fc→GELU, final norm, sampler softmax — mirroring
//! the engine-level [`PrecisionPlan`]. The attention-only constructors
//! ([`PrecisionPolicy::reference`]/[`uniform`](PrecisionPolicy::uniform)/
//! [`lamp`](PrecisionPolicy::lamp)) leave every other site at reference,
//! preserving the pre-plan behavior of existing callers; per-site builders
//! ([`with_mlp`](PrecisionPolicy::with_mlp) …) activate the rest.
//!
//! The rule ↔ integer mode codes are shared with the L1 kernel
//! (`python/compile/kernels/lamp_attention.py`) and baked into the
//! artifacts; keep the two tables in sync.

use crate::error::{Error, Result};
use crate::lamp::softmax::SoftmaxRule;
use crate::model::{
    AttentionPrecision, KvPrecision, PrecisionPlan, SitePrecision, SpecConfig, WeightPrecision,
};

/// Default tile width for the tile-granular rules when the name carries
/// no explicit width (`"tile"` / `"tile_random"`).
pub const DEFAULT_TILE_WIDTH: usize = 16;

/// Selection rule, coordinator-facing (mirrors kernel mode codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    Strict,
    Relaxed,
    RelaxedLengthNorm,
    Random,
    /// Tile-granular strict rule (PR 8): per-tile summed sensitivity vs an
    /// absolute τ, attention site only. Native engines only — not baked
    /// into any compiled artifact.
    Tile { width: usize },
    /// Count-matched random baseline for [`Rule::Tile`].
    TileRandom { width: usize },
}

impl Rule {
    /// The artifact mode code (MODE_* in lamp_attention.py). Tile rules
    /// carry a code for labeling symmetry, but no compiled artifact
    /// implements them — `PjrtEngine::validate_policy` rejects both.
    pub fn mode_code(self) -> i32 {
        match self {
            Rule::Strict => 0,
            Rule::Relaxed => 1,
            Rule::RelaxedLengthNorm => 2,
            Rule::Random => 3,
            Rule::Tile { .. } => 4,
            Rule::TileRandom { .. } => 5,
        }
    }

    pub fn by_name(name: &str) -> Result<Self> {
        // Tile rules take an optional width suffix: "tile8", "tile_random4".
        let parse_width = |suffix: &str| -> Result<usize> {
            if suffix.is_empty() {
                return Ok(DEFAULT_TILE_WIDTH);
            }
            match suffix.parse::<usize>() {
                Ok(w) if w >= 1 => Ok(w),
                _ => Err(Error::config(format!(
                    "bad tile width {suffix:?} in rule {name:?} (want an integer >= 1)"
                ))),
            }
        };
        if let Some(rest) = name.strip_prefix("tile_random") {
            return Ok(Rule::TileRandom { width: parse_width(rest)? });
        }
        if let Some(rest) = name.strip_prefix("tile") {
            return Ok(Rule::Tile { width: parse_width(rest)? });
        }
        match name {
            "strict" => Ok(Rule::Strict),
            "relaxed" => Ok(Rule::Relaxed),
            "relaxed_ln" => Ok(Rule::RelaxedLengthNorm),
            "random" => Ok(Rule::Random),
            other => Err(Error::config(format!(
                "unknown rule {other:?} (strict|relaxed|relaxed_ln|random|tile<w>|tile_random<w>)"
            ))),
        }
    }

    pub fn name(self) -> String {
        match self {
            Rule::Strict => "strict".to_string(),
            Rule::Relaxed => "relaxed".to_string(),
            Rule::RelaxedLengthNorm => "relaxed_ln".to_string(),
            Rule::Random => "random".to_string(),
            Rule::Tile { width } => format!("tile{width}"),
            Rule::TileRandom { width } => format!("tile_random{width}"),
        }
    }

    /// Convert to the native engine's [`SoftmaxRule`] (`ref_len` is the
    /// model's training context, used by the length-normalized rule).
    pub fn to_softmax_rule(self, ref_len: usize) -> SoftmaxRule {
        match self {
            Rule::Strict => SoftmaxRule::Strict,
            Rule::Relaxed => SoftmaxRule::Relaxed,
            Rule::RelaxedLengthNorm => SoftmaxRule::RelaxedLengthNorm { ref_len },
            Rule::Random => SoftmaxRule::Random,
            Rule::Tile { width } => SoftmaxRule::Tile { width },
            Rule::TileRandom { width } => SoftmaxRule::TileRandom { width },
        }
    }
}

/// One composition site's (μ, τ, rule) in coordinator vocabulary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SitePolicy {
    pub mu: u32,
    pub tau: f32,
    pub rule: Rule,
}

impl SitePolicy {
    /// Full-precision reference (μ=23, no recomputation).
    pub fn reference() -> Self {
        SitePolicy { mu: 23, tau: f32::INFINITY, rule: Rule::Strict }
    }

    /// Uniform PS(μ), no recomputation.
    pub fn uniform(mu: u32) -> Self {
        SitePolicy { mu, tau: f32::INFINITY, rule: Rule::Strict }
    }

    /// LAMP at (μ, τ) with a rule.
    pub fn lamp(mu: u32, tau: f32, rule: Rule) -> Self {
        SitePolicy { mu, tau, rule }
    }

    /// True when this site runs the exact FP32 reference computation.
    /// Delegates to the engine-level predicate so the coordinator's
    /// attention-only gate and the kernel reference short-circuit can
    /// never disagree (the `ref_len` is irrelevant to the predicate).
    pub fn is_reference(&self) -> bool {
        self.to_site_precision(1).is_reference()
    }

    /// Convert to the native engine's per-site precision.
    pub fn to_site_precision(&self, ref_len: usize) -> SitePrecision {
        SitePrecision {
            mu: self.mu,
            tau: self.tau,
            rule: self.rule.to_softmax_rule(ref_len),
        }
    }

    /// Human-readable fragment used inside [`PrecisionPolicy::label`].
    fn fragment(&self) -> String {
        if self.is_reference() {
            "reference".to_string()
        } else if !self.tau.is_finite() {
            format!("uniform(mu={})", self.mu)
        } else {
            format!("lamp(mu={},tau={},{})", self.mu, self.tau, self.rule.name())
        }
    }
}

/// Coordinator-level speculative-decoding request: the *draft* plan's
/// per-site precision plus the look-ahead depth `k`. Mirrors the
/// engine-level [`SpecConfig`]; validated through
/// [`PrecisionPlan::validate`], which requires every draft site to be no
/// more expensive than the target site and at least one to be strictly
/// cheaper.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecPolicy {
    pub attention: SitePolicy,
    pub mlp: SitePolicy,
    pub norm: SitePolicy,
    pub sampler: SitePolicy,
    /// Look-ahead depth: tokens drafted per speculation round.
    pub k: usize,
}

impl SpecPolicy {
    /// The same draft (μ, τ, rule) at every composition site.
    pub fn whole_model(site: SitePolicy, k: usize) -> Self {
        SpecPolicy { attention: site, mlp: site, norm: site, sampler: site, k }
    }

    /// Convert to the engine-level draft configuration.
    pub fn to_config(&self, ref_len: usize) -> SpecConfig {
        SpecConfig {
            attention: self.attention.to_site_precision(ref_len),
            mlp: self.mlp.to_site_precision(ref_len),
            norm: self.norm.to_site_precision(ref_len),
            sampler: self.sampler.to_site_precision(ref_len),
            k: self.k,
        }
    }

    /// Label fragment (metric-key stable: equal specs render equally,
    /// distinct specs distinctly).
    fn fragment(&self) -> String {
        let sites = if self.attention == self.mlp
            && self.mlp == self.norm
            && self.norm == self.sampler
        {
            self.attention.fragment()
        } else {
            format!(
                "att={},mlp={},norm={},sampler={}",
                self.attention.fragment(),
                self.mlp.fragment(),
                self.norm.fragment(),
                self.sampler.fragment()
            )
        };
        format!("spec[k={},{}]", self.k, sites)
    }
}

/// A complete per-site precision policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrecisionPolicy {
    /// Attention-score site (softmax ∘ KQ matmul).
    pub attention: SitePolicy,
    /// MLP site (GELU ∘ fc matmul; proj matmul uniform PS).
    pub mlp: SitePolicy,
    /// Final-norm site (layernorm ∘ residual storage).
    pub norm: SitePolicy,
    /// Sampler site (softmax ∘ logits matmul).
    pub sampler: SitePolicy,
    /// Weight-storage requirement ([`WeightPrecision::Any`] by default:
    /// serve on whatever storage the engine holds). Backends check it at
    /// submit via `Engine::validate_policy` — the compiled PJRT artifact
    /// executes f32 weight buffers only.
    pub weights: WeightPrecision,
    /// KV-cache storage requirement ([`KvPrecision::Any`] by default:
    /// decode on whatever KV format the engine's block pool holds).
    /// Checked at submit via `Engine::validate_policy`, like weights.
    pub kv: KvPrecision,
    /// Speculative decoding (`None` = plain one-token-per-step decode):
    /// draft `k` tokens under the cheap plan, verify them with this
    /// policy's exact plan in one batched forward. Native engines only —
    /// `PjrtEngine::validate_policy` rejects it.
    pub spec: Option<SpecPolicy>,
}

impl PrecisionPolicy {
    /// Full-precision reference at every site.
    pub fn reference() -> Self {
        PrecisionPolicy {
            attention: SitePolicy::reference(),
            mlp: SitePolicy::reference(),
            norm: SitePolicy::reference(),
            sampler: SitePolicy::reference(),
            weights: WeightPrecision::Any,
            kv: KvPrecision::Any,
            spec: None,
        }
    }

    /// Uniform PS(μ) attention, no recomputation; other sites reference.
    pub fn uniform(mu: u32) -> Self {
        PrecisionPolicy { attention: SitePolicy::uniform(mu), ..Self::reference() }
    }

    /// Attention-site LAMP at (μ, τ); other sites reference.
    pub fn lamp(mu: u32, tau: f32, rule: Rule) -> Self {
        PrecisionPolicy { attention: SitePolicy::lamp(mu, tau, rule), ..Self::reference() }
    }

    /// The same (μ, τ, rule) at every composition site.
    pub fn whole_model(mu: u32, tau: f32, rule: Rule) -> Self {
        let site = SitePolicy::lamp(mu, tau, rule);
        PrecisionPolicy {
            attention: site,
            mlp: site,
            norm: site,
            sampler: site,
            weights: WeightPrecision::Any,
            kv: KvPrecision::Any,
            spec: None,
        }
    }

    /// Replace the MLP site.
    pub fn with_mlp(mut self, site: SitePolicy) -> Self {
        self.mlp = site;
        self
    }

    /// Replace the final-norm site.
    pub fn with_norm(mut self, site: SitePolicy) -> Self {
        self.norm = site;
        self
    }

    /// Replace the sampler site.
    pub fn with_sampler(mut self, site: SitePolicy) -> Self {
        self.sampler = site;
        self
    }

    /// Replace the weight-storage requirement.
    pub fn with_weights(mut self, weights: WeightPrecision) -> Self {
        self.weights = weights;
        self
    }

    /// Replace the KV-cache storage requirement.
    pub fn with_kv(mut self, kv: KvPrecision) -> Self {
        self.kv = kv;
        self
    }

    /// Attach (or clear) a speculative-decoding draft configuration.
    pub fn with_spec(mut self, spec: Option<SpecPolicy>) -> Self {
        self.spec = spec;
        self
    }

    /// True when every non-attention site is at reference (the policy is
    /// expressible on backends that only implement attention LAMP, e.g.
    /// the compiled PJRT artifact).
    pub fn is_attention_only(&self) -> bool {
        self.mlp.is_reference() && self.norm.is_reference() && self.sampler.is_reference()
    }

    /// Named accuracy tiers for the serving API — the coordinator-level
    /// knob a deployment would actually expose. Derived from the paper's
    /// headline points (§4.3: 0.3%/1.6%/7.6% recomputation bands); the
    /// `*-whole` tiers extend the band to every composition site.
    pub fn tier(name: &str) -> Result<Self> {
        match name {
            // Exact reference, full cost.
            "exact" => Ok(Self::reference()),
            // ~TF32-quality at BF16-accumulate cost.
            "high" => Ok(Self::lamp(7, 0.03, Rule::Relaxed)),
            // Balanced default.
            "balanced" => Ok(Self::lamp(4, 0.1, Rule::Relaxed)),
            // Cheapest: uniform low precision.
            "economy" => Ok(Self::uniform(4)),
            // Balanced attention + low-precision MLP/norm/logits with
            // per-site LAMP repair — the whole-model serving point.
            "balanced-whole" => Ok(Self::lamp(4, 0.1, Rule::Relaxed)
                .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))
                .with_norm(SitePolicy::lamp(10, 1.0, Rule::Strict))
                .with_sampler(SitePolicy::lamp(7, 0.05, Rule::Relaxed))),
            other => Err(Error::config(format!(
                "unknown tier {other:?} (exact|high|balanced|economy|balanced-whole)"
            ))),
        }
    }

    /// Human-readable label, used as the key of per-policy serving metrics
    /// (e.g. the recompute-rate breakdown in `ServerStats`). Policies that
    /// compare equal render identically; non-reference extra sites append
    /// their own fragments, so distinct plans get distinct labels.
    pub fn label(&self) -> String {
        let mut s = self.attention.fragment();
        for (name, site) in
            [("mlp", &self.mlp), ("norm", &self.norm), ("sampler", &self.sampler)]
        {
            if !site.is_reference() {
                s.push_str(&format!("+{name}[{}]", site.fragment()));
            }
        }
        if self.weights != WeightPrecision::Any {
            s.push_str(&format!("+weights[{}]", self.weights.label()));
        }
        if self.kv != KvPrecision::Any {
            s.push_str(&format!("+kv[{}]", self.kv.label()));
        }
        if let Some(spec) = &self.spec {
            s.push_str(&format!("+{}", spec.fragment()));
        }
        s
    }

    /// Two requests can share an artifact batch iff their policies match
    /// exactly at every site (μ, τ, rule are baked into the batched call's
    /// scalars).
    pub fn batch_compatible(&self, other: &PrecisionPolicy) -> bool {
        self == other
    }

    /// The attention site in native-engine vocabulary (kept for the
    /// artifact path, which executes attention LAMP only).
    pub fn to_attention_precision(&self, ref_len: usize) -> AttentionPrecision {
        self.attention.to_site_precision(ref_len)
    }

    /// The full per-site plan in native-engine vocabulary — the single
    /// policy → plan translation the engines and the scheduler share.
    pub fn to_plan(&self, ref_len: usize) -> PrecisionPlan {
        PrecisionPlan {
            attention: self.attention.to_site_precision(ref_len),
            mlp: self.mlp.to_site_precision(ref_len),
            norm: self.norm.to_site_precision(ref_len),
            sampler: self.sampler.to_site_precision(ref_len),
            weights: self.weights,
            kv: self.kv,
            spec: self.spec.map(|s| s.to_config(ref_len)),
        }
    }

    /// Validate every site's ranges with typed, site-naming errors — the
    /// front-door rejection that keeps invalid plans from panicking deep
    /// in the engines. Delegates to [`PrecisionPlan::validate`], the
    /// single source of truth for the per-site ranges (the `ref_len`
    /// passed to the translation does not affect validation).
    pub fn validate(&self) -> Result<()> {
        self.to_plan(1).validate()
    }
}

/// One step of a [`DegradationLadder`]: a validated transformation of a
/// request's [`PrecisionPolicy`] toward cheaper compute.
///
/// Degradation moves along LAMP's own accuracy axis — raising τ repairs
/// fewer products, `uniform` drops repair entirely — instead of dropping
/// requests. Reference sites are never touched (an `exact`-tier request
/// stays exact on every rung), and storage requirements are preserved, so
/// a degraded policy always re-validates.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradeRung {
    /// Metric label for this rung (e.g. `"relax-4x"`).
    pub name: String,
    /// Multiply every active finite-τ site's threshold (τ↑ ⇒ fewer
    /// repairs ⇒ cheaper). Clamped below 1 for relaxed-family rules,
    /// whose thresholds are fractions.
    pub tau_scale: f32,
    /// Drop repair entirely: every active site becomes uniform PS(μ).
    pub uniform: bool,
}

impl DegradeRung {
    pub fn tau(name: impl Into<String>, tau_scale: f32) -> Self {
        DegradeRung { name: name.into(), tau_scale, uniform: false }
    }

    pub fn uniform(name: impl Into<String>) -> Self {
        DegradeRung { name: name.into(), tau_scale: 1.0, uniform: true }
    }

    fn apply_site(&self, site: SitePolicy) -> SitePolicy {
        if site.is_reference() {
            return site;
        }
        if self.uniform {
            return SitePolicy::uniform(site.mu);
        }
        if !site.tau.is_finite() {
            return site; // already uniform
        }
        let mut tau = site.tau * self.tau_scale;
        if matches!(site.rule, Rule::Relaxed | Rule::RelaxedLengthNorm) {
            tau = tau.min(0.99); // relaxed thresholds are fractions < 1
        }
        SitePolicy { tau, ..site }
    }

    /// Apply this rung to every site; storage requirements pass through.
    pub fn apply(&self, policy: &PrecisionPolicy) -> PrecisionPolicy {
        PrecisionPolicy {
            attention: self.apply_site(policy.attention),
            mlp: self.apply_site(policy.mlp),
            norm: self.apply_site(policy.norm),
            sampler: self.apply_site(policy.sampler),
            weights: policy.weights,
            kv: policy.kv,
            // Degrading means overload: speculation spends extra compute
            // on look-ahead drafts, so it is the first thing shed. (It
            // also sidesteps validity: raising the target's τ could make
            // a fixed draft no longer strictly cheaper.)
            spec: None,
        }
    }
}

/// A validated ladder of precision-degradation rungs plus the hysteresis
/// thresholds the scheduler's overload controller steps it with.
///
/// Rung 0 is "no degradation"; rung `r ≥ 1` applies `rungs[r - 1]`.
/// Rungs are absolute (each is applied to the request's *original*
/// policy, not to the previous rung's output), so the effective policy at
/// any rung is independent of the path taken to reach it.
#[derive(Debug, Clone)]
pub struct DegradationLadder {
    pub rungs: Vec<DegradeRung>,
    /// KV-pool occupancy at/above which a step counts as pressured.
    pub occupancy_high: f64,
    /// Occupancy at/below which a step counts as clear.
    pub occupancy_low: f64,
    /// Consecutive pressured steps before stepping one rung down.
    pub degrade_after: usize,
    /// Consecutive clear steps before stepping one rung back up
    /// (restore-slow: several times `degrade_after` avoids flapping).
    pub restore_after: usize,
}

impl Default for DegradationLadder {
    fn default() -> Self {
        DegradationLadder {
            rungs: vec![
                DegradeRung::tau("relax-4x", 4.0),
                DegradeRung::tau("relax-16x", 16.0),
                DegradeRung::uniform("uniform"),
            ],
            occupancy_high: 0.85,
            occupancy_low: 0.5,
            degrade_after: 2,
            restore_after: 8,
        }
    }
}

impl DegradationLadder {
    pub fn validate(&self) -> Result<()> {
        if self.rungs.is_empty() {
            return Err(Error::config("degradation ladder has no rungs"));
        }
        for r in &self.rungs {
            if !(r.tau_scale >= 1.0 && r.tau_scale.is_finite()) {
                return Err(Error::config(format!(
                    "ladder rung {:?}: tau_scale {} must be finite and >= 1",
                    r.name, r.tau_scale
                )));
            }
        }
        if !(0.0..=1.0).contains(&self.occupancy_low)
            || !(0.0..=1.0).contains(&self.occupancy_high)
            || self.occupancy_low > self.occupancy_high
        {
            return Err(Error::config(format!(
                "ladder occupancy thresholds low {} / high {} out of order",
                self.occupancy_low, self.occupancy_high
            )));
        }
        if self.degrade_after == 0 || self.restore_after == 0 {
            return Err(Error::config("ladder patience counters must be >= 1"));
        }
        Ok(())
    }

    /// Deepest rung index.
    pub fn max_rung(&self) -> usize {
        self.rungs.len()
    }

    /// Metric label for a rung index (`"none"` for rung 0).
    pub fn rung_name(&self, rung: usize) -> &str {
        if rung == 0 {
            "none"
        } else {
            &self.rungs[rung.min(self.rungs.len()) - 1].name
        }
    }

    /// The effective policy at `rung` for a request asking for `policy`.
    pub fn apply(&self, rung: usize, policy: &PrecisionPolicy) -> PrecisionPolicy {
        if rung == 0 {
            *policy
        } else {
            self.rungs[rung.min(self.rungs.len()) - 1].apply(policy)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_codes_stable() {
        // These are baked into the artifacts — changing them breaks every
        // compiled HLO. Pin them.
        assert_eq!(Rule::Strict.mode_code(), 0);
        assert_eq!(Rule::Relaxed.mode_code(), 1);
        assert_eq!(Rule::RelaxedLengthNorm.mode_code(), 2);
        assert_eq!(Rule::Random.mode_code(), 3);
        assert_eq!(Rule::Tile { width: 16 }.mode_code(), 4);
        assert_eq!(Rule::TileRandom { width: 16 }.mode_code(), 5);
    }

    #[test]
    fn rule_names_roundtrip() {
        for r in [
            Rule::Strict,
            Rule::Relaxed,
            Rule::RelaxedLengthNorm,
            Rule::Random,
            Rule::Tile { width: 8 },
            Rule::TileRandom { width: 32 },
        ] {
            assert_eq!(Rule::by_name(&r.name()).unwrap(), r);
        }
        assert!(Rule::by_name("bogus").is_err());
        // Bare tile names pick the default width.
        assert_eq!(
            Rule::by_name("tile").unwrap(),
            Rule::Tile { width: DEFAULT_TILE_WIDTH }
        );
        assert_eq!(
            Rule::by_name("tile_random").unwrap(),
            Rule::TileRandom { width: DEFAULT_TILE_WIDTH }
        );
        assert!(Rule::by_name("tile0").is_err());
        assert!(Rule::by_name("tilex").is_err());
    }

    #[test]
    fn tile_policies_validate_attention_only() {
        // Tile rules use absolute thresholds (tau >= 1 is legal) but are
        // attention-site-only and require width >= 1.
        let tile = Rule::Tile { width: 4 };
        assert!(PrecisionPolicy::lamp(4, 1.5, tile).validate().is_ok());
        let e = PrecisionPolicy::reference()
            .with_mlp(SitePolicy::lamp(4, 0.1, tile))
            .validate()
            .unwrap_err()
            .to_string();
        assert!(e.contains("attention site only"), "{e}");
        assert!(PrecisionPolicy::lamp(4, 0.1, Rule::Tile { width: 0 })
            .validate()
            .is_err());
    }

    #[test]
    fn tiers_resolve_and_validate() {
        for t in ["exact", "high", "balanced", "economy", "balanced-whole"] {
            PrecisionPolicy::tier(t).unwrap().validate().unwrap();
        }
        assert!(PrecisionPolicy::tier("ultra").is_err());
        assert!(PrecisionPolicy::tier("balanced").unwrap().is_attention_only());
        assert!(!PrecisionPolicy::tier("balanced-whole").unwrap().is_attention_only());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        assert!(PrecisionPolicy::lamp(0, 0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(24, 0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(4, -0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(4, 1.5, Rule::Relaxed).validate().is_err());
        // Strict thresholds are absolute: tau > 1 is fine there.
        assert!(PrecisionPolicy::lamp(4, 1.5, Rule::Strict).validate().is_ok());
    }

    #[test]
    fn validation_names_the_offending_site() {
        let bad_mlp = PrecisionPolicy::reference().with_mlp(SitePolicy::lamp(0, 0.1, Rule::Strict));
        let e = bad_mlp.validate().unwrap_err().to_string();
        assert!(e.contains("mlp"), "{e}");
        let nan_norm = PrecisionPolicy::reference()
            .with_norm(SitePolicy::lamp(4, f32::NAN, Rule::Strict));
        let e = nan_norm.validate().unwrap_err().to_string();
        assert!(e.contains("norm") && e.contains("NaN"), "{e}");
        let bad_sampler = PrecisionPolicy::reference()
            .with_sampler(SitePolicy::lamp(4, 1.5, Rule::Relaxed));
        let e = bad_sampler.validate().unwrap_err().to_string();
        assert!(e.contains("sampler"), "{e}");
        // Absolute thresholds: tau >= 1 is fine for MLP/norm sites.
        assert!(PrecisionPolicy::reference()
            .with_mlp(SitePolicy::lamp(4, 1.5, Rule::Relaxed))
            .validate()
            .is_ok());
    }

    #[test]
    fn length_norm_rule_is_attention_only() {
        // App. C.5 normalizes over causal row lengths; other sites see
        // fixed-width rows, so the rule is rejected there.
        assert!(PrecisionPolicy::lamp(4, 0.1, Rule::RelaxedLengthNorm)
            .validate()
            .is_ok());
        for policy in [
            PrecisionPolicy::reference()
                .with_mlp(SitePolicy::lamp(4, 0.1, Rule::RelaxedLengthNorm)),
            PrecisionPolicy::reference()
                .with_norm(SitePolicy::lamp(4, 0.1, Rule::RelaxedLengthNorm)),
            PrecisionPolicy::reference()
                .with_sampler(SitePolicy::lamp(4, 0.1, Rule::RelaxedLengthNorm)),
        ] {
            let e = policy.validate().unwrap_err().to_string();
            assert!(e.contains("attention site only"), "{e}");
        }
    }

    #[test]
    fn labels_distinguish_policy_classes() {
        assert_eq!(PrecisionPolicy::reference().label(), "reference");
        assert_eq!(PrecisionPolicy::uniform(4).label(), "uniform(mu=4)");
        let l = PrecisionPolicy::lamp(3, 0.05, Rule::Relaxed).label();
        assert!(l.contains("mu=3") && l.contains("relaxed"), "{l}");
        // Equal policies render identically (metric-key stability).
        assert_eq!(
            PrecisionPolicy::lamp(4, 0.1, Rule::Strict).label(),
            PrecisionPolicy::lamp(4, 0.1, Rule::Strict).label()
        );
    }

    #[test]
    fn labels_roundtrip_per_site_plans() {
        // Attention-only labels stay in the historical format; per-site
        // additions produce distinct labels per distinct plan and equal
        // labels for equal plans (the batch-compatibility key contract).
        let base = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        let a = base.with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict));
        let b = base.with_mlp(SitePolicy::lamp(7, 0.25, Rule::Strict));
        let c = base.with_norm(SitePolicy::uniform(7));
        assert_eq!(base.label(), "lamp(mu=4,tau=0.1,strict)");
        assert_ne!(a.label(), base.label());
        assert_ne!(a.label(), b.label());
        assert_ne!(a.label(), c.label());
        assert!(a.label().contains("mlp["), "{}", a.label());
        assert!(c.label().contains("norm[uniform(mu=7)"), "{}", c.label());
        assert_eq!(
            a.label(),
            base.with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict)).label()
        );
        // Label equality tracks batch compatibility on these plans.
        assert!(a.batch_compatible(&base.with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))));
        assert!(!a.batch_compatible(&b));
        assert!(!a.batch_compatible(&c));
    }

    #[test]
    fn weights_requirement_in_label_validation_and_batching() {
        use crate::linalg::WeightFormat;
        let base = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        assert_eq!(base.weights, WeightPrecision::Any);
        let bf = base.with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        bf.validate().unwrap();
        assert!(bf.label().contains("weights[bf16]"), "{}", bf.label());
        assert!(!base.label().contains("weights"), "{}", base.label());
        // Storage requirements key batches like any other policy field.
        assert!(!base.batch_compatible(&bf));
        assert!(bf.batch_compatible(&base.with_weights(WeightPrecision::Exact(
            WeightFormat::Bf16
        ))));
        // Invalid storage μ is rejected at the policy front door.
        let bad = base.with_weights(WeightPrecision::Exact(WeightFormat::PsRounded {
            mu: 42,
        }));
        assert!(bad.validate().is_err());
        // The translation threads the requirement into the plan.
        assert_eq!(bf.to_plan(64).weights, bf.weights);
    }

    #[test]
    fn kv_requirement_in_label_validation_and_batching() {
        use crate::linalg::WeightFormat;
        let base = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        assert_eq!(base.kv, KvPrecision::Any);
        let bf = base.with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        bf.validate().unwrap();
        assert!(bf.label().contains("kv[bf16]"), "{}", bf.label());
        assert!(!base.label().contains("kv["), "{}", base.label());
        // KV requirements key batches like any other policy field.
        assert!(!base.batch_compatible(&bf));
        assert!(bf.batch_compatible(&base.with_kv(KvPrecision::Exact(WeightFormat::Bf16))));
        // Invalid storage μ is rejected at the policy front door.
        let bad = base.with_kv(KvPrecision::Exact(WeightFormat::PsRounded { mu: 42 }));
        assert!(bad.validate().is_err());
        // The translation threads the requirement into the plan.
        assert_eq!(bf.to_plan(64).kv, bf.kv);
        // kv and weights fragments render independently.
        let both = bf.with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        assert!(
            both.label().contains("weights[bf16]") && both.label().contains("kv[bf16]"),
            "{}",
            both.label()
        );
    }

    #[test]
    fn spec_policy_in_label_validation_and_batching() {
        let base = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
        let spec = base.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 4)));
        // A strictly-cheaper draft validates through the plan front door.
        spec.validate().unwrap();
        assert!(spec.label().contains("spec[k=4"), "{}", spec.label());
        assert!(!base.label().contains("spec["), "{}", base.label());
        // Spec keys batches: drafts differing only in k don't co-batch.
        assert!(!spec.batch_compatible(&base));
        assert!(!spec.batch_compatible(
            &base.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 2)))
        ));
        assert!(spec.batch_compatible(
            &base.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 4)))
        ));
        // The translation threads the draft into the plan.
        let plan = spec.to_plan(64);
        let cfg = plan.spec.expect("spec threads into the plan");
        assert_eq!(cfg.k, 4);
        assert_eq!(cfg.attention.mu, 2);
        // A draft more expensive than the target is rejected.
        let bad = base.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(6), 4)));
        let e = bad.validate().unwrap_err().to_string();
        assert!(e.contains("spec draft"), "{e}");
        // k = 0 is rejected.
        let zero = base.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 0)));
        assert!(zero.validate().is_err());
    }

    #[test]
    fn degradation_sheds_speculation_before_precision() {
        let ladder = DegradationLadder::default();
        let policy = PrecisionPolicy::tier("balanced")
            .unwrap()
            .with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 4)));
        policy.validate().unwrap();
        // Rung 0 is the request's own policy — speculation intact.
        assert_eq!(ladder.apply(0, &policy).spec, policy.spec);
        // Every degraded rung drops speculation and still validates.
        for rung in 1..=ladder.max_rung() {
            let eff = ladder.apply(rung, &policy);
            assert_eq!(eff.spec, None, "rung {rung} kept spec");
            eff.validate().unwrap();
        }
    }

    #[test]
    fn degradation_ladder_produces_valid_policies_on_every_rung() {
        let ladder = DegradationLadder::default();
        ladder.validate().unwrap();
        for tier in ["exact", "high", "balanced", "economy", "balanced-whole"] {
            let policy = PrecisionPolicy::tier(tier).unwrap();
            for rung in 0..=ladder.max_rung() {
                let eff = ladder.apply(rung, &policy);
                eff.validate().unwrap_or_else(|e| {
                    panic!("tier {tier} rung {rung} invalid: {e}")
                });
            }
        }
    }

    #[test]
    fn degradation_moves_along_the_tau_axis() {
        let ladder = DegradationLadder::default();
        let policy = PrecisionPolicy::tier("balanced").unwrap(); // relaxed tau=0.1
        let r1 = ladder.apply(1, &policy);
        assert!((r1.attention.tau - 0.4).abs() < 1e-6, "{}", r1.attention.tau);
        // Relaxed thresholds clamp below 1 even at the 16x rung.
        let r2 = ladder.apply(2, &policy);
        assert!((0.0..1.0).contains(&r2.attention.tau), "{}", r2.attention.tau);
        // Deepest rung drops repair entirely but keeps mu.
        let r3 = ladder.apply(ladder.max_rung(), &policy);
        assert!(!r3.attention.tau.is_finite());
        assert_eq!(r3.attention.mu, policy.attention.mu);
        // Rungs are absolute: each applies to the original policy.
        assert_eq!(ladder.apply(1, &policy), ladder.apply(1, &policy));
        // Reference sites and exact tiers are never touched.
        let exact = PrecisionPolicy::reference();
        for rung in 0..=ladder.max_rung() {
            assert_eq!(ladder.apply(rung, &exact), exact);
        }
        // Storage requirements pass through.
        use crate::linalg::WeightFormat;
        let pinned = policy.with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        assert_eq!(ladder.apply(2, &pinned).kv, pinned.kv);
        // Rung names are metric-stable.
        assert_eq!(ladder.rung_name(0), "none");
        assert_eq!(ladder.rung_name(1), "relax-4x");
        assert_eq!(ladder.rung_name(ladder.max_rung()), "uniform");
    }

    #[test]
    fn degradation_ladder_validation() {
        let mut bad = DegradationLadder { rungs: vec![], ..Default::default() };
        assert!(bad.validate().is_err());
        bad = DegradationLadder::default();
        bad.rungs[0].tau_scale = 0.5;
        assert!(bad.validate().is_err());
        bad = DegradationLadder::default();
        bad.occupancy_low = 0.9;
        bad.occupancy_high = 0.5;
        assert!(bad.validate().is_err());
        bad = DegradationLadder::default();
        bad.degrade_after = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn batch_compatibility_is_exact_match() {
        let a = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
        let b = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
        let c = PrecisionPolicy::lamp(4, 0.2, Rule::Relaxed);
        assert!(a.batch_compatible(&b));
        assert!(!a.batch_compatible(&c));
        // Differing only in a non-attention site ⇒ not batch compatible.
        let d = a.with_sampler(SitePolicy::uniform(7));
        assert!(!a.batch_compatible(&d));
    }

    #[test]
    fn to_plan_round_trips_every_site() {
        let p = PrecisionPolicy::whole_model(4, 0.1, Rule::Strict)
            .with_sampler(SitePolicy::lamp(7, 0.05, Rule::Relaxed));
        let plan = p.to_plan(128);
        assert_eq!(plan.attention.mu, 4);
        assert_eq!(plan.mlp.mu, 4);
        assert_eq!(plan.norm.mu, 4);
        assert_eq!(plan.sampler.mu, 7);
        assert_eq!(plan.sampler.rule, SoftmaxRule::Relaxed);
        assert!(!plan.is_attention_only());
        let reference = PrecisionPolicy::reference().to_plan(128);
        assert!(reference.is_attention_only());
        assert!(reference.attention.is_reference());
    }
}
