//! Precision policies: the coordinator-level vocabulary for LAMP.
//!
//! A policy is a (μ, τ, rule) triple. The rule ↔ integer mode codes are
//! shared with the L1 kernel (`python/compile/kernels/lamp_attention.py`)
//! and baked into the artifacts; keep the two tables in sync.

use crate::error::{Error, Result};
use crate::lamp::softmax::SoftmaxRule;
use crate::model::AttentionPrecision;

/// Selection rule, coordinator-facing (mirrors kernel mode codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    Strict,
    Relaxed,
    RelaxedLengthNorm,
    Random,
}

impl Rule {
    /// The artifact mode code (MODE_* in lamp_attention.py).
    pub fn mode_code(self) -> i32 {
        match self {
            Rule::Strict => 0,
            Rule::Relaxed => 1,
            Rule::RelaxedLengthNorm => 2,
            Rule::Random => 3,
        }
    }

    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "strict" => Ok(Rule::Strict),
            "relaxed" => Ok(Rule::Relaxed),
            "relaxed_ln" => Ok(Rule::RelaxedLengthNorm),
            "random" => Ok(Rule::Random),
            other => Err(Error::config(format!(
                "unknown rule {other:?} (strict|relaxed|relaxed_ln|random)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rule::Strict => "strict",
            Rule::Relaxed => "relaxed",
            Rule::RelaxedLengthNorm => "relaxed_ln",
            Rule::Random => "random",
        }
    }

    /// Convert to the native engine's [`SoftmaxRule`] (`ref_len` is the
    /// model's training context, used by the length-normalized rule).
    pub fn to_softmax_rule(self, ref_len: usize) -> SoftmaxRule {
        match self {
            Rule::Strict => SoftmaxRule::Strict,
            Rule::Relaxed => SoftmaxRule::Relaxed,
            Rule::RelaxedLengthNorm => SoftmaxRule::RelaxedLengthNorm { ref_len },
            Rule::Random => SoftmaxRule::Random,
        }
    }
}

/// A complete precision policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrecisionPolicy {
    pub mu: u32,
    pub tau: f32,
    pub rule: Rule,
}

impl PrecisionPolicy {
    /// Full-precision reference (μ=23).
    pub fn reference() -> Self {
        PrecisionPolicy { mu: 23, tau: f32::INFINITY, rule: Rule::Strict }
    }

    /// Uniform PS(μ), no recomputation.
    pub fn uniform(mu: u32) -> Self {
        PrecisionPolicy { mu, tau: f32::INFINITY, rule: Rule::Strict }
    }

    /// LAMP at (μ, τ) with a rule.
    pub fn lamp(mu: u32, tau: f32, rule: Rule) -> Self {
        PrecisionPolicy { mu, tau, rule }
    }

    /// Named accuracy tiers for the serving API — the coordinator-level
    /// knob a deployment would actually expose. Derived from the paper's
    /// headline points (§4.3: 0.3%/1.6%/7.6% recomputation bands).
    pub fn tier(name: &str) -> Result<Self> {
        match name {
            // Exact reference, full cost.
            "exact" => Ok(Self::reference()),
            // ~TF32-quality at BF16-accumulate cost.
            "high" => Ok(Self::lamp(7, 0.03, Rule::Relaxed)),
            // Balanced default.
            "balanced" => Ok(Self::lamp(4, 0.1, Rule::Relaxed)),
            // Cheapest: uniform low precision.
            "economy" => Ok(Self::uniform(4)),
            other => Err(Error::config(format!(
                "unknown tier {other:?} (exact|high|balanced|economy)"
            ))),
        }
    }

    /// Human-readable label, used as the key of per-policy serving metrics
    /// (e.g. the recompute-rate breakdown in `ServerStats`). Policies that
    /// compare equal render identically.
    pub fn label(&self) -> String {
        if self.mu == 23 && !self.tau.is_finite() {
            "reference".to_string()
        } else if !self.tau.is_finite() {
            format!("uniform(mu={})", self.mu)
        } else {
            format!("lamp(mu={},tau={},{})", self.mu, self.tau, self.rule.name())
        }
    }

    /// Two requests can share an artifact batch iff their policies match
    /// exactly (μ, τ, rule are baked into the batched call's scalars).
    pub fn batch_compatible(&self, other: &PrecisionPolicy) -> bool {
        self == other
    }

    /// Convert to the native engine's precision type.
    pub fn to_attention_precision(&self, ref_len: usize) -> AttentionPrecision {
        AttentionPrecision {
            mu: self.mu,
            tau: self.tau,
            rule: self.rule.to_softmax_rule(ref_len),
        }
    }

    /// Validate ranges.
    pub fn validate(&self) -> Result<()> {
        if !(1..=23).contains(&self.mu) {
            return Err(Error::config(format!("mu {} out of 1..=23", self.mu)));
        }
        if self.tau < 0.0 || self.tau.is_nan() {
            return Err(Error::config(format!("tau {} must be >= 0", self.tau)));
        }
        if matches!(self.rule, Rule::Relaxed | Rule::RelaxedLengthNorm)
            && self.tau.is_finite()
            && self.tau >= 1.0
        {
            return Err(Error::config(format!(
                "relative threshold tau {} must be < 1 for relaxed rules",
                self.tau
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_codes_stable() {
        // These are baked into the artifacts — changing them breaks every
        // compiled HLO. Pin them.
        assert_eq!(Rule::Strict.mode_code(), 0);
        assert_eq!(Rule::Relaxed.mode_code(), 1);
        assert_eq!(Rule::RelaxedLengthNorm.mode_code(), 2);
        assert_eq!(Rule::Random.mode_code(), 3);
    }

    #[test]
    fn rule_names_roundtrip() {
        for r in [Rule::Strict, Rule::Relaxed, Rule::RelaxedLengthNorm, Rule::Random] {
            assert_eq!(Rule::by_name(r.name()).unwrap(), r);
        }
        assert!(Rule::by_name("bogus").is_err());
    }

    #[test]
    fn tiers_resolve_and_validate() {
        for t in ["exact", "high", "balanced", "economy"] {
            PrecisionPolicy::tier(t).unwrap().validate().unwrap();
        }
        assert!(PrecisionPolicy::tier("ultra").is_err());
    }

    #[test]
    fn validation_catches_bad_ranges() {
        assert!(PrecisionPolicy::lamp(0, 0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(24, 0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(4, -0.1, Rule::Strict).validate().is_err());
        assert!(PrecisionPolicy::lamp(4, 1.5, Rule::Relaxed).validate().is_err());
        // Strict thresholds are absolute: tau > 1 is fine there.
        assert!(PrecisionPolicy::lamp(4, 1.5, Rule::Strict).validate().is_ok());
    }

    #[test]
    fn labels_distinguish_policy_classes() {
        assert_eq!(PrecisionPolicy::reference().label(), "reference");
        assert_eq!(PrecisionPolicy::uniform(4).label(), "uniform(mu=4)");
        let l = PrecisionPolicy::lamp(3, 0.05, Rule::Relaxed).label();
        assert!(l.contains("mu=3") && l.contains("relaxed"), "{l}");
        // Equal policies render identically (metric-key stability).
        assert_eq!(
            PrecisionPolicy::lamp(4, 0.1, Rule::Strict).label(),
            PrecisionPolicy::lamp(4, 0.1, Rule::Strict).label()
        );
    }

    #[test]
    fn batch_compatibility_is_exact_match() {
        let a = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
        let b = PrecisionPolicy::lamp(4, 0.1, Rule::Relaxed);
        let c = PrecisionPolicy::lamp(4, 0.2, Rule::Relaxed);
        assert!(a.batch_compatible(&b));
        assert!(!a.batch_compatible(&c));
    }
}
