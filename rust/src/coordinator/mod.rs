//! The L3 serving coordinator.
//!
//! LAMP is a numeric-format contribution, so the coordinator is shaped as a
//! *precision-aware inference service*: clients submit sequences together
//! with an accuracy target, and the coordinator routes them through the
//! right (μ, τ, rule) point of the compiled artifact.
//!
//! * [`policy`] — precision policies: named accuracy tiers mapped to
//!   per-composition-site (μ, τ, rule) triples (attention, MLP, norm,
//!   sampler — the serving mirror of `model::PrecisionPlan`); the rule ↔
//!   mode-code table shared with the L1 kernel.
//! * [`engine`] — the [`engine::Engine`] trait with the two backends:
//!   [`engine::NativeEngine`] (bit-exact Rust model) and
//!   [`engine::PjrtEngine`] (compiled HLO artifacts).
//! * [`request`] — request/response types and sequence padding.
//! * [`batcher`] — dynamic batcher: groups compatible requests (same
//!   policy) into fixed-shape artifact batches, padding the remainder.
//! * [`scheduler`] — continuous-batching decode scheduler: a pool of live
//!   KV-cache sessions stepped in lockstep, admitting requests mid-flight
//!   and streaming per-token events, bit-identical per request to solo
//!   decoding.
//! * [`server`] — the serving loop: worker threads draining the batcher,
//!   generation traffic routed through the scheduler, latency/throughput
//!   accounting.
//! * [`faults`] — seeded deterministic fault injection: a
//!   [`faults::FaultInjector`] engine decorator that turns any chaos
//!   scenario into a replayable seed.

pub mod batcher;
pub mod engine;
pub mod faults;
pub mod policy;
pub mod replay;
pub mod request;
pub mod scheduler;
pub mod server;

pub use crate::linalg::WeightFormat;
pub use crate::model::{KvBlockPool, KvCacheOptions, KvPoolStats, KvPrecision, WeightPrecision};
pub use batcher::Batcher;
pub use engine::{Engine, EngineOutput, NativeEngine, PjrtEngine};
pub use faults::{FaultInjector, FaultPlan, FaultStats};
pub use policy::{DegradationLadder, DegradeRung, PrecisionPolicy, Rule, SitePolicy, SpecPolicy};
pub use replay::{replay, ReplayOptions, ReplayReport};
pub use request::{
    CancelToken, Deadline, GenerateRequest, GenerateResponse, InferenceRequest,
    InferenceResponse,
};
pub use scheduler::{DecodeMetrics, GenerateEvent, RetryPolicy, Scheduler, SchedulerOptions};
pub use server::{Server, ServerStats};
