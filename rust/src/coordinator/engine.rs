//! The inference engine abstraction and its two backends.
//!
//! * [`NativeEngine`] — the pure-Rust model (`crate::model`), bit-exact
//!   PS(μ) arithmetic, per-layer instrumentation. Used by the experiment
//!   harness for fast (μ, τ) sweeps and as the parity oracle.
//! * [`PjrtEngine`] — the compiled HLO artifact executed through PJRT; the
//!   production path (Python never runs here).
//!
//! Both consume the same `.lamp` weights, so outputs agree up to FP32
//! reduction-order differences (XLA tiles its FP32 matmuls; the PS(μ) KQ
//! accumulation itself is sequential and bit-identical in both engines).

use super::policy::PrecisionPolicy;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::model::{forward, LampStats, ModelConfig, Weights};
use crate::runtime::{ArtifactStore, ModelExecutor, ModelRequest};

/// Output of one batched engine call.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Per-sequence logits [S, V].
    pub logits: Vec<Matrix>,
    /// Aggregate LAMP statistics for the batch.
    pub stats: LampStats,
}

/// A batched LAMP inference engine.
///
/// Not `Send`: the PJRT executable wraps thread-affine FFI handles, so the
/// server drains batches on the thread that owns the engine; parallelism
/// happens inside the engine (XLA's own thread pool / the native engine's
/// per-sequence pool upstream).
pub trait Engine {
    /// Model configuration (shapes, batch size).
    fn config(&self) -> &ModelConfig;

    /// Run a batch of exactly `config().batch` padded sequences of length
    /// `config().seq`.
    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput>;

    /// Human-readable backend name.
    fn backend(&self) -> &'static str;
}

/// Pure-Rust engine.
pub struct NativeEngine {
    weights: Weights,
}

impl NativeEngine {
    pub fn new(weights: Weights) -> Self {
        NativeEngine { weights }
    }

    /// Load trained weights from the artifact store.
    pub fn load(store: &ArtifactStore, config_name: &str) -> Result<Self> {
        Ok(NativeEngine { weights: store.weights(config_name)? })
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }
}

impl Engine for NativeEngine {
    fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput> {
        let cfg = &self.weights.config;
        let prec = policy.to_attention_precision(cfg.seq);
        let mut logits = Vec::with_capacity(tokens.len());
        let mut stats = LampStats::default();
        for (b, seq) in tokens.iter().enumerate() {
            let out = forward(
                &self.weights,
                seq,
                prec,
                seed as u64 ^ ((b as u64) << 32),
            )?;
            logits.push(out.logits);
            stats.merge(&out.stats);
        }
        Ok(EngineOutput { logits, stats })
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// PJRT-artifact engine.
pub struct PjrtEngine {
    executor: ModelExecutor,
}

impl PjrtEngine {
    pub fn load(store: &ArtifactStore, config_name: &str) -> Result<Self> {
        Ok(PjrtEngine { executor: ModelExecutor::load(store, config_name)? })
    }

    pub fn from_executor(executor: ModelExecutor) -> Self {
        PjrtEngine { executor }
    }
}

impl Engine for PjrtEngine {
    fn config(&self) -> &ModelConfig {
        self.executor.config()
    }

    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput> {
        let resp = self.executor.execute(&ModelRequest {
            tokens: tokens.to_vec(),
            mu: policy.mu,
            tau: policy.tau,
            seed,
            mode: policy.rule.mode_code(),
        })?;
        let layers = self.executor.config().layers;
        Ok(EngineOutput {
            logits: resp.logits,
            stats: LampStats {
                recomputed: resp.recomputed as usize,
                causal_total: resp.causal_total as usize,
                // The artifact reports an aggregate counter only.
                per_layer: vec![0; layers],
            },
        })
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Rule;
    use crate::util::Rng;

    #[test]
    fn native_engine_batch_and_stats() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng));
        let tokens = vec![vec![1u32; 8], vec![2u32; 8]];
        let out = engine
            .infer(&tokens, &PrecisionPolicy::lamp(3, 0.01, Rule::Strict), 0)
            .unwrap();
        assert_eq!(out.logits.len(), 2);
        assert_eq!(out.logits[0].shape(), (8, 128));
        assert_eq!(out.stats.causal_total, 2 * 2 * 2 * 36);
        assert!(out.stats.recomputed > 0);
        assert_eq!(engine.backend(), "native");
    }

    #[test]
    fn native_reference_recomputes_nothing() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(2);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng));
        let out = engine
            .infer(&[vec![3u32; 4]], &PrecisionPolicy::reference(), 0)
            .unwrap();
        assert_eq!(out.stats.recomputed, 0);
    }
}
