//! The inference engine abstraction and its two backends.
//!
//! * [`NativeEngine`] — the pure-Rust model (`crate::model`), bit-exact
//!   PS(μ) arithmetic, per-layer instrumentation. Used by the experiment
//!   harness for fast (μ, τ) sweeps and as the parity oracle.
//! * [`PjrtEngine`] — the compiled HLO artifact executed through PJRT; the
//!   production path (Python never runs here).
//!
//! Both consume the same `.lamp` weights, so outputs agree up to FP32
//! reduction-order differences (XLA tiles its FP32 matmuls; the PS(μ) KQ
//! accumulation itself is sequential and bit-identical in both engines).

use super::policy::{PrecisionPolicy, Rule};
use crate::error::{Error, Result};
use crate::linalg::{Matrix, WeightFormat};
use crate::model::{
    forward_with, generate_with_session, Decode, DecodeSession, ForwardScratch,
    KvBlockPool, KvCacheOptions, LampStats, ModelConfig, PrecisionPlan, Weights,
};
use crate::runtime::{ArtifactStore, ModelExecutor, ModelRequest};
use crate::util::ThreadPool;
use std::sync::{Arc, Mutex};

/// Output of one batched engine call.
#[derive(Debug, Clone)]
pub struct EngineOutput {
    /// Per-sequence logits [S, V].
    pub logits: Vec<Matrix>,
    /// Aggregate LAMP statistics for the batch.
    pub stats: LampStats,
}

/// A batched LAMP inference engine.
///
/// Not `Send`: the PJRT executable wraps thread-affine FFI handles, so the
/// server drains batches on the thread that owns the engine; parallelism
/// happens inside the engine (XLA's own thread pool / the native engine's
/// per-sequence pool upstream).
pub trait Engine {
    /// Model configuration (shapes, batch size).
    fn config(&self) -> &ModelConfig;

    /// Run a batch of exactly `config().batch` padded sequences of length
    /// `config().seq`.
    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput>;

    /// Validate that this backend can execute `policy` — the capability
    /// gate the `Server` applies at `submit()` so an unsupported request
    /// is rejected alone instead of erroring mid-batch and taking its
    /// co-queued requests down with it. The default accepts anything that
    /// passes range validation *and* whose [`crate::model::WeightPrecision`] requirement
    /// matches [`Self::weight_format`] — the storage gate lives here so no
    /// backend can forget it. Backends with a narrower precision surface
    /// (the compiled artifact executes attention-site LAMP only) tighten
    /// it further.
    fn validate_policy(&self, policy: &PrecisionPolicy) -> Result<()> {
        policy.validate()?;
        require_weight_storage(policy, self.weight_format())?;
        require_kv_storage(policy, self.kv_format())
    }

    /// Translate a serving policy into the per-site precision plan a
    /// decode session of this engine uses — the single source of truth
    /// shared by fresh sessions ([`Self::decode_session`]) and the
    /// scheduler's slot recycling (`DecodeSession::reseat`), so recycled
    /// and fresh slots can never diverge on an engine that customizes the
    /// translation.
    fn decode_precision(&self, policy: &PrecisionPolicy) -> PrecisionPlan {
        policy.to_plan(self.config().seq)
    }

    /// Open an incremental KV-cache decode session against this engine —
    /// the session-pool entry point used by the continuous-batching
    /// scheduler (`coordinator::scheduler`). Backends without a native
    /// decode path (the compiled artifact executes fixed-shape full
    /// forwards only) return an error, and the scheduler fails the
    /// affected requests without touching the others.
    fn decode_session(&self, policy: &PrecisionPolicy, seed: u64) -> Result<DecodeSession<'_>> {
        let _ = (policy, seed);
        Err(Error::runtime(format!(
            "backend {:?} has no incremental decode path",
            self.backend()
        )))
    }

    /// The storage format of the weights this backend serves — surfaced
    /// in `ServerStats` so mixed fleets are attributable per format, and
    /// checked against each policy's [`crate::model::WeightPrecision`] requirement in
    /// [`Self::validate_policy`]. The default is f32 (the artifact path
    /// stages f32 buffers); engines with quantized storage override it.
    fn weight_format(&self) -> WeightFormat {
        WeightFormat::F32
    }

    /// The storage format of this backend's KV-cache block pool — the KV
    /// twin of [`Self::weight_format`], checked against each policy's
    /// [`crate::model::KvPrecision`] requirement in
    /// [`Self::validate_policy`]. Defaults to f32 (private per-session
    /// pools); engines configured with a quantized pool override it.
    fn kv_format(&self) -> WeightFormat {
        WeightFormat::F32
    }

    /// The shared KV block pool backing this engine's decode sessions, if
    /// one is configured. The scheduler uses it to gate admission on free
    /// blocks and to surface pool occupancy / prefix-share metrics;
    /// `None` means sessions carry private full-context pools and
    /// admission is ungated (the pre-paging behavior).
    fn kv_pool(&self) -> Option<Arc<KvBlockPool>> {
        None
    }

    /// Fault-injection counters when this engine is a
    /// [`crate::coordinator::faults::FaultInjector`] decorator; `None`
    /// (the default) on real backends. Lets the scheduler and server
    /// surface injected-fault totals without downcasting.
    fn fault_stats(&self) -> Option<super::faults::FaultStats> {
        None
    }

    /// Human-readable backend name.
    fn backend(&self) -> &'static str;
}

/// Shared storage gate: a policy demanding an exact weight format is
/// rejected unless the engine holds exactly that storage.
fn require_weight_storage(policy: &PrecisionPolicy, held: WeightFormat) -> Result<()> {
    if !policy.weights.accepts(held) {
        return Err(Error::runtime(format!(
            "policy requires {} weight storage, backend holds {}",
            policy.weights.label(),
            held.label()
        )));
    }
    Ok(())
}

/// Shared KV-storage gate: a policy pinning an exact KV-cache format is
/// rejected unless the engine's block pool holds exactly that format.
fn require_kv_storage(policy: &PrecisionPolicy, held: WeightFormat) -> Result<()> {
    if !policy.kv.accepts(held) {
        return Err(Error::runtime(format!(
            "policy requires {} KV-cache storage, backend holds {}",
            policy.kv.label(),
            held.label()
        )));
    }
    Ok(())
}

/// Pure-Rust engine.
///
/// Holds a free-list of [`ForwardScratch`] buffers (so repeated `infer`
/// calls allocate nothing once warm, even when several threads share one
/// engine through an `Arc`) and, optionally, a [`ThreadPool`] over which
/// attention is tiled. Without a pool the engine computes sequentially —
/// the right configuration when an outer harness already parallelizes
/// across sequences (e.g. the experiment panels).
pub struct NativeEngine {
    weights: Weights,
    pool: Option<Arc<ThreadPool>>,
    /// Shared paged KV block pool for decode sessions (`None` = private
    /// per-session full-context pools, the pre-paging behavior).
    kv: Option<Arc<KvBlockPool>>,
    scratch: Mutex<Vec<ForwardScratch>>,
}

impl NativeEngine {
    pub fn new(weights: Weights) -> Self {
        NativeEngine { weights, pool: None, kv: None, scratch: Mutex::new(Vec::new()) }
    }

    /// Back decode sessions with a shared paged KV block pool — the
    /// `--kv-fmt`/`--kv-tau` entry point. All sessions draw blocks from
    /// one pool, enabling admission gating, prefix sharing, and (for
    /// bf16) half the resident KV bytes per session.
    pub fn with_kv_cache(mut self, opts: KvCacheOptions) -> Result<Self> {
        self.kv = Some(KvBlockPool::new(&self.weights.config, opts)?);
        Ok(self)
    }

    /// Re-store the engine's weight matrices under `fmt`
    /// (`Weights::quantize_to`): the `--weights-fmt` entry point. bf16
    /// halves resident parameter bytes and decode weight traffic; the
    /// same-format case (every default `--weights-fmt f32` run) is a
    /// zero-copy no-op.
    pub fn with_weight_format(mut self, fmt: WeightFormat) -> Result<Self> {
        fmt.validate()?;
        if fmt != self.weights.weight_format() {
            self.weights = self.weights.quantize_to(fmt)?;
        }
        Ok(self)
    }

    /// Load trained weights from the artifact store.
    pub fn load(store: &ArtifactStore, config_name: &str) -> Result<Self> {
        Ok(Self::new(store.weights(config_name)?))
    }

    /// Tile attention across `threads` workers (capped at the host CPU
    /// count). `threads == 0` means "all available CPUs".
    pub fn with_threads(mut self, threads: usize) -> Self {
        let cap = if threads == 0 { usize::MAX } else { threads };
        self.pool = Some(Arc::new(ThreadPool::with_cpus(cap)));
        self
    }

    /// Share an existing pool for attention tiling.
    pub fn with_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    pub fn weights(&self) -> &Weights {
        &self.weights
    }

    /// Run `f` with a pooled scratch, returning the scratch afterwards —
    /// zero allocation in steady state, safe under concurrent callers.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut ForwardScratch) -> R) -> R {
        let mut scratch = self
            .scratch
            .lock()
            .expect("scratch lock")
            .pop()
            .unwrap_or_else(|| ForwardScratch::for_config(&self.weights.config));
        let r = f(&mut scratch);
        self.scratch.lock().expect("scratch lock").push(scratch);
        r
    }

    /// Autoregressive generation through the KV-cache decode path —
    /// the same session source ([`Engine::decode_session`]) and decode
    /// loop (`generate_with_session`) the scheduler uses, so solo and
    /// scheduled decoding share one definition, shared KV pool included.
    /// Returns (tokens, recompute_rate).
    pub fn generate(
        &self,
        prompt: &[u32],
        new_tokens: usize,
        policy: &PrecisionPolicy,
        decode: Decode,
        seed: u64,
    ) -> Result<(Vec<u32>, f64)> {
        let mut session = self.decode_session(policy, seed)?;
        let (tokens, stats) = generate_with_session(&mut session, prompt, new_tokens, decode)?;
        Ok((tokens, stats.rate()))
    }
}

impl Engine for NativeEngine {
    fn config(&self) -> &ModelConfig {
        &self.weights.config
    }

    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput> {
        let cfg = &self.weights.config;
        let plan = policy.to_plan(cfg.seq);
        self.with_scratch(|scratch| {
            let mut logits = Vec::with_capacity(tokens.len());
            let mut stats = LampStats::default();
            for (b, seq) in tokens.iter().enumerate() {
                let out = forward_with(
                    &self.weights,
                    seq,
                    plan,
                    seed as u64 ^ ((b as u64) << 32),
                    scratch,
                    self.pool.as_deref(),
                )?;
                logits.push(out.logits);
                stats.merge(&out.stats);
            }
            Ok(EngineOutput { logits, stats })
        })
    }

    /// KV-cache decode sessions are native-engine territory: the session
    /// shares this engine's weights, so its logits are bit-identical to the
    /// full forward pass (DESIGN.md §Bit-exactness). With a configured
    /// shared KV pool ([`NativeEngine::with_kv_cache`]) sessions draw
    /// paged blocks from it; otherwise each session carries a private
    /// f32 full-context pool.
    fn decode_session(&self, policy: &PrecisionPolicy, seed: u64) -> Result<DecodeSession<'_>> {
        require_weight_storage(policy, self.weight_format())?;
        require_kv_storage(policy, self.kv_format())?;
        let plan = self.decode_precision(policy);
        let mut session = match &self.kv {
            Some(pool) => DecodeSession::with_pool(&self.weights, plan, seed, pool.clone()),
            None => DecodeSession::new(&self.weights, plan, seed),
        };
        // Speculative verification fans candidate rows across the engine's
        // pool; the rows are bit-identical either way, so this only sets
        // the parallelism, never the output.
        session.set_threads(self.pool.clone());
        Ok(session)
    }

    /// Storage requirements are checked against the actual weights (via
    /// the trait-default `validate_policy` storage gate), so a request
    /// pinned to e.g. bf16 storage is rejected at submit by an f32-holding
    /// engine instead of silently serving the wrong format.
    fn weight_format(&self) -> WeightFormat {
        self.weights.weight_format()
    }

    /// The configured pool's slab format; private per-session pools are
    /// always f32 (the trait default).
    fn kv_format(&self) -> WeightFormat {
        self.kv.as_ref().map(|p| p.format()).unwrap_or(WeightFormat::F32)
    }

    fn kv_pool(&self) -> Option<Arc<KvBlockPool>> {
        self.kv.clone()
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// The compiled HLO bakes attention-site LAMP only; reject plans with
/// active non-attention sites instead of silently dropping them (the
/// native engine serves those).
fn require_attention_only(policy: &PrecisionPolicy) -> Result<()> {
    if !policy.is_attention_only() {
        return Err(Error::runtime(format!(
            "pjrt backend executes the attention site only; policy {} \
             activates non-attention LAMP sites (use the native engine)",
            policy.label()
        )));
    }
    Ok(())
}

/// PJRT-artifact engine.
pub struct PjrtEngine {
    executor: ModelExecutor,
}

impl PjrtEngine {
    pub fn load(store: &ArtifactStore, config_name: &str) -> Result<Self> {
        Ok(PjrtEngine { executor: ModelExecutor::load(store, config_name)? })
    }

    pub fn from_executor(executor: ModelExecutor) -> Self {
        PjrtEngine { executor }
    }
}

impl Engine for PjrtEngine {
    fn config(&self) -> &ModelConfig {
        self.executor.config()
    }

    fn infer(
        &self,
        tokens: &[Vec<u32>],
        policy: &PrecisionPolicy,
        seed: i32,
    ) -> Result<EngineOutput> {
        // Defense in depth for direct callers — the Server applies the
        // same gate at submit() via `validate_policy`, so a whole-model
        // request never reaches a cut batch here.
        require_attention_only(policy)?;
        require_weight_storage(policy, self.weight_format())?;
        let att = policy.attention;
        let resp = self.executor.execute(&ModelRequest {
            tokens: tokens.to_vec(),
            mu: att.mu,
            tau: att.tau,
            seed,
            mode: att.rule.mode_code(),
        })?;
        let layers = self.executor.config().layers;
        Ok(EngineOutput {
            logits: resp.logits,
            stats: LampStats {
                recomputed: resp.recomputed as usize,
                causal_total: resp.causal_total as usize,
                // The artifact reports an aggregate counter only.
                per_layer: vec![0; layers],
                ..LampStats::default()
            },
        })
    }

    /// The artifact stages f32 weight buffers only and has no paged KV
    /// pool: a request pinned to a non-f32 weight or KV storage format is
    /// rejected at submit, not mid-batch (the trait-default
    /// [`Engine::weight_format`]/[`Engine::kv_format`] are f32, so the
    /// shared storage gates enforce exactly that).
    fn validate_policy(&self, policy: &PrecisionPolicy) -> Result<()> {
        policy.validate()?;
        require_attention_only(policy)?;
        // The compiled artifact implements mode codes 0-3 only; the tile
        // rules (PR 8) exist in the native engines alone.
        if matches!(policy.attention.rule, Rule::Tile { .. } | Rule::TileRandom { .. }) {
            return Err(Error::config(format!(
                "pjrt backend does not implement tile rule {:?}",
                policy.attention.rule.name()
            )));
        }
        // Speculative decoding rides the incremental KV decode path (draft
        // rounds, checkpoint/rollback, batched verify); the artifact
        // executes fixed-shape full forwards only.
        if policy.spec.is_some() {
            return Err(Error::config(
                "pjrt backend does not support speculative decoding \
                 (use the native engine)"
                    .to_string(),
            ));
        }
        require_weight_storage(policy, self.weight_format())?;
        require_kv_storage(policy, self.kv_format())
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::Rule;
    use crate::util::Rng;

    #[test]
    fn native_engine_batch_and_stats() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
        let tokens = vec![vec![1u32; 8], vec![2u32; 8]];
        let out = engine
            .infer(&tokens, &PrecisionPolicy::lamp(3, 0.01, Rule::Strict), 0)
            .unwrap();
        assert_eq!(out.logits.len(), 2);
        assert_eq!(out.logits[0].shape(), (8, 128));
        assert_eq!(out.stats.causal_total, 2 * 2 * 2 * 36);
        assert!(out.stats.recomputed > 0);
        assert_eq!(engine.backend(), "native");
    }

    #[test]
    fn parallel_engine_bit_identical_and_generates() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(3);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        let seq_engine = NativeEngine::new(w.clone());
        let par_engine = NativeEngine::new(w).with_threads(3);
        let tokens = vec![vec![1u32; 12], vec![9u32; 12]];
        let policy = PrecisionPolicy::lamp(3, 0.01, Rule::Strict);
        let a = seq_engine.infer(&tokens, &policy, 1).unwrap();
        let b = par_engine.infer(&tokens, &policy, 1).unwrap();
        assert_eq!(a.logits, b.logits, "pool must not change engine output");
        assert_eq!(a.stats.recomputed, b.stats.recomputed);
        // Scratch is pooled and reused across calls.
        let c = par_engine.infer(&tokens, &policy, 1).unwrap();
        assert_eq!(a.logits, c.logits);
        // KV-cache decode rides on the same engine.
        let (toks, rate) =
            par_engine.generate(&[1, 2, 3], 5, &policy, Decode::Greedy, 0).unwrap();
        assert_eq!(toks.len(), 8);
        assert!(rate > 0.0, "strict tau=0.01 must recompute");
        let mut session = par_engine.decode_session(&policy, 0).unwrap();
        session.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn decode_session_default_is_unsupported() {
        // A backend that does not override `decode_session` reports a typed
        // runtime error instead of panicking — the scheduler relies on this
        // to fail requests cleanly on session-less engines.
        struct NoDecode(ModelConfig);
        impl Engine for NoDecode {
            fn config(&self) -> &ModelConfig {
                &self.0
            }
            fn infer(
                &self,
                _tokens: &[Vec<u32>],
                _policy: &PrecisionPolicy,
                _seed: i32,
            ) -> Result<EngineOutput> {
                Err(Error::runtime("stub".to_string()))
            }
            fn backend(&self) -> &'static str {
                "stub"
            }
        }
        let e = NoDecode(ModelConfig::nano());
        let err = e
            .decode_session(&PrecisionPolicy::reference(), 0)
            .err()
            .expect("must be unsupported");
        assert!(err.to_string().contains("no incremental decode path"));
    }

    #[test]
    fn decode_precision_translates_every_site() {
        use crate::coordinator::policy::SitePolicy;
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(5);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
        let policy = PrecisionPolicy::lamp(4, 0.1, Rule::Strict)
            .with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict))
            .with_sampler(SitePolicy::uniform(7));
        let plan = engine.decode_precision(&policy);
        assert_eq!(plan.attention.mu, 4);
        assert_eq!(plan.mlp.mu, 7);
        assert!(plan.norm.is_reference());
        assert_eq!(plan.sampler.mu, 7);
        // And a session opened under it accounts non-attention sites.
        let mut session = engine.decode_session(&policy, 3).unwrap();
        session.prefill(&[1, 2, 3, 4]).unwrap();
        assert!(session.stats().mlp.recomputed > 0, "mlp site inactive");
        assert_eq!(session.stats().mlp.total, cfg.layers * 4 * cfg.d_ff());
    }

    #[test]
    fn engine_kv_cache_configuration_and_gate() {
        use crate::model::KvPrecision;
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(21);
        let w = Weights::random(&cfg, &mut rng).unwrap();
        // Default engine: f32 KV format, no shared pool, so a bf16-KV
        // pinned policy is rejected at the capability gate.
        let e = NativeEngine::new(w.clone());
        assert_eq!(e.kv_format(), WeightFormat::F32);
        assert!(e.kv_pool().is_none());
        let pinned = PrecisionPolicy::reference()
            .with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        let err = e.validate_policy(&pinned).unwrap_err().to_string();
        assert!(err.contains("KV-cache storage"), "{err}");
        // With a matching shared pool the policy is accepted, sessions
        // draw paged blocks from the pool, and solo generate rides the
        // same pool.
        let e = NativeEngine::new(w)
            .with_kv_cache(KvCacheOptions::serving(&cfg, WeightFormat::Bf16, 2))
            .unwrap();
        assert_eq!(e.kv_format(), WeightFormat::Bf16);
        e.validate_policy(&pinned).unwrap();
        let mut s = e.decode_session(&pinned, 0).unwrap();
        s.prefill(&[1, 2, 3]).unwrap();
        assert!(e.kv_pool().unwrap().stats().used_blocks > 0);
        let (toks, _) = e
            .generate(&[1, 2, 3], 4, &PrecisionPolicy::reference(), Decode::Greedy, 1)
            .unwrap();
        assert_eq!(toks.len(), 7);
        // Invalid pool options are typed config errors.
        let mut bad = KvCacheOptions::serving(&cfg, WeightFormat::F32, 1);
        bad.block_size = 0;
        let mut rng = Rng::new(22);
        assert!(NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())
            .with_kv_cache(bad)
            .is_err());
    }

    #[test]
    fn speculative_policy_serves_bit_identical_tokens() {
        use crate::coordinator::policy::{SitePolicy, SpecPolicy};
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(17);
        let engine =
            NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap()).with_threads(3);
        let solo = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
        let spec =
            solo.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 3)));
        engine.validate_policy(&spec).unwrap();
        let (base, _) =
            engine.generate(&[5, 9, 2], 10, &solo, Decode::Greedy, 7).unwrap();
        let (specd, _) =
            engine.generate(&[5, 9, 2], 10, &spec, Decode::Greedy, 7).unwrap();
        assert_eq!(base, specd, "speculation must not change the stream");
    }

    #[test]
    fn native_reference_recomputes_nothing() {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(2);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
        let out = engine
            .infer(&[vec![3u32; 4]], &PrecisionPolicy::reference(), 0)
            .unwrap();
        assert_eq!(out.stats.recomputed, 0);
    }
}
