//! The serving loop: requests in, batched engine calls, responses out.
//!
//! Single-threaded engine draining (the PJRT executable is already
//! internally parallel on CPU; the native engine parallelizes across the
//! batch via the thread pool upstream). The server tracks the
//! latency/throughput statistics reported by the serving benchmarks.
//!
//! Two traffic classes share one server:
//! * **one-shot inference** ([`Server::submit`]) — logits for a whole
//!   sequence, batched by the [`Batcher`] into fixed-shape engine calls;
//! * **generation** ([`Server::submit_generate`]) — autoregressive decode,
//!   driven through the continuous-batching [`Scheduler`] so short
//!   requests never queue behind long generations.

use super::batcher::{Batcher, CutBatch};
use super::engine::Engine;
use super::request::{GenerateRequest, InferenceRequest, InferenceResponse};
use super::scheduler::{GenerateEvent, Scheduler, SchedulerOptions};
use crate::error::{Error, Result};
use crate::metrics::Accumulator;
use crate::model::LampStats;
use crate::obs::ObsHub;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub padding_rows: usize,
    pub total_tokens: usize,
    pub recomputed: usize,
    pub causal_total: usize,
    pub latency_mean_s: f64,
    pub latency_p95_s: f64,
    pub wall_s: f64,
    pub throughput_tok_s: f64,
    // --- Decode-path metrics (continuous-batching scheduler). ---
    /// Generation requests accepted (completed + failed).
    pub generate_requests: usize,
    /// Generation requests that failed (their sessions errored).
    pub generate_failed: usize,
    /// Tokens generated across all generation requests.
    pub generated_tokens: usize,
    /// Time-to-first-token percentiles of the latest generation drive, s.
    pub ttft_p50_s: f64,
    pub ttft_p95_s: f64,
    /// Inter-token latency percentiles of the latest generation drive, s.
    pub itl_p50_s: f64,
    pub itl_p95_s: f64,
    /// Mean live sessions per scheduler iteration (occupancy) of the
    /// latest generation drive.
    pub mean_active_sessions: f64,
    /// **Attention-site** recompute rate per policy label over the latest
    /// generation drive (per-site breakdown: `recompute_rate_by_site`).
    pub recompute_rate_by_policy: Vec<(String, f64)>,
    /// Recompute rate per composition site (attention, mlp, norm, sampler)
    /// over the latest generation drive.
    pub recompute_rate_by_site: Vec<(String, f64)>,
    /// The engine's active weight-storage format (`WeightFormat::label`):
    /// `f32`, `bf16`, or `ps<mu>` — alongside the per-site rates so mixed
    /// fleets of requests are attributable per format.
    pub weight_format: String,
    // --- Paged KV-cache metrics (PR 5; engines with a shared pool). ---
    /// The engine's KV-cache storage format (`f32`/`bf16`/`ps<mu>`).
    pub kv_format: String,
    /// Slab-resident bytes of live KV blocks (0 without a shared pool).
    pub kv_resident_bytes: usize,
    /// Block-pool occupancy at snapshot time.
    pub kv_blocks_used: usize,
    pub kv_blocks_capacity: usize,
    pub kv_occupancy: f64,
    /// Prefix-share adoptions and hit rate over the pool's lifetime.
    pub prefix_share_hits: usize,
    pub prefix_share_rate: f64,
    /// Decode sessions preempted on pool exhaustion (recomputed later).
    pub preemptions: usize,
    // --- Fault-tolerance metrics (PR 6). ---
    /// In-place retries of retryable decode-step failures.
    pub generate_retries: usize,
    /// Requests terminated by deadline or run-budget timeouts.
    pub generate_timeouts: usize,
    /// Requests terminated by their cancellation handle.
    pub generate_canceled: usize,
    /// Faults injected by a [`FaultInjector`](super::faults::FaultInjector)
    /// wrapped around the engine (0 without injection).
    pub faults_injected: usize,
    /// Requests admitted with a ladder-degraded precision policy.
    pub degraded_admissions: usize,
    /// Ladder transitions to a cheaper rung (degrade) and back (restore).
    pub degrade_transitions: usize,
    pub restore_transitions: usize,
    /// Current degradation-ladder rung after the latest generation drive
    /// (0 = nominal) and its name.
    pub ladder_rung: usize,
    pub ladder_rung_name: String,
    // --- Speculative-decoding metrics (PR 9). ---
    /// Draft/verify rounds completed across all generation drives.
    pub spec_rounds: usize,
    /// Tokens drafted under the cheap plan and tokens of those accepted by
    /// the exact verify pass (accepted/drafted = acceptance rate).
    pub spec_drafted: usize,
    pub spec_accepted: usize,
    pub spec_acceptance_rate: f64,
    /// Mean tokens emitted per round (accepted prefix + the free token
    /// sampled from the verify logits).
    pub spec_mean_accept_len: f64,
    /// Histogram of tokens emitted per round: index i counts rounds that
    /// emitted i+1 tokens.
    pub spec_accept_hist: Vec<usize>,
}

impl ServerStats {
    /// Render the snapshot as one stable-keyed JSON object (the
    /// `--stats-json` payload). Keys follow field declaration order;
    /// the per-policy/per-site rate lists become objects keyed by label
    /// and the acceptance histogram an integer array.
    pub fn to_json(&self) -> String {
        use crate::obs::export::{json_escape, json_f64};
        fn rates(pairs: &[(String, f64)]) -> String {
            let body = pairs
                .iter()
                .map(|(k, v)| {
                    format!(
                        "\"{}\": {}",
                        crate::obs::export::json_escape(k),
                        crate::obs::export::json_f64(*v)
                    )
                })
                .collect::<Vec<_>>()
                .join(", ");
            format!("{{{body}}}")
        }
        let hist = self
            .spec_accept_hist
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        let fields: Vec<(&str, String)> = vec![
            ("requests", self.requests.to_string()),
            ("batches", self.batches.to_string()),
            ("padding_rows", self.padding_rows.to_string()),
            ("total_tokens", self.total_tokens.to_string()),
            ("recomputed", self.recomputed.to_string()),
            ("causal_total", self.causal_total.to_string()),
            ("latency_mean_s", json_f64(self.latency_mean_s)),
            ("latency_p95_s", json_f64(self.latency_p95_s)),
            ("wall_s", json_f64(self.wall_s)),
            ("throughput_tok_s", json_f64(self.throughput_tok_s)),
            ("generate_requests", self.generate_requests.to_string()),
            ("generate_failed", self.generate_failed.to_string()),
            ("generated_tokens", self.generated_tokens.to_string()),
            ("ttft_p50_s", json_f64(self.ttft_p50_s)),
            ("ttft_p95_s", json_f64(self.ttft_p95_s)),
            ("itl_p50_s", json_f64(self.itl_p50_s)),
            ("itl_p95_s", json_f64(self.itl_p95_s)),
            ("mean_active_sessions", json_f64(self.mean_active_sessions)),
            ("recompute_rate_by_policy", rates(&self.recompute_rate_by_policy)),
            ("recompute_rate_by_site", rates(&self.recompute_rate_by_site)),
            ("weight_format", format!("\"{}\"", json_escape(&self.weight_format))),
            ("kv_format", format!("\"{}\"", json_escape(&self.kv_format))),
            ("kv_resident_bytes", self.kv_resident_bytes.to_string()),
            ("kv_blocks_used", self.kv_blocks_used.to_string()),
            ("kv_blocks_capacity", self.kv_blocks_capacity.to_string()),
            ("kv_occupancy", json_f64(self.kv_occupancy)),
            ("prefix_share_hits", self.prefix_share_hits.to_string()),
            ("prefix_share_rate", json_f64(self.prefix_share_rate)),
            ("preemptions", self.preemptions.to_string()),
            ("generate_retries", self.generate_retries.to_string()),
            ("generate_timeouts", self.generate_timeouts.to_string()),
            ("generate_canceled", self.generate_canceled.to_string()),
            ("faults_injected", self.faults_injected.to_string()),
            ("degraded_admissions", self.degraded_admissions.to_string()),
            ("degrade_transitions", self.degrade_transitions.to_string()),
            ("restore_transitions", self.restore_transitions.to_string()),
            ("ladder_rung", self.ladder_rung.to_string()),
            (
                "ladder_rung_name",
                format!("\"{}\"", json_escape(&self.ladder_rung_name)),
            ),
            ("spec_rounds", self.spec_rounds.to_string()),
            ("spec_drafted", self.spec_drafted.to_string()),
            ("spec_accepted", self.spec_accepted.to_string()),
            ("spec_acceptance_rate", json_f64(self.spec_acceptance_rate)),
            ("spec_mean_accept_len", json_f64(self.spec_mean_accept_len)),
            ("spec_accept_hist", format!("[{hist}]")),
        ];
        let body = fields
            .iter()
            .map(|(k, v)| format!("  \"{k}\": {v}"))
            .collect::<Vec<_>>()
            .join(",\n");
        format!("{{\n{body}\n}}\n")
    }
}

/// Synchronous batching server over one engine.
pub struct Server {
    engine: Box<dyn Engine>,
    batcher: Batcher,
    latencies: Vec<f64>,
    stats: ServerStats,
    started: Instant,
    pending_generate: VecDeque<GenerateRequest>,
    decode_opts: SchedulerOptions,
    /// The server's observability hub: each generation drive runs against
    /// a child hub (shared tracer/clock, private registry) whose counters
    /// are absorbed back here, so lifetime counters accumulate across
    /// drives exactly like the `+=` folds in [`ServerStats`].
    obs: Arc<ObsHub>,
}

impl Server {
    pub fn new(engine: Box<dyn Engine>, max_wait: Duration) -> Self {
        let batch = engine.config().batch;
        Server {
            engine,
            batcher: Batcher::new(batch, max_wait),
            latencies: Vec::new(),
            stats: ServerStats::default(),
            started: Instant::now(),
            pending_generate: VecDeque::new(),
            decode_opts: SchedulerOptions::default(),
            obs: Arc::new(ObsHub::new()),
        }
    }

    /// Configure the continuous-batching scheduler used for generation
    /// traffic (slot count, prefill chunking, step-fan-out pool).
    pub fn with_scheduler_options(mut self, opts: SchedulerOptions) -> Self {
        self.decode_opts = opts;
        self
    }

    /// Attach an observability hub (e.g. one with a span tracer for
    /// `--trace-out`, or a virtual clock under replay). The scheduler
    /// options' own `obs` field is ignored by the server — drives always
    /// go through children of this hub.
    pub fn with_obs(mut self, hub: Arc<ObsHub>) -> Self {
        self.obs = hub;
        self
    }

    /// The server's observability hub (snapshot its registry for
    /// `--metrics-out`, read its tracer for `--trace-out`).
    pub fn obs(&self) -> &Arc<ObsHub> {
        &self.obs
    }

    /// Validate and enqueue a request. Backend capability is checked here
    /// (`Engine::validate_policy`), so a policy this engine cannot execute
    /// is rejected alone instead of erroring mid-batch and failing its
    /// co-queued requests.
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        let cfg = self.engine.config();
        req.validate(cfg.vocab, cfg.seq)?;
        self.engine.validate_policy(&req.policy)?;
        self.batcher.push(req);
        Ok(())
    }

    /// Validate and enqueue a generation request (same front-door backend
    /// capability check as [`Self::submit`]).
    pub fn submit_generate(&mut self, req: GenerateRequest) -> Result<()> {
        let cfg = self.engine.config();
        req.validate(cfg.vocab, cfg.seq)?;
        self.engine.validate_policy(&req.policy)?;
        self.pending_generate.push_back(req);
        Ok(())
    }

    /// Queued requests.
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Queued generation requests.
    pub fn pending_generation(&self) -> usize {
        self.pending_generate.len()
    }

    /// Drive every queued generation request through the continuous-batching
    /// scheduler until retirement; returns the full event stream (per-token
    /// events, completions, failures). Decode metrics fold into
    /// [`ServerStats`].
    ///
    /// Returns `Err(Error::Timeout)` when the scheduler's run budget
    /// ([`SchedulerOptions::max_run_steps`]/[`SchedulerOptions::max_run_wall`])
    /// trips; in-flight requests are failed with typed timeout events and the
    /// metrics still fold into the stats before the error propagates.
    pub fn serve_generation(&mut self) -> Result<Vec<GenerateEvent>> {
        if self.pending_generate.is_empty() {
            return Ok(Vec::new());
        }
        let reqs: Vec<GenerateRequest> = self.pending_generate.drain(..).collect();
        let n = reqs.len();
        let drive_hub = Arc::new(self.obs.child());
        let (events, metrics, outcome) = {
            let mut opts = self.decode_opts.clone();
            opts.obs = Some(Arc::clone(&drive_hub));
            let mut sched = Scheduler::new(self.engine.as_ref(), opts);
            for r in reqs {
                sched.admit(r);
            }
            let mut events = Vec::new();
            let outcome = sched.run_until_idle(&mut events);
            (events, sched.metrics(), outcome)
        };
        // Fold the drive's counters/gauges/histograms into the server
        // registry: counters add (lifetime accumulation), gauges take the
        // latest value — the same semantics as the field folds below.
        self.obs.registry().absorb(&drive_hub.registry().snapshot());
        self.stats.generate_requests += n;
        self.stats.generate_failed += metrics.failed;
        self.stats.generated_tokens += metrics.generated_tokens;
        self.stats.recomputed += metrics.recomputed;
        self.stats.causal_total += metrics.causal_total;
        self.stats.total_tokens += metrics.generated_tokens;
        self.stats.ttft_p50_s = metrics.ttft_p50_s;
        self.stats.ttft_p95_s = metrics.ttft_p95_s;
        self.stats.itl_p50_s = metrics.itl_p50_s;
        self.stats.itl_p95_s = metrics.itl_p95_s;
        self.stats.mean_active_sessions = metrics.mean_active_sessions;
        self.stats.recompute_rate_by_policy = metrics.recompute_by_policy;
        self.stats.recompute_rate_by_site = metrics.recompute_by_site;
        self.stats.preemptions += metrics.preemptions;
        self.stats.prefix_share_hits = metrics.prefix_share_hits;
        self.stats.prefix_share_rate = metrics.prefix_share_rate;
        self.stats.generate_retries += metrics.retries;
        self.stats.generate_timeouts += metrics.timeouts;
        self.stats.generate_canceled += metrics.canceled;
        self.stats.faults_injected = metrics.faults_injected;
        self.stats.degraded_admissions += metrics.degraded_admissions;
        self.stats.degrade_transitions += metrics.degrade_transitions;
        self.stats.restore_transitions += metrics.restore_transitions;
        self.stats.ladder_rung = metrics.ladder_rung;
        self.stats.ladder_rung_name = metrics.ladder_rung_name;
        self.stats.spec_rounds += metrics.spec_rounds;
        self.stats.spec_drafted += metrics.spec_drafted;
        self.stats.spec_accepted += metrics.spec_accepted;
        self.stats.spec_acceptance_rate = if self.stats.spec_drafted > 0 {
            self.stats.spec_accepted as f64 / self.stats.spec_drafted as f64
        } else {
            0.0
        };
        if self.stats.spec_accept_hist.len() < metrics.spec_accept_hist.len() {
            self.stats.spec_accept_hist.resize(metrics.spec_accept_hist.len(), 0);
        }
        for (slot, &n) in
            self.stats.spec_accept_hist.iter_mut().zip(metrics.spec_accept_hist.iter())
        {
            *slot += n;
        }
        self.stats.spec_mean_accept_len = if self.stats.spec_rounds > 0 {
            self.stats
                .spec_accept_hist
                .iter()
                .enumerate()
                .map(|(i, &n)| (i + 1) * n)
                .sum::<usize>() as f64
                / self.stats.spec_rounds as f64
        } else {
            0.0
        };
        outcome?;
        Ok(events)
    }

    /// Drain one batch if ready; returns its responses.
    pub fn step(&mut self, force: bool) -> Result<Vec<InferenceResponse>> {
        match self.batcher.cut(force) {
            None => Ok(Vec::new()),
            Some(batch) => self.run_batch(batch),
        }
    }

    /// Drain everything (forcing partial batches).
    pub fn drain(&mut self) -> Result<Vec<InferenceResponse>> {
        let mut out = Vec::new();
        while self.batcher.pending() > 0 {
            out.extend(self.step(true)?);
        }
        Ok(out)
    }

    fn run_batch(&mut self, batch: CutBatch) -> Result<Vec<InferenceResponse>> {
        let cfg = self.engine.config();
        let seq = cfg.seq;
        let batch_size = cfg.batch;
        let tokens = Batcher::assemble_tokens(&batch, seq);
        let seed = batch.requests.first().map(|(r, _)| r.seed).unwrap_or(0);
        let out = self.engine.infer(&tokens, &batch.policy, seed)?;
        if out.logits.len() != batch_size {
            return Err(Error::coordinator(format!(
                "engine returned {} rows for batch {batch_size}",
                out.logits.len()
            )));
        }
        // Padding rows inflate the recompute counters; attribute pro rata
        // to real rows only.
        let real = batch.requests.len();
        let scale = real as f64 / batch_size as f64;
        let stats = LampStats {
            recomputed: (out.stats.recomputed as f64 * scale).round() as usize,
            causal_total: (out.stats.causal_total as f64 * scale).round() as usize,
            per_layer: out.stats.per_layer.clone(),
            mlp: out.stats.mlp.scaled(scale),
            norm: out.stats.norm.scaled(scale),
            sampler: out.stats.sampler.scaled(scale),
        };
        self.stats.batches += 1;
        self.stats.padding_rows += batch.padding_rows;
        self.stats.recomputed += stats.recomputed;
        self.stats.causal_total += stats.causal_total;

        let now = Instant::now();
        let mut responses = Vec::with_capacity(real);
        for (i, (req, t0)) in batch.requests.into_iter().enumerate() {
            let n = req.tokens.len();
            let logits = out.logits[i].slice_rows(0, n)?;
            let latency = now.duration_since(t0).as_secs_f64();
            self.latencies.push(latency);
            self.stats.requests += 1;
            self.stats.total_tokens += n;
            responses.push(InferenceResponse {
                id: req.id,
                logits,
                batch_stats: stats.clone(),
                latency_s: latency,
            });
        }
        Ok(responses)
    }

    /// Final statistics snapshot.
    pub fn stats(&mut self) -> ServerStats {
        self.stats.weight_format = self.engine.weight_format().label();
        self.stats.kv_format = self.engine.kv_format().label();
        if let Some(pool) = self.engine.kv_pool() {
            let kv = pool.stats();
            self.stats.kv_resident_bytes = kv.resident_bytes;
            self.stats.kv_blocks_used = kv.used_blocks;
            self.stats.kv_blocks_capacity = kv.capacity_blocks;
            self.stats.kv_occupancy = kv.occupancy();
            self.stats.prefix_share_hits = kv.share_hits;
            self.stats.prefix_share_rate = kv.share_rate();
        }
        let mut acc = Accumulator::new();
        for &l in &self.latencies {
            acc.push(l);
        }
        self.stats.latency_mean_s = if self.latencies.is_empty() { 0.0 } else { acc.mean() };
        self.stats.latency_p95_s = super::scheduler::percentile(&self.latencies, 0.95);
        self.stats.wall_s = self.started.elapsed().as_secs_f64();
        self.stats.throughput_tok_s = if self.stats.wall_s > 0.0 {
            self.stats.total_tokens as f64 / self.stats.wall_s
        } else {
            0.0
        };
        self.stats.clone()
    }

    /// Engine backend name.
    pub fn backend(&self) -> &'static str {
        self.engine.backend()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::NativeEngine;
    use crate::coordinator::policy::{PrecisionPolicy, Rule};
    use crate::model::{ModelConfig, Weights};
    use crate::util::Rng;

    fn server() -> Server {
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        Server::new(
            Box::new(NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())),
            Duration::from_millis(1),
        )
    }

    #[test]
    fn serves_full_batch() {
        let mut s = server();
        let p = PrecisionPolicy::lamp(4, 0.05, Rule::Strict);
        s.submit(InferenceRequest::new(1, vec![1, 2, 3, 4], p)).unwrap();
        s.submit(InferenceRequest::new(2, vec![5, 6], p)).unwrap();
        let rs = s.step(false).unwrap();
        assert_eq!(rs.len(), 2);
        let r1 = rs.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(r1.logits.shape(), (4, 128));
        let r2 = rs.iter().find(|r| r.id == 2).unwrap();
        assert_eq!(r2.logits.shape(), (2, 128));
    }

    #[test]
    fn padding_does_not_change_real_logits() {
        // Serve the same request alone (padded) and in a full batch: the
        // causal property guarantees identical logits for the real prefix.
        let p = PrecisionPolicy::reference();
        let mut s1 = server();
        s1.submit(InferenceRequest::new(1, vec![1, 2, 3], p)).unwrap();
        let alone = s1.drain().unwrap().remove(0);

        let mut s2 = server();
        s2.submit(InferenceRequest::new(1, vec![1, 2, 3], p)).unwrap();
        s2.submit(InferenceRequest::new(2, vec![9, 8, 7, 6], p)).unwrap();
        let mut both = s2.drain().unwrap();
        both.sort_by_key(|r| r.id);
        assert_eq!(alone.logits, both[0].logits);
    }

    #[test]
    fn rejects_invalid() {
        let mut s = server();
        let p = PrecisionPolicy::reference();
        assert!(s.submit(InferenceRequest::new(1, vec![], p)).is_err());
        assert!(s.submit(InferenceRequest::new(1, vec![9999], p)).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let mut s = server();
        // τ=0 selects every nonzero-sensitivity product, so recomputation
        // is guaranteed even with near-uniform random-init attention.
        let p = PrecisionPolicy::lamp(3, 0.0, Rule::Strict);
        for id in 0..5 {
            s.submit(InferenceRequest::new(id, vec![1, 2, 3, 4, 5, 6], p)).unwrap();
        }
        let rs = s.drain().unwrap();
        assert_eq!(rs.len(), 5);
        let stats = s.stats();
        assert_eq!(stats.requests, 5);
        assert!(stats.batches >= 3); // batch=2 → 3 batches for 5 requests
        assert!(stats.recomputed > 0);
        assert!(stats.latency_mean_s >= 0.0);
        assert!(stats.throughput_tok_s > 0.0);
        assert_eq!(stats.total_tokens, 30);
    }

    #[test]
    fn generation_path_matches_solo_decode_and_tracks_stats() {
        use crate::coordinator::request::GenerateRequest;
        use crate::coordinator::scheduler::GenerateEvent;
        use crate::model::Decode;

        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(1);
        let weights = Weights::random(&cfg, &mut rng).unwrap();
        let oracle = NativeEngine::new(weights.clone());
        let mut s = Server::new(Box::new(NativeEngine::new(weights)), Duration::from_millis(1));

        let p = PrecisionPolicy::lamp(3, 0.05, Rule::Strict);
        s.submit_generate(GenerateRequest::new(1, vec![1, 2, 3], 6, p)).unwrap();
        s.submit_generate(
            GenerateRequest::new(2, vec![9, 8], 4, p)
                .with_decode(Decode::TopK { k: 4, temperature: 1.1 }),
        )
        .unwrap();
        assert_eq!(s.pending_generation(), 2);
        let events = s.serve_generation().unwrap();
        assert_eq!(s.pending_generation(), 0);
        let mut responses: Vec<_> = events
            .into_iter()
            .filter_map(|e| match e {
                GenerateEvent::Finished(r) => Some(r),
                GenerateEvent::Failed { id, error } => {
                    panic!("request {id} failed: {error}")
                }
                GenerateEvent::Token { .. } => None,
            })
            .collect();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 2);
        let (solo1, _) = oracle.generate(&[1, 2, 3], 6, &p, Decode::Greedy, 1).unwrap();
        let (solo2, _) = oracle
            .generate(&[9, 8], 4, &p, Decode::TopK { k: 4, temperature: 1.1 }, 2)
            .unwrap();
        assert_eq!(responses[0].tokens, solo1);
        assert_eq!(responses[1].tokens, solo2);

        let stats = s.stats();
        assert_eq!(stats.generate_requests, 2);
        assert_eq!(stats.generate_failed, 0);
        assert_eq!(stats.generated_tokens, 10);
        assert!(stats.recomputed > 0, "strict tau=0.05 must recompute");
        assert_eq!(stats.recompute_rate_by_policy.len(), 1);
        assert!(stats.mean_active_sessions > 0.0);
    }

    #[test]
    fn attention_only_backend_rejects_whole_model_policy_at_submit() {
        use crate::coordinator::engine::EngineOutput;
        use crate::coordinator::policy::SitePolicy;

        // An engine with the PJRT-style attention-only surface: the
        // capability gate must fire at submit(), so the incompatible
        // request is rejected alone and queued requests still drain.
        struct AttnOnly(ModelConfig, NativeEngine);
        impl crate::coordinator::Engine for AttnOnly {
            fn config(&self) -> &ModelConfig {
                &self.0
            }
            fn infer(
                &self,
                tokens: &[Vec<u32>],
                policy: &PrecisionPolicy,
                seed: i32,
            ) -> crate::error::Result<EngineOutput> {
                assert!(policy.is_attention_only(), "gate must fire before infer");
                self.1.infer(tokens, policy, seed)
            }
            fn validate_policy(&self, policy: &PrecisionPolicy) -> crate::error::Result<()> {
                policy.validate()?;
                if !policy.is_attention_only() {
                    return Err(crate::error::Error::runtime(
                        "attention site only".to_string(),
                    ));
                }
                Ok(())
            }
            fn backend(&self) -> &'static str {
                "attn-only"
            }
        }

        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(9);
        let native = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap());
        let mut s = Server::new(Box::new(AttnOnly(cfg, native)), Duration::from_millis(1));
        let ok = PrecisionPolicy::lamp(4, 0.1, Rule::Strict);
        let whole = ok.with_mlp(SitePolicy::lamp(7, 0.5, Rule::Strict));
        s.submit(InferenceRequest::new(1, vec![1, 2], ok)).unwrap();
        let err = s.submit(InferenceRequest::new(2, vec![3, 4], whole)).unwrap_err();
        assert!(err.to_string().contains("attention site only"), "{err}");
        s.submit(InferenceRequest::new(3, vec![5, 6], ok)).unwrap();
        // The valid requests are unaffected by the rejected one.
        let rs = s.drain().unwrap();
        assert_eq!(rs.len(), 2);
        // The native engine accepts whole-model policies at submit.
        let mut native_server = server();
        native_server
            .submit(InferenceRequest::new(4, vec![1, 2], whole))
            .unwrap();
        assert_eq!(native_server.drain().unwrap().len(), 1);
    }

    #[test]
    fn generation_reports_per_site_recompute_rates() {
        use crate::coordinator::policy::SitePolicy;
        use crate::coordinator::request::GenerateRequest;

        let mut s = server();
        let p = PrecisionPolicy::lamp(3, 0.05, Rule::Strict)
            .with_mlp(SitePolicy::lamp(3, 0.5, Rule::Strict))
            .with_norm(SitePolicy::lamp(3, 0.5, Rule::Strict))
            .with_sampler(SitePolicy::lamp(3, 0.0, Rule::Strict));
        s.submit_generate(GenerateRequest::new(1, vec![1, 2, 3], 5, p)).unwrap();
        let events = s.serve_generation().unwrap();
        assert!(!events.is_empty());
        let stats = s.stats();
        let rates = &stats.recompute_rate_by_site;
        assert_eq!(rates.len(), 4);
        let rate_of = |name: &str| {
            rates
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, r)| *r)
                .expect("site present")
        };
        assert!(rate_of("attention") > 0.0);
        assert!(rate_of("mlp") > 0.0);
        assert!(rate_of("norm") > 0.0);
        assert!(rate_of("sampler") > 0.0);
    }

    #[test]
    fn generation_surfaces_speculative_acceptance_stats() {
        use crate::coordinator::policy::{SitePolicy, SpecPolicy};
        use crate::coordinator::request::GenerateRequest;
        use crate::coordinator::scheduler::GenerateEvent;
        use crate::model::Decode;

        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(17);
        let weights = Weights::random(&cfg, &mut rng).unwrap();
        let oracle = NativeEngine::new(weights.clone());
        let mut s =
            Server::new(Box::new(NativeEngine::new(weights)), Duration::from_millis(1));

        let target = PrecisionPolicy::lamp(3, 0.1, Rule::Strict);
        let spec =
            target.with_spec(Some(SpecPolicy::whole_model(SitePolicy::uniform(2), 3)));
        s.submit_generate(GenerateRequest::new(1, vec![1, 2, 3], 8, spec)).unwrap();
        let events = s.serve_generation().unwrap();
        let tokens = events
            .iter()
            .find_map(|e| match e {
                GenerateEvent::Finished(r) => Some(r.tokens.clone()),
                GenerateEvent::Failed { id, error } => {
                    panic!("request {id} failed: {error}")
                }
                GenerateEvent::Token { .. } => None,
            })
            .expect("request finished");
        // Speculation is an execution strategy, not a precision change:
        // the stream matches plain decoding under the target policy.
        let (solo, _) =
            oracle.generate(&[1, 2, 3], 8, &target, Decode::Greedy, 1).unwrap();
        assert_eq!(tokens, solo);

        let stats = s.stats();
        assert!(stats.spec_rounds > 0, "8 tokens at k=3 must round-trip");
        assert!(stats.spec_drafted > 0);
        assert!(stats.spec_accepted <= stats.spec_drafted);
        assert!(stats.spec_acceptance_rate >= 0.0 && stats.spec_acceptance_rate <= 1.0);
        assert_eq!(
            stats.spec_accept_hist.iter().sum::<usize>(),
            stats.spec_rounds,
            "every round lands in exactly one histogram bucket"
        );
        assert!(stats.spec_mean_accept_len >= 1.0, "each round emits at least one token");
    }

    #[test]
    fn generation_submit_validates() {
        use crate::coordinator::request::GenerateRequest;
        let mut s = server();
        let p = PrecisionPolicy::reference();
        assert!(s.submit_generate(GenerateRequest::new(1, vec![], 4, p)).is_err());
        assert!(s.submit_generate(GenerateRequest::new(2, vec![9999], 4, p)).is_err());
        assert!(s
            .submit_generate(GenerateRequest::new(3, vec![1], 4, p).with_eos(4000))
            .is_err());
        assert!(s.serve_generation().unwrap().is_empty(), "nothing valid was queued");
    }

    #[test]
    fn stats_surface_active_weight_format_and_bf16_engine_serves() {
        use crate::coordinator::WeightFormat;
        let mut s = server();
        assert_eq!(s.stats().weight_format, "f32");
        // A bf16-storage engine reports its format and serves requests.
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(31);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())
            .with_weight_format(WeightFormat::Bf16)
            .unwrap();
        let mut s = Server::new(Box::new(engine), Duration::from_millis(1));
        s.submit(InferenceRequest::new(1, vec![1, 2, 3], PrecisionPolicy::reference()))
            .unwrap();
        assert_eq!(s.drain().unwrap().len(), 1);
        assert_eq!(s.stats().weight_format, "bf16");
    }

    #[test]
    fn storage_pinned_policy_gated_at_submit() {
        use crate::coordinator::{WeightFormat, WeightPrecision};
        // An f32 engine rejects a bf16-pinned request at submit; a bf16
        // engine accepts it and rejects the f32-pinned one.
        let mut f32_server = server();
        let pinned_bf16 = PrecisionPolicy::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::Bf16));
        let err = f32_server
            .submit(InferenceRequest::new(1, vec![1], pinned_bf16))
            .unwrap_err();
        assert!(err.to_string().contains("weight storage"), "{err}");

        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(33);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())
            .with_weight_format(WeightFormat::Bf16)
            .unwrap();
        let mut bf16_server = Server::new(Box::new(engine), Duration::from_millis(1));
        bf16_server
            .submit(InferenceRequest::new(2, vec![1], pinned_bf16))
            .unwrap();
        let pinned_f32 = PrecisionPolicy::reference()
            .with_weights(WeightPrecision::Exact(WeightFormat::F32));
        assert!(bf16_server
            .submit(InferenceRequest::new(3, vec![1], pinned_f32))
            .is_err());
        // Generation submits pass through the same gate.
        use crate::coordinator::request::GenerateRequest;
        assert!(f32_server
            .submit_generate(GenerateRequest::new(4, vec![1], 2, pinned_bf16))
            .is_err());
        bf16_server
            .submit_generate(GenerateRequest::new(5, vec![1], 2, pinned_bf16))
            .unwrap();
        assert_eq!(bf16_server.drain().unwrap().len(), 1);
        assert!(!bf16_server.serve_generation().unwrap().is_empty());
    }

    #[test]
    fn kv_pinned_policy_gated_at_submit_and_stats_surface_pool() {
        use crate::coordinator::request::GenerateRequest;
        use crate::coordinator::{KvCacheOptions, KvPrecision, WeightFormat};
        // Default engine (no shared pool): bf16-KV-pinned requests are
        // rejected at submit, and the stats report the f32 default.
        let mut s = server();
        let pinned = PrecisionPolicy::reference()
            .with_kv(KvPrecision::Exact(WeightFormat::Bf16));
        let err = s.submit(InferenceRequest::new(1, vec![1], pinned)).unwrap_err();
        assert!(err.to_string().contains("KV-cache storage"), "{err}");
        assert!(s
            .submit_generate(GenerateRequest::new(2, vec![1], 2, pinned))
            .is_err());
        assert_eq!(s.stats().kv_format, "f32");
        assert_eq!(s.stats().kv_blocks_capacity, 0);

        // A bf16-pool engine accepts the pinned request, serves it through
        // the paged scheduler, and surfaces pool occupancy in the stats.
        let cfg = ModelConfig::nano();
        let mut rng = Rng::new(41);
        let engine = NativeEngine::new(Weights::random(&cfg, &mut rng).unwrap())
            .with_kv_cache(KvCacheOptions::serving(&cfg, WeightFormat::Bf16, 4))
            .unwrap();
        let mut s = Server::new(Box::new(engine), Duration::from_millis(1));
        s.submit_generate(GenerateRequest::new(3, vec![1, 2, 3], 4, pinned)).unwrap();
        s.submit_generate(GenerateRequest::new(4, vec![1, 2, 3], 4, pinned)).unwrap();
        let events = s.serve_generation().unwrap();
        assert!(!events.is_empty());
        let stats = s.stats();
        assert_eq!(stats.generate_requests, 2);
        assert_eq!(stats.generate_failed, 0);
        assert_eq!(stats.kv_format, "bf16");
        assert!(stats.kv_blocks_capacity > 0);
        // The f32-pinned policy is rejected on the bf16-pool engine.
        let f32_pinned = PrecisionPolicy::reference()
            .with_kv(KvPrecision::Exact(WeightFormat::F32));
        assert!(s
            .submit_generate(GenerateRequest::new(5, vec![1], 2, f32_pinned))
            .is_err());
    }

    #[test]
    fn mixed_policies_still_all_served() {
        let mut s = server();
        s.submit(InferenceRequest::new(1, vec![1], PrecisionPolicy::uniform(4))).unwrap();
        s.submit(InferenceRequest::new(2, vec![2], PrecisionPolicy::uniform(7))).unwrap();
        s.submit(InferenceRequest::new(3, vec![3], PrecisionPolicy::reference())).unwrap();
        let rs = s.drain().unwrap();
        assert_eq!(rs.len(), 3);
        let stats = s.stats();
        assert_eq!(stats.batches, 3, "one batch per policy");
        assert_eq!(stats.padding_rows, 3);
    }
}
