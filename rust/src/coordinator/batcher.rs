//! Dynamic batcher: groups policy-compatible requests into fixed-shape
//! artifact batches.
//!
//! The compiled HLO has a baked batch dimension, so the batcher's job is:
//! (1) admit requests into per-policy queues, (2) cut a batch when either
//! the batch is full or the oldest request exceeds `max_wait`, (3) pad
//! partial batches by repeating the last real sequence (padding rows are
//! dropped from responses — causality makes them free of side effects on
//! real rows; they do inflate the recompute counters, which the server
//! subtracts out pro rata).

use super::policy::PrecisionPolicy;
use super::request::InferenceRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A batch cut from the queue, ready for an engine call.
#[derive(Debug)]
pub struct CutBatch {
    pub policy: PrecisionPolicy,
    /// The real requests riding in this batch (<= batch size).
    pub requests: Vec<(InferenceRequest, Instant)>,
    /// Number of padding rows appended.
    pub padding_rows: usize,
}

/// Per-policy FIFO queues with deadline-based cutting.
pub struct Batcher {
    batch_size: usize,
    max_wait: Duration,
    queues: Vec<(PrecisionPolicy, VecDeque<(InferenceRequest, Instant)>)>,
}

impl Batcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Self {
        assert!(batch_size >= 1);
        Batcher { batch_size, max_wait, queues: Vec::new() }
    }

    /// Admit a request.
    pub fn push(&mut self, req: InferenceRequest) {
        let now = Instant::now();
        for (policy, q) in &mut self.queues {
            if policy.batch_compatible(&req.policy) {
                q.push_back((req, now));
                return;
            }
        }
        let mut q = VecDeque::new();
        let policy = req.policy;
        q.push_back((req, now));
        self.queues.push((policy, q));
    }

    /// Number of queued requests across all policies.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Cut the next batch, if any queue is full or has an expired head.
    /// `force` cuts any non-empty queue regardless of deadlines (used at
    /// shutdown / drain).
    ///
    /// Preference order: full queues first (throughput), then queues with
    /// an expired head — and *within* each class, the queue whose head is
    /// **oldest**. Registration order is deliberately ignored: a queue's
    /// position in `self.queues` tracks first-push-since-empty, so a hot
    /// first-registered policy whose head is perpetually expired would
    /// otherwise starve an older expired request parked behind a partial
    /// cut in a later-registered queue.
    pub fn cut(&mut self, force: bool) -> Option<CutBatch> {
        let now = Instant::now();
        let mut pick: Option<(usize, Instant)> = None;
        let consider = |i: usize, t0: Instant, pick: &mut Option<(usize, Instant)>| {
            let older = match *pick {
                None => true,
                Some((_, t)) => t0 < t,
            };
            if older {
                *pick = Some((i, t0));
            }
        };
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if q.len() >= self.batch_size {
                let (_, t0) = q.front().expect("full queue is non-empty");
                consider(i, *t0, &mut pick);
            }
        }
        if pick.is_none() {
            for (i, (_, q)) in self.queues.iter().enumerate() {
                if let Some((_, t0)) = q.front() {
                    if force || now.duration_since(*t0) >= self.max_wait {
                        consider(i, *t0, &mut pick);
                    }
                }
            }
        }
        let (i, _) = pick?;
        let (policy, q) = &mut self.queues[i];
        let take = q.len().min(self.batch_size);
        let requests: Vec<_> = q.drain(..take).collect();
        let padding_rows = self.batch_size - requests.len();
        let batch = CutBatch { policy: *policy, requests, padding_rows };
        if q.is_empty() {
            self.queues.remove(i);
        }
        Some(batch)
    }

    /// Assemble the padded token matrix for an engine call: real padded
    /// sequences first, then repeats of the last real sequence.
    pub fn assemble_tokens(batch: &CutBatch, seq: usize) -> Vec<Vec<u32>> {
        let mut rows: Vec<Vec<u32>> =
            batch.requests.iter().map(|(r, _)| r.padded(seq)).collect();
        let filler = rows.last().expect("non-empty batch").clone();
        for _ in 0..batch.padding_rows {
            rows.push(filler.clone());
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, policy: PrecisionPolicy) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2, 3], policy)
    }

    #[test]
    fn full_batch_cuts_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        b.push(req(1, p));
        assert!(b.cut(false).is_none(), "half batch must wait");
        b.push(req(2, p));
        let cut = b.cut(false).expect("full batch");
        assert_eq!(cut.requests.len(), 2);
        assert_eq!(cut.padding_rows, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn incompatible_policies_do_not_mix() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, PrecisionPolicy::uniform(4)));
        b.push(req(2, PrecisionPolicy::uniform(7)));
        assert!(b.cut(false).is_none(), "different mus must not share a batch");
        assert_eq!(b.pending(), 2);
        let cut = b.cut(true).unwrap();
        assert_eq!(cut.requests.len(), 1);
        assert_eq!(cut.padding_rows, 1);
    }

    #[test]
    fn deadline_cuts_partial_batch() {
        let mut b = Batcher::new(4, Duration::from_millis(1));
        b.push(req(1, PrecisionPolicy::uniform(4)));
        std::thread::sleep(Duration::from_millis(5));
        let cut = b.cut(false).expect("expired head");
        assert_eq!(cut.requests.len(), 1);
        assert_eq!(cut.padding_rows, 3);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        for id in [10, 20, 30] {
            b.push(req(id, p));
        }
        let cut = b.cut(false).unwrap();
        let ids: Vec<u64> = cut.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn assemble_pads_with_last_sequence() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        b.push(InferenceRequest::new(1, vec![7, 8], p));
        let cut = b.cut(true).unwrap();
        let rows = Batcher::assemble_tokens(&cut, 4);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![7, 8, 8, 8]);
        assert_eq!(rows[1], rows[0]);
        assert_eq!(rows[2], rows[0]);
    }

    #[test]
    fn deadline_cut_serves_oldest_head_across_policies() {
        // Regression: the deadline scan used to pick the first-registered
        // queue with an expired head. Arrange an *older* expired request in
        // a later-registered queue (possible after a partial cut leaves
        // newer items at the front of the earlier queue) and check it wins.
        let mut b = Batcher::new(2, Duration::from_millis(30));
        let p0 = PrecisionPolicy::uniform(4);
        let p1 = PrecisionPolicy::uniform(7);
        b.push(req(1, p0)); // registers p0 first
        b.push(req(2, p1)); // p1 second; req 2 will become the oldest head
        std::thread::sleep(Duration::from_millis(2)); // req 2 strictly older than req 4
        b.push(req(3, p0));
        b.push(req(4, p0)); // p0 now full with {1, 3, 4}
        let cut = b.cut(false).expect("full p0 queue");
        let ids: Vec<u64> = cut.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![1, 3], "full cut takes the FIFO prefix");
        // queues: p0 = {4} (newer head), p1 = {2} (older head).
        std::thread::sleep(Duration::from_millis(40));
        let cut = b.cut(false).expect("expired heads");
        let ids: Vec<u64> = cut.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(
            ids,
            vec![2],
            "expired cut must serve the oldest head, not the first-registered queue"
        );
        let cut = b.cut(false).expect("remaining expired head");
        let ids: Vec<u64> = cut.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![4]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn force_drain_follows_global_fifo() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        b.push(req(1, PrecisionPolicy::uniform(4)));
        std::thread::sleep(Duration::from_millis(2));
        b.push(req(2, PrecisionPolicy::uniform(7)));
        // Empty the first-registered queue, then refill it later.
        let cut = b.cut(true).unwrap();
        assert_eq!(cut.requests[0].0.id, 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push(req(3, PrecisionPolicy::uniform(4)));
        // Force drain: id 2 is older than id 3 even though its queue now
        // registered first anyway; the pick is by head age, not position.
        let cut = b.cut(true).unwrap();
        assert_eq!(cut.requests[0].0.id, 2);
        let cut = b.cut(true).unwrap();
        assert_eq!(cut.requests[0].0.id, 3);
        assert!(b.cut(true).is_none());
    }

    #[test]
    fn oversize_queue_cuts_batch_size() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        for id in 0..5 {
            b.push(req(id, p));
        }
        let cut = b.cut(false).unwrap();
        assert_eq!(cut.requests.len(), 2);
        assert_eq!(b.pending(), 3);
    }
}
