//! Dynamic batcher: groups policy-compatible requests into fixed-shape
//! artifact batches.
//!
//! The compiled HLO has a baked batch dimension, so the batcher's job is:
//! (1) admit requests into per-policy queues, (2) cut a batch when either
//! the batch is full or the oldest request exceeds `max_wait`, (3) pad
//! partial batches by repeating the last real sequence (padding rows are
//! dropped from responses — causality makes them free of side effects on
//! real rows; they do inflate the recompute counters, which the server
//! subtracts out pro rata).

use super::policy::PrecisionPolicy;
use super::request::InferenceRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A batch cut from the queue, ready for an engine call.
#[derive(Debug)]
pub struct CutBatch {
    pub policy: PrecisionPolicy,
    /// The real requests riding in this batch (<= batch size).
    pub requests: Vec<(InferenceRequest, Instant)>,
    /// Number of padding rows appended.
    pub padding_rows: usize,
}

/// Per-policy FIFO queues with deadline-based cutting.
pub struct Batcher {
    batch_size: usize,
    max_wait: Duration,
    queues: Vec<(PrecisionPolicy, VecDeque<(InferenceRequest, Instant)>)>,
}

impl Batcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Self {
        assert!(batch_size >= 1);
        Batcher { batch_size, max_wait, queues: Vec::new() }
    }

    /// Admit a request.
    pub fn push(&mut self, req: InferenceRequest) {
        let now = Instant::now();
        for (policy, q) in &mut self.queues {
            if policy.batch_compatible(&req.policy) {
                q.push_back((req, now));
                return;
            }
        }
        let mut q = VecDeque::new();
        let policy = req.policy;
        q.push_back((req, now));
        self.queues.push((policy, q));
    }

    /// Number of queued requests across all policies.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Cut the next batch, if any queue is full or has an expired head.
    /// `force` cuts any non-empty queue regardless of deadlines (used at
    /// shutdown / drain).
    pub fn cut(&mut self, force: bool) -> Option<CutBatch> {
        let now = Instant::now();
        // Prefer full queues, then expired heads.
        let mut pick: Option<usize> = None;
        for (i, (_, q)) in self.queues.iter().enumerate() {
            if q.len() >= self.batch_size {
                pick = Some(i);
                break;
            }
        }
        if pick.is_none() {
            for (i, (_, q)) in self.queues.iter().enumerate() {
                if let Some((_, t0)) = q.front() {
                    if force || now.duration_since(*t0) >= self.max_wait {
                        pick = Some(i);
                        break;
                    }
                }
            }
        }
        let i = pick?;
        let (policy, q) = &mut self.queues[i];
        let take = q.len().min(self.batch_size);
        let requests: Vec<_> = q.drain(..take).collect();
        let padding_rows = self.batch_size - requests.len();
        let batch = CutBatch { policy: *policy, requests, padding_rows };
        if q.is_empty() {
            self.queues.remove(i);
        }
        Some(batch)
    }

    /// Assemble the padded token matrix for an engine call: real padded
    /// sequences first, then repeats of the last real sequence.
    pub fn assemble_tokens(batch: &CutBatch, seq: usize) -> Vec<Vec<u32>> {
        let mut rows: Vec<Vec<u32>> =
            batch.requests.iter().map(|(r, _)| r.padded(seq)).collect();
        let filler = rows.last().expect("non-empty batch").clone();
        for _ in 0..batch.padding_rows {
            rows.push(filler.clone());
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, policy: PrecisionPolicy) -> InferenceRequest {
        InferenceRequest::new(id, vec![1, 2, 3], policy)
    }

    #[test]
    fn full_batch_cuts_immediately() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        b.push(req(1, p));
        assert!(b.cut(false).is_none(), "half batch must wait");
        b.push(req(2, p));
        let cut = b.cut(false).expect("full batch");
        assert_eq!(cut.requests.len(), 2);
        assert_eq!(cut.padding_rows, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn incompatible_policies_do_not_mix() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(req(1, PrecisionPolicy::uniform(4)));
        b.push(req(2, PrecisionPolicy::uniform(7)));
        assert!(b.cut(false).is_none(), "different mus must not share a batch");
        assert_eq!(b.pending(), 2);
        let cut = b.cut(true).unwrap();
        assert_eq!(cut.requests.len(), 1);
        assert_eq!(cut.padding_rows, 1);
    }

    #[test]
    fn deadline_cuts_partial_batch() {
        let mut b = Batcher::new(4, Duration::from_millis(1));
        b.push(req(1, PrecisionPolicy::uniform(4)));
        std::thread::sleep(Duration::from_millis(5));
        let cut = b.cut(false).expect("expired head");
        assert_eq!(cut.requests.len(), 1);
        assert_eq!(cut.padding_rows, 3);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        for id in [10, 20, 30] {
            b.push(req(id, p));
        }
        let cut = b.cut(false).unwrap();
        let ids: Vec<u64> = cut.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn assemble_pads_with_last_sequence() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        b.push(InferenceRequest::new(1, vec![7, 8], p));
        let cut = b.cut(true).unwrap();
        let rows = Batcher::assemble_tokens(&cut, 4);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![7, 8, 8, 8]);
        assert_eq!(rows[1], rows[0]);
        assert_eq!(rows[2], rows[0]);
    }

    #[test]
    fn oversize_queue_cuts_batch_size() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        let p = PrecisionPolicy::uniform(4);
        for id in 0..5 {
            b.push(req(id, p));
        }
        let cut = b.cut(false).unwrap();
        assert_eq!(cut.requests.len(), 2);
        assert_eq!(b.pending(), 3);
    }
}
