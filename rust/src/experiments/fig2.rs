//! Figure 2: KL divergence, flip rate and recomputation rate as functions
//! of the threshold τ for several accumulation widths μ (strict LAMP,
//! xl-sim, web panel). Headline claim (§4.3): consistent 12×/83×/385× KL
//! reductions at 0.3%/1.6%/7.6% recomputation for small μ.

use super::common::{load_weights, tau_grid, EvalOptions, EvalPanel};
use crate::benchkit::{fnum, Table};
use crate::coordinator::{PrecisionPolicy, Rule};
use crate::data::Domain;
use crate::error::Result;

pub fn mu_grid(quick: bool) -> Vec<u32> {
    if quick {
        vec![4]
    } else {
        vec![2, 4, 7, 10]
    }
}

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, opts)?;
    let mut t = Table::new(
        "Fig 2 — strict LAMP sweep on xl-sim/web: metrics vs tau per mu",
        &["mu", "tau", "KL", "KL(uniform)/KL", "flip%", "recompute%"],
    );
    for mu in mu_grid(opts.quick) {
        let uni = panel.evaluate(&PrecisionPolicy::uniform(mu), 0)?;
        t.row(vec![
            mu.to_string(),
            "inf".into(),
            fnum(uni.kl),
            "1.0".into(),
            format!("{:.3}", 100.0 * uni.flip),
            "0".into(),
        ]);
        for tau in tau_grid(Rule::Strict, opts.quick) {
            let r = panel.evaluate(&PrecisionPolicy::lamp(mu, tau, Rule::Strict), 0)?;
            t.row(vec![
                mu.to_string(),
                format!("{tau}"),
                fnum(r.kl),
                fnum(uni.kl / r.kl.max(1e-300)),
                format!("{:.3}", 100.0 * r.flip),
                format!("{:.3}", 100.0 * r.rate),
            ]);
        }
    }
    Ok(vec![t])
}
