//! Figure 6 (App. C.3): Pareto boundaries on direct vs randomly permuted
//! token sequences (word order destroyed, unigram preserved), μ=4, xl-sim.
//! Expected shape: KL boundaries overlap ("input-agnostic"); flip-rate
//! boundary may shift slightly upward for permuted tokens.

use super::common::{load_weights, EvalOptions, EvalPanel, TABLE_SEED};
use super::fig3::sweep_rule;
use crate::benchkit::{fnum, Table};
use crate::coordinator::Rule;
use crate::data::{Dataset, Domain};
use crate::error::Result;
use crate::metrics::pareto_front;

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let cfg = weights.config.clone();
    let seq_len = opts.seq_len.min(cfg.seq);
    let base = Dataset::generate(
        Domain::Web,
        cfg.vocab,
        opts.num_seqs,
        seq_len,
        TABLE_SEED,
        opts.stream_seed,
    );
    let mut t = Table::new(
        "Fig 6 — strict LAMP Pareto (mu=4): direct vs permuted tokens",
        &["tokens", "tau", "recompute%", "KL", "flip%"],
    );
    for (label, dataset) in [
        ("direct", base.clone()),
        ("permuted", base.permuted(opts.stream_seed ^ 0xBEEF)),
    ] {
        let panel = EvalPanel::with_dataset(weights.clone(), dataset, opts.workers)?;
        let (kl_pts, flip_pts) = sweep_rule(&panel, 4, Rule::Strict, opts.quick)?;
        for p in pareto_front(&kl_pts) {
            let f = flip_pts
                .iter()
                .find(|q| q.tau == p.tau)
                .map(|q| q.metric)
                .unwrap_or(f64::NAN);
            t.row(vec![
                label.into(),
                format!("{:.3}", p.tau),
                format!("{:.3}", 100.0 * p.rate),
                fnum(p.metric),
                format!("{:.3}", 100.0 * f),
            ]);
        }
    }
    Ok(vec![t])
}
