//! Figure 1: KL divergence vs mantissa bits μ for uniform PS(μ)
//! accumulation, LAMP (τ=0.1, ~1% recomputation), and the random baseline
//! at the same threshold.
//!
//! Routed through the bundled `fig1` trial manifest: the series in the
//! rendered table are exactly the rows `lamp trials run fig1` pins as a
//! byte-exact canonical artifact (`trials::figure`), so figure and
//! artifact can never disagree. Quick mode trims the sweep and panel to
//! the caller's smoke scale; a full run replays the manifest verbatim.

use super::common::EvalOptions;
use crate::benchkit::{fnum, Table};
use crate::error::Result;
use crate::trials::{self, figure, TrialManifest};

/// The paper's Fig. 1 setting: τ = 0.1 ("corresponding to a threshold
/// τ = 0.1 in Sections 2–3"), strict rule.
pub const FIG1_TAU: f32 = 0.1;

pub fn mu_grid(quick: bool) -> Vec<u32> {
    if quick {
        vec![4, 7, 10]
    } else {
        vec![2, 3, 4, 5, 6, 7, 8, 10, 12, 16, 20, 23]
    }
}

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let mut manifest =
        TrialManifest::parse(trials::builtin("fig1").expect("bundled fig1 trial"))?;
    let mut fig = manifest.figure.clone().expect("fig1 manifest is a figure trial");
    manifest.workers = opts.workers;
    if opts.quick {
        fig.mu_grid = mu_grid(true);
        fig.num_seqs = fig.num_seqs.min(opts.num_seqs.max(1));
        fig.seq_len = fig.seq_len.min(opts.seq_len.max(2));
    }
    let rows = figure::rows(&manifest, &fig)?;
    let mut t = Table::new(
        &format!(
            "Fig 1 — {} on {} panel: KL vs mu (tau={}, strict) [trial fig1]",
            manifest.model.name,
            fig.domain.name(),
            fig.tau
        ),
        &["mu", "KL(uniform)", "KL(LAMP)", "KL(random)", "recompute%"],
    );
    for r in &rows {
        let rate = if r.causal_total == 0 {
            0.0
        } else {
            r.recomputed as f64 / r.causal_total as f64
        };
        t.row(vec![
            r.mu.to_string(),
            fnum(r.kl_uniform),
            fnum(r.kl_lamp),
            fnum(r.kl_random),
            format!("{:.3}", 100.0 * rate),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_small() {
        assert_eq!(mu_grid(true).len(), 3);
        assert!(mu_grid(false).contains(&7));
        assert!(mu_grid(false).contains(&23));
    }

    #[test]
    fn bundled_trial_pins_the_paper_setting() {
        let m = TrialManifest::parse(trials::builtin("fig1").unwrap()).unwrap();
        let fig = m.figure.expect("figure trial");
        assert_eq!(fig.tau, FIG1_TAU, "manifest must pin the paper's tau");
        for mu in &fig.mu_grid {
            assert!(mu_grid(false).contains(mu), "manifest grid must be a paper-grid subset");
        }
    }
}
