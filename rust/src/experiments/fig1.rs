//! Figure 1: KL divergence vs mantissa bits μ for uniform PS(μ)
//! accumulation, LAMP (τ=0.1, ~1% recomputation), and the random baseline
//! at the same recomputation count. GPT-2 XL → xl-sim, OpenWebText → web.

use super::common::{load_weights, EvalOptions, EvalPanel};
use crate::benchkit::{fnum, Table};
use crate::coordinator::{PrecisionPolicy, Rule};
use crate::data::Domain;
use crate::error::Result;

/// The paper's Fig. 1 setting: τ = 0.1 ("corresponding to a threshold
/// τ = 0.1 in Sections 2–3"), strict rule.
pub const FIG1_TAU: f32 = 0.1;

pub fn mu_grid(quick: bool) -> Vec<u32> {
    if quick {
        vec![4, 7, 10]
    } else {
        vec![2, 3, 4, 5, 6, 7, 8, 10, 12, 16, 20, 23]
    }
}

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, opts)?;
    let mut t = Table::new(
        "Fig 1 — GPT-2 xl-sim on web panel: KL vs mu (tau=0.1, strict)",
        &["mu", "KL(uniform)", "KL(LAMP)", "KL(random)", "recompute%"],
    );
    for mu in mu_grid(opts.quick) {
        let uni = panel.evaluate(&PrecisionPolicy::uniform(mu), 0)?;
        let lamp = panel.evaluate(&PrecisionPolicy::lamp(mu, FIG1_TAU, Rule::Strict), 0)?;
        let rand = panel.evaluate(&PrecisionPolicy::lamp(mu, FIG1_TAU, Rule::Random), 0)?;
        t.row(vec![
            mu.to_string(),
            fnum(uni.kl),
            fnum(lamp.kl),
            fnum(rand.kl),
            format!("{:.3}", 100.0 * lamp.rate),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_grid_small() {
        assert_eq!(mu_grid(true).len(), 3);
        assert!(mu_grid(false).contains(&7));
        assert!(mu_grid(false).contains(&23));
    }
}
