//! Experiment drivers: one module per paper figure/table.
//!
//! Every driver regenerates the corresponding plot's series as an aligned
//! text table (the same rows/series the paper reports), using the native
//! engine for (μ, τ) sweeps — see DESIGN.md §Engines — over the synthetic
//! evaluation panels of `crate::data`. `cargo bench --bench figN` and
//! `lamp exp figN` both route here.

pub mod ablations;
pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table1;

pub use common::{EvalOptions, EvalPanel, EvalResult};

use crate::benchkit::Table;
use crate::error::{Error, Result};

/// Run a named experiment; returns its result tables.
pub fn run(name: &str, opts: &EvalOptions) -> Result<Vec<Table>> {
    match name {
        "fig1" => fig1::run(opts),
        "fig2" => fig2::run(opts),
        "fig3" => fig3::run(opts),
        "fig4" => fig4::run(opts),
        "fig5" => fig5::run(opts),
        "fig6" => fig6::run(opts),
        "fig7" => fig7::run(opts),
        "table1" => table1::run(opts),
        "appendix_b" => appendix_b(),
        "ablation_rounding" => ablations::rounding_modes(),
        "ablation_recompute" => ablations::recompute_algorithms(),
        "ablation_plan_sites" => ablations::plan_sites(),
        "ablation_weight_storage" => ablations::weight_storage(),
        "ablation_kv_storage" => ablations::kv_storage(),
        "ablation_speculative" => ablations::speculative(),
        other => Err(Error::config(format!(
            "unknown experiment {other:?} (fig1..fig7|table1|appendix_b|ablation_rounding|ablation_recompute|ablation_plan_sites|ablation_weight_storage|ablation_kv_storage|ablation_speculative)"
        ))),
    }
}

/// All experiment names in paper order (+ ablations).
pub fn all_names() -> &'static [&'static str] {
    &[
        "fig1",
        "fig2",
        "fig3",
        "fig4",
        "fig5",
        "fig6",
        "fig7",
        "table1",
        "appendix_b",
        "ablation_rounding",
        "ablation_recompute",
        "ablation_plan_sites",
        "ablation_weight_storage",
        "ablation_kv_storage",
        "ablation_speculative",
    ]
}

/// Appendix B verification: the counterexample families, as a table.
fn appendix_b() -> Result<Vec<Table>> {
    use crate::lamp::counterexamples::{kappa_c_softmax, PropB1, PropB2};
    let mut t = Table::new(
        "Appendix B — greedy heuristics fail componentwise softmax LAMP",
        &["family", "n0", "s", "tau", "kappa(optimal)", "kappa(greedy)", "greedy ok?"],
    );
    for (n0, s) in [(3usize, 2usize), (5, 3), (8, 4)] {
        let b1 = PropB1::new(n0, s, 4.0);
        let ko = kappa_c_softmax(&b1.y, &b1.optimal_mask());
        let kg = kappa_c_softmax(&b1.y, &b1.greedy_mask());
        t.row(vec![
            "B.1".into(),
            n0.to_string(),
            s.to_string(),
            format!("{:.4}", b1.tau),
            format!("{ko:.4}"),
            format!("{kg:.4}"),
            (kg <= b1.tau).to_string(),
        ]);
        let b2 = PropB2::new(n0.max(2), s);
        let ko = kappa_c_softmax(&b2.y, &b2.optimal_mask());
        let kg = kappa_c_softmax(&b2.y, &b2.greedy_mask());
        t.row(vec![
            "B.2".into(),
            n0.to_string(),
            s.to_string(),
            format!("{:.4}", b2.tau),
            format!("{ko:.4}"),
            format!("{kg:.4}"),
            (kg <= b2.tau).to_string(),
        ]);
    }
    Ok(vec![t])
}
