//! Figure 4 (App. C.1): Pareto boundaries of strict LAMP across datasets
//! (OpenWebText/CodeParrot/ArXiv → web/code/arxiv panels), μ=4, xl-sim.
//! Expected shape: near-identical boundaries — LAMP is input-agnostic.

use super::common::{load_weights, EvalOptions, EvalPanel};
use super::fig3::sweep_rule;
use crate::benchkit::{fnum, Table};
use crate::coordinator::Rule;
use crate::data::Domain;
use crate::error::Result;
use crate::metrics::pareto_front;

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let mut t = Table::new(
        "Fig 4 — strict LAMP Pareto (mu=4) across datasets",
        &["dataset", "tau", "recompute%", "KL", "flip%"],
    );
    for domain in [Domain::Web, Domain::Code, Domain::Arxiv] {
        let panel = EvalPanel::build(weights.clone(), domain, opts)?;
        let (kl_pts, flip_pts) = sweep_rule(&panel, 4, Rule::Strict, opts.quick)?;
        for p in pareto_front(&kl_pts) {
            let f = flip_pts
                .iter()
                .find(|q| q.tau == p.tau)
                .map(|q| q.metric)
                .unwrap_or(f64::NAN);
            t.row(vec![
                domain.name().into(),
                format!("{:.3}", p.tau),
                format!("{:.3}", 100.0 * p.rate),
                fnum(p.metric),
                format!("{:.3}", 100.0 * f),
            ]);
        }
        drop(panel);
    }
    Ok(vec![t])
}
