//! Shared experiment machinery: evaluation panels, reference caching,
//! (μ, τ) sweeps, Pareto extraction.

use crate::coordinator::{Engine, NativeEngine, PrecisionPolicy, Rule};
use crate::data::{Dataset, Domain};
use crate::error::Result;
use crate::linalg::Matrix;
use crate::metrics::{flip_rate, mean_kl_from_logits, ParetoPoint};
use crate::model::{ModelConfig, Weights};
use crate::runtime::ArtifactStore;
use crate::util::{Rng, ThreadPool};
use std::sync::Arc;

/// The project-wide Markov-table seed shared with `python/compile/data.py`.
pub const TABLE_SEED: u64 = 7;

/// Options controlling experiment scale (CLI-overridable).
#[derive(Debug, Clone)]
pub struct EvalOptions {
    /// Evaluation sequences per panel.
    pub num_seqs: usize,
    /// Tokens per sequence (≤ model seq).
    pub seq_len: usize,
    /// Held-out stream seed.
    pub stream_seed: u64,
    /// Parallel workers.
    pub workers: usize,
    /// Artifact directory (used when trained weights are available).
    pub artifacts: Option<String>,
    /// Quick mode: shrink sweeps for smoke testing.
    pub quick: bool,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            num_seqs: 6,
            seq_len: 64,
            stream_seed: 42,
            workers: 8,
            artifacts: Some("artifacts".to_string()),
            quick: false,
        }
    }
}

/// Result of evaluating one policy on one panel.
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub kl: f64,
    pub flip: f64,
    /// Recomputation rate over the causal mask.
    pub rate: f64,
    pub recomputed: usize,
    pub causal_total: usize,
}

impl EvalResult {
    pub fn pareto_kl(&self, tau: f64) -> ParetoPoint {
        ParetoPoint { rate: self.rate, metric: self.kl, tau }
    }
    pub fn pareto_flip(&self, tau: f64) -> ParetoPoint {
        ParetoPoint { rate: self.rate, metric: self.flip, tau }
    }
}

/// An evaluation panel: a model + dataset + cached FP32 reference logits.
pub struct EvalPanel {
    pub weights: Arc<Weights>,
    pub dataset: Dataset,
    pub reference: Vec<Matrix>,
    pool: Arc<ThreadPool>,
}

/// Load trained weights from artifacts when present, else deterministic
/// random weights (clearly logged — random weights still exhibit the LAMP
/// numerics, with flatter attention).
pub fn load_weights(config_name: &str, opts: &EvalOptions) -> Result<Arc<Weights>> {
    if let Some(dir) = &opts.artifacts {
        if let Ok(store) = ArtifactStore::open(dir) {
            if let Ok(w) = store.weights(config_name) {
                return Ok(Arc::new(w));
            }
        }
    }
    crate::log_warn!(
        "experiments",
        "trained weights for {config_name:?} not found — using random init (run `make artifacts`)"
    );
    let cfg = ModelConfig::by_name(config_name)?;
    let mut rng = Rng::new(0xA11CE ^ config_name.len() as u64);
    Ok(Arc::new(Weights::random(&cfg, &mut rng)?))
}

impl EvalPanel {
    /// Build a panel: generate the dataset and compute reference logits.
    pub fn build(
        weights: Arc<Weights>,
        domain: Domain,
        opts: &EvalOptions,
    ) -> Result<Self> {
        let cfg = &weights.config;
        let seq_len = opts.seq_len.min(cfg.seq);
        let dataset = Dataset::generate(
            domain,
            cfg.vocab,
            opts.num_seqs,
            seq_len,
            TABLE_SEED,
            opts.stream_seed,
        );
        let pool = Arc::new(ThreadPool::with_cpus(opts.workers));
        let panel = EvalPanel {
            reference: Vec::new(),
            weights,
            dataset,
            pool,
        };
        let reference = panel.logits(&PrecisionPolicy::reference(), 0)?;
        Ok(EvalPanel { reference, ..panel })
    }

    /// Build a panel from an explicit dataset (permutation experiments).
    pub fn with_dataset(
        weights: Arc<Weights>,
        dataset: Dataset,
        workers: usize,
    ) -> Result<Self> {
        let pool = Arc::new(ThreadPool::with_cpus(workers));
        let panel = EvalPanel { reference: Vec::new(), weights, dataset, pool };
        let reference = panel.logits(&PrecisionPolicy::reference(), 0)?;
        Ok(EvalPanel { reference, ..panel })
    }

    /// Logits for every sequence under `policy` (parallel across sequences).
    pub fn logits(&self, policy: &PrecisionPolicy, seed: i32) -> Result<Vec<Matrix>> {
        let engine = NativeEngine::new((*self.weights).clone());
        let engine = Arc::new(engine);
        let jobs: Vec<(usize, Vec<u32>)> = self
            .dataset
            .sequences
            .iter()
            .cloned()
            .enumerate()
            .collect();
        let policy = *policy;
        let results = self.pool.map(jobs, move |(i, seq)| {
            let out = engine.infer(&[seq], &policy, seed.wrapping_add(i as i32));
            out.map(|o| (o.logits.into_iter().next().unwrap(), o.stats))
        });
        results
            .into_iter()
            .map(|r| r.map(|(l, _)| l))
            .collect::<Result<Vec<_>>>()
    }

    /// Evaluate one policy: KL + flip rate vs the cached reference, plus
    /// the recomputation rate.
    pub fn evaluate(&self, policy: &PrecisionPolicy, seed: i32) -> Result<EvalResult> {
        let engine = Arc::new(NativeEngine::new((*self.weights).clone()));
        let jobs: Vec<(usize, Vec<u32>)> = self
            .dataset
            .sequences
            .iter()
            .cloned()
            .enumerate()
            .collect();
        let policy_c = *policy;
        let results = self.pool.map(jobs, move |(i, seq)| {
            engine
                .infer(&[seq], &policy_c, seed.wrapping_add(i as i32))
                .map(|o| (i, o))
        });
        let mut kl = 0.0;
        let mut flip = 0.0;
        let mut recomputed = 0usize;
        let mut causal = 0usize;
        let n = self.dataset.len();
        for r in results {
            let (i, out) = r?;
            kl += mean_kl_from_logits(&self.reference[i], &out.logits[0]);
            flip += flip_rate(&self.reference[i], &out.logits[0]);
            recomputed += out.stats.recomputed;
            causal += out.stats.causal_total;
        }
        Ok(EvalResult {
            kl: kl / n as f64,
            flip: flip / n as f64,
            rate: if causal == 0 { 0.0 } else { recomputed as f64 / causal as f64 },
            recomputed,
            causal_total: causal,
        })
    }

    /// Perplexity of the model's own predictions on this panel under
    /// `policy` (App. C.5 metric; no reference needed).
    pub fn perplexity(&self, policy: &PrecisionPolicy, seed: i32) -> Result<(f64, f64)> {
        use crate::model::loss::next_token_nll;
        let logits = self.logits(policy, seed)?;
        let mut nlls = Vec::new();
        for (i, l) in logits.iter().enumerate() {
            nlls.extend(next_token_nll(l, &self.dataset.sequences[i]));
        }
        let engine = NativeEngine::new((*self.weights).clone());
        // One representative pass for the recomputation rate.
        let out = engine.infer(
            &[self.dataset.sequences[0].clone()],
            policy,
            seed,
        )?;
        Ok((crate::model::loss::perplexity(&nlls), out.stats.rate()))
    }
}

/// The τ sweep grids used across figures (quick mode trims them).
pub fn tau_grid(rule: Rule, quick: bool) -> Vec<f32> {
    let full: Vec<f32> = match rule {
        // Strict thresholds are absolute sensitivities.
        Rule::Strict | Rule::Random => vec![1.0, 0.5, 0.2, 0.1, 0.05, 0.02, 0.01],
        // Relaxed thresholds are relative, in [0, 1).
        Rule::Relaxed | Rule::RelaxedLengthNorm => {
            vec![0.9, 0.6, 0.3, 0.1, 0.03, 0.01]
        }
    };
    if quick {
        full.into_iter().step_by(3).collect()
    } else {
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> EvalOptions {
        EvalOptions {
            num_seqs: 2,
            seq_len: 12,
            stream_seed: 1,
            workers: 2,
            artifacts: None,
            quick: true,
        }
    }

    fn nano_weights() -> Arc<Weights> {
        let mut rng = Rng::new(3);
        Arc::new(Weights::random(&ModelConfig::nano(), &mut rng).unwrap())
    }

    #[test]
    fn panel_reference_is_zero_error() {
        let panel = EvalPanel::build(nano_weights(), Domain::Web, &opts()).unwrap();
        let r = panel.evaluate(&PrecisionPolicy::reference(), 0).unwrap();
        assert!(r.kl < 1e-12);
        assert_eq!(r.flip, 0.0);
        assert_eq!(r.rate, 0.0);
    }

    #[test]
    fn lamp_beats_uniform_on_panel() {
        let panel = EvalPanel::build(nano_weights(), Domain::Web, &opts()).unwrap();
        let uni = panel.evaluate(&PrecisionPolicy::uniform(2), 0).unwrap();
        let lamp = panel
            .evaluate(&PrecisionPolicy::lamp(2, 0.01, Rule::Strict), 0)
            .unwrap();
        assert!(uni.kl > 0.0);
        assert!(lamp.rate > 0.0);
        assert!(lamp.kl < uni.kl, "lamp={} uniform={}", lamp.kl, uni.kl);
    }

    #[test]
    fn perplexity_finite() {
        let panel = EvalPanel::build(nano_weights(), Domain::Math, &opts()).unwrap();
        let (ppl, rate) = panel
            .perplexity(&PrecisionPolicy::uniform(4), 0)
            .unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
        assert_eq!(rate, 0.0);
    }

    #[test]
    fn tau_grids_nonempty_and_sorted_desc() {
        for rule in [Rule::Strict, Rule::Relaxed] {
            for quick in [false, true] {
                let g = tau_grid(rule, quick);
                assert!(!g.is_empty());
                for w in g.windows(2) {
                    assert!(w[0] > w[1]);
                }
            }
        }
    }
}
