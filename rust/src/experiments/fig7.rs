//! Figure 7 (App. C.4): Pareto boundaries of strict LAMP vs the
//! random-recomputation baseline (same budget, random positions), μ=4,
//! xl-sim, web. Expected shape: random recomputation yields essentially no
//! improvement — "the adaptive choice of the recomputations is the crux".

use super::common::{load_weights, EvalOptions, EvalPanel};
use super::fig3::sweep_rule;
use crate::benchkit::{fnum, Table};
use crate::coordinator::Rule;
use crate::data::Domain;
use crate::error::Result;
use crate::metrics::pareto_front;

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let panel = EvalPanel::build(weights, Domain::Web, opts)?;
    let mut t = Table::new(
        "Fig 7 — Pareto (mu=4): LAMP vs random recomputation",
        &["rule", "tau", "recompute%", "KL", "flip%"],
    );
    for rule in [Rule::Strict, Rule::Random] {
        let (kl_pts, flip_pts) = sweep_rule(&panel, 4, rule, opts.quick)?;
        for p in pareto_front(&kl_pts) {
            let f = flip_pts
                .iter()
                .find(|q| q.tau == p.tau)
                .map(|q| q.metric)
                .unwrap_or(f64::NAN);
            t.row(vec![
                rule.name().into(),
                format!("{:.3}", p.tau),
                format!("{:.3}", 100.0 * p.rate),
                fnum(p.metric),
                format!("{:.3}", 100.0 * f),
            ]);
        }
    }
    Ok(vec![t])
}
