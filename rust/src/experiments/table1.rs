//! Table 1 (App. C.5): perplexity of full-precision / low-precision /
//! relaxed LAMP (eq. 9) / length-normalized relaxed LAMP at μ=4 on the
//! math/wiki/code panels, with the recomputation "sparsity".
//!
//! Expected shape: low precision degrades perplexity; both LAMP variants
//! recover nearly full-precision perplexity at a few percent
//! recomputation; LN trades threshold for fewer recomputations.

use super::common::{load_weights, EvalOptions, EvalPanel};
use crate::benchkit::Table;
use crate::coordinator::{PrecisionPolicy, Rule};
use crate::data::Domain;
use crate::error::Result;

pub const MU: u32 = 4;
pub const TAUS: [f32; 2] = [0.03, 0.09];

pub fn run(opts: &EvalOptions) -> Result<Vec<Table>> {
    let weights = load_weights("xl", opts)?;
    let mut t = Table::new(
        "Table 1 — perplexity (mu=4 KQ accumulation)",
        &["dataset", "method", "spec", "perplexity", "sparsity%"],
    );
    for domain in [Domain::Math, Domain::Wiki, Domain::Code] {
        let panel = EvalPanel::build(weights.clone(), domain, opts)?;
        let (ppl, _) = panel.perplexity(&PrecisionPolicy::reference(), 0)?;
        t.row(vec![
            domain.name().into(),
            "Full precision".into(),
            "N/A".into(),
            format!("{ppl:.3}"),
            "100".into(),
        ]);
        let (ppl, _) = panel.perplexity(&PrecisionPolicy::uniform(MU), 0)?;
        t.row(vec![
            domain.name().into(),
            "Low precision".into(),
            "N/A".into(),
            format!("{ppl:.3}"),
            "0".into(),
        ]);
        for tau in TAUS {
            for (rule, label) in [
                (Rule::Relaxed, format!("Relaxed (tau={tau})")),
                (Rule::RelaxedLengthNorm, format!("Relaxed LN (tau={tau})")),
            ] {
                let policy = PrecisionPolicy::lamp(MU, tau, rule);
                let (ppl, _) = panel.perplexity(&policy, 0)?;
                let r = panel.evaluate(&policy, 0)?;
                t.row(vec![
                    domain.name().into(),
                    "LAMP".into(),
                    label,
                    format!("{ppl:.3}"),
                    format!("{:.2}", 100.0 * r.rate),
                ]);
            }
        }
    }
    Ok(vec![t])
}
